#!/usr/bin/env python3
"""Validate an exported Chrome-trace file (``trace.json``).

Checks, in order:

* top-level shape: a ``traceEvents`` list plus ``otherData`` naming the
  clock the timestamps are on;
* per-row schema: every event carries ``name``/``ph``/``pid``/``tid``,
  ``ph`` is one of the phases the recorder emits (``B``/``E``/``i``/
  ``C``/``M``), and non-metadata rows carry a numeric ``ts``;
* metadata: every ``pid`` has a ``process_name`` row and every
  ``(pid, tid)`` lane a ``thread_name`` row — otherwise Perfetto shows
  bare integers;
* span discipline: ``B``/``E`` balance per ``(pid, tid)`` track with
  matching names (the recorder's well-nesting contract), and ``ts`` is
  non-decreasing within each track.  When ``otherData.dropped_events``
  is non-zero (a saturated recorder or a flight-recorder ring) span
  discipline degrades to FLAG lines: the truncation explains missing
  begins/ends, so they are reported but don't fail the check;
* ``--require-layers a,b`` additionally asserts that events of each
  listed ``cat`` are present (the repo's four layers are ``request``,
  ``engine``, ``fleet``, ``placement``).

Exits non-zero listing every problem.  No dependencies; CI runs it
against the trace the bench smoke writes, the same way the docs job
runs ``check_links.py``.

  PYTHONPATH=src python examples/fleet_demo.py
  python tools/check_trace.py trace.json --require-layers \\
      request,engine,fleet,placement
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

PHASES = ("B", "E", "i", "C", "M")


def check(path: Path, require_layers=()) -> int:
    problems = []
    flags = []
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        print(f"BAD     {path}: unreadable ({e})")
        return 1

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        print(f"BAD     {path}: no traceEvents list")
        return 1
    if not isinstance(doc.get("otherData", {}).get("clock"), str):
        problems.append("otherData.clock missing (which timebase is ts on?)")
    dropped = doc.get("otherData", {}).get("dropped_events", 0) or 0
    truncated = bool(dropped)
    # a truncated trace legitimately loses begins/ends; span-discipline
    # problems become flags (reported, non-fatal) instead of failures
    span_problems = flags if truncated else problems

    named_pids, named_tids = set(), set()
    seen_pids, seen_tids = set(), set()
    stacks = {}          # (pid, tid) -> [names of open spans]
    last_ts = {}         # (pid, tid) -> latest ts
    cats = set()
    for i, e in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in PHASES:
            problems.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            problems.append(f"{where}: missing name")
            continue
        if "pid" not in e or "tid" not in e:
            problems.append(f"{where}: missing pid/tid")
            continue
        key = (e["pid"], e["tid"])
        if ph == "M":
            if e["name"] == "process_name":
                named_pids.add(e["pid"])
            elif e["name"] == "thread_name":
                named_tids.add(key)
            continue
        seen_pids.add(e["pid"])
        seen_tids.add(key)
        cats.add(e.get("cat"))
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"{where}: {e['name']!r} has no numeric ts")
            continue
        if ts < last_ts.get(key, float("-inf")):
            problems.append(f"{where}: ts goes backwards on track {key} "
                            f"({e['name']!r}: {ts} < {last_ts[key]})")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                span_problems.append(f"{where}: end without begin "
                                     f"({e['name']!r} on track {key})")
            elif stack[-1] != e["name"]:
                span_problems.append(f"{where}: mis-nested on track {key} "
                                     f"(begin {stack[-1]!r} closed by end "
                                     f"{e['name']!r})")
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            span_problems.append(f"unclosed span(s) on track {key}: {stack}")
    for pid in seen_pids - named_pids:
        problems.append(f"pid {pid} has no process_name metadata")
    for key in seen_tids - named_tids:
        problems.append(f"track {key} has no thread_name metadata")
    for layer in require_layers:
        if layer not in cats:
            problems.append(f"required layer {layer!r} has no events "
                            f"(present: {sorted(c for c in cats if c)})")

    for p in problems:
        print(f"BAD     {path.name}: {p}")
    for f in flags:
        print(f"FLAG    {path.name}: {f}")
    n = sum(1 for e in events if isinstance(e, dict) and e.get("ph") != "M")
    trunc = f", truncated: {dropped} dropped" if truncated else ""
    print(f"checked {path.name}: {'FAIL' if problems else 'ok'} "
          f"({n} events, {len(seen_pids)} processes, "
          f"{len(seen_tids)} tracks, {len(problems)} problems, "
          f"{len(flags)} flags{trunc})")
    return 1 if problems else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="+", help="trace.json file(s) to check")
    ap.add_argument("--require-layers", default="",
                    help="comma-separated cats that must appear "
                         "(e.g. request,engine,fleet,placement)")
    args = ap.parse_args(argv)
    layers = tuple(s for s in args.require_layers.split(",") if s)
    rc = 0
    for t in args.trace:
        rc |= check(Path(t), require_layers=layers)
    return rc


if __name__ == "__main__":
    sys.exit(main())
