#!/usr/bin/env python3
"""Check that internal markdown links in README.md and docs/ resolve.

For every ``[text](target)`` in the scanned files:

* external targets (``http(s)://``, ``mailto:``) are skipped;
* relative file targets must exist on disk (resolved against the
  linking file's directory);
* fragment targets (``#heading`` or ``file.md#heading``) must match a
  heading in the target file, using GitHub's anchor slugging.

Exits non-zero listing every broken link.  No dependencies; used by the
CI docs job next to ``python -m compileall src``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
INLINE_CODE = re.compile(r"`[^`]*`")
FENCE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-style anchor for a markdown heading."""
    h = INLINE_CODE.sub(lambda m: m.group(0).strip("`"), heading)
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def headings(path: Path) -> set:
    slugs, counts = set(), {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        slug = slugify(line.lstrip("#"))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def links_in(path: Path):
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK.finditer(INLINE_CODE.sub("", line)):
            yield m.group(1)


def check(files) -> int:
    broken = []
    for md in files:
        for target in links_in(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = md if not path_part \
                else (md.parent / path_part).resolve()
            if not dest.exists():
                broken.append(f"{md.relative_to(ROOT)}: {target} "
                              f"(missing file)")
                continue
            if frag and dest.suffix == ".md" \
                    and slugify(frag.replace("-", " ")) not in headings(dest) \
                    and frag not in headings(dest):
                broken.append(f"{md.relative_to(ROOT)}: {target} "
                              f"(missing heading)")
    for b in broken:
        print(f"BROKEN  {b}")
    print(f"checked {len(files)} files: "
          f"{'FAIL' if broken else 'ok'} ({len(broken)} broken)")
    return 1 if broken else 0


def main() -> int:
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("**/*.md"))
    files = [f for f in files if f.exists()]
    return check(files)


if __name__ == "__main__":
    sys.exit(main())
