#!/usr/bin/env python3
"""Perf-regression gate over the ``BENCH_*.json`` artifacts.

``benchmarks/baselines.json`` pins a list of checks, each naming an
artifact file, a dotted path into its JSON, a comparison op and an
expected value:

* ``eq``     — exact equality (bools, counts, strings: the invariants
  the benches promise, e.g. ``bit_identical`` or zero recompiles);
* ``ge``/``le`` — one-sided floors/ceilings for ratios and rates that
  must not regress (conservative: they hold for both ``--quick`` CI
  regeneration and the committed full-mode artifacts);
* ``approx`` — two-sided band ``|v - expect| <= tol * |expect|``
  (``tol`` defaults to 0.25) for values that should stay put.

The same module owns the **perf trajectory**: ``trajectory_entry``
folds the current artifacts into one labelled row of headline numbers
and ``append_trajectory`` upserts it into ``BENCH_trajectory.json``
(rows are keyed by label, so re-running a PR's summary replaces its row
instead of duplicating it; no wall-clock stamps, so the file is
deterministic for a given set of artifacts).

  python tools/check_perf.py                       # gate (CI runs this)
  python tools/check_perf.py --list                # show every check
  PYTHONPATH=src python -m benchmarks.run --summary-only --label pr9

No dependencies; exits non-zero listing every violated check, the same
contract as ``check_trace.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINES = Path(__file__).resolve().parents[1] / "benchmarks" \
    / "baselines.json"
TRAJECTORY = "BENCH_trajectory.json"
OPS = ("eq", "ge", "le", "approx")


def get_path(doc, dotted: str):
    """Resolve a dotted path (``slots.4.speedup``) into a JSON doc.
    Dict keys are matched as strings; list hops take integer indices.
    Raises ``KeyError`` naming the full path on any miss."""
    cur = doc
    for part in dotted.split("."):
        if isinstance(cur, dict):
            if part not in cur:
                raise KeyError(dotted)
            cur = cur[part]
        elif isinstance(cur, list):
            try:
                cur = cur[int(part)]
            except (ValueError, IndexError):
                raise KeyError(dotted)
        else:
            raise KeyError(dotted)
    return cur


def check_one(root: Path, chk: dict):
    """Evaluate one baseline check; returns (ok, message)."""
    fname, dotted = chk["file"], chk["path"]
    op, expect = chk["op"], chk["expect"]
    if op not in OPS:
        return False, f"{fname}:{dotted}: unknown op {op!r}"
    path = root / fname
    if not path.exists():
        return False, f"{fname}: artifact missing (run the bench first)"
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return False, f"{fname}: unreadable ({e})"
    try:
        v = get_path(doc, dotted)
    except KeyError:
        return False, f"{fname}:{dotted}: path missing from artifact"
    if op == "eq":
        ok = v == expect
        want = f"== {expect!r}"
    elif op == "ge":
        ok = isinstance(v, (int, float)) and v >= expect
        want = f">= {expect!r}"
    elif op == "le":
        ok = isinstance(v, (int, float)) and v <= expect
        want = f"<= {expect!r}"
    else:  # approx
        tol = chk.get("tol", 0.25)
        ok = (isinstance(v, (int, float))
              and abs(v - expect) <= tol * abs(expect))
        want = f"~= {expect!r} (tol {tol:g})"
    return ok, f"{fname}:{dotted} = {v!r} (want {want})"


def run_checks(root: Path, baselines: Path):
    """Run every baseline check; returns (passed, failed) message lists."""
    doc = json.loads(baselines.read_text(encoding="utf-8"))
    passed, failed = [], []
    for chk in doc["checks"]:
        ok, msg = check_one(root, chk)
        (passed if ok else failed).append(msg)
    return passed, failed


# ------------------------------------------------------- trajectory ----
def _maybe(root: Path, fname: str, *dotted_paths: str):
    """Pull values out of an artifact, ``None``-filling anything absent
    (a missing artifact yields all-``None`` — the trajectory row still
    lands, just sparse)."""
    path = root / fname
    if not path.exists():
        return [None] * len(dotted_paths)
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError):
        return [None] * len(dotted_paths)
    out = []
    for d in dotted_paths:
        try:
            out.append(get_path(doc, d))
        except KeyError:
            out.append(None)
    return out


def trajectory_entry(root: Path, label: str) -> dict:
    """One labelled row of headline numbers from the current artifacts
    (the fields documented in README's BENCH_trajectory.json table)."""
    s_tps, s_speedup, s_bit, s_obs = _maybe(
        root, "BENCH_serving.json",
        "slots.4.batched.tokens_per_s", "slots.4.speedup",
        "bit_identical", "obs_overhead.overhead_factor")
    pk_tps, pk_match, pk_gain = _maybe(
        root, "BENCH_serving.json",
        "paged_kernel.kernel_int8.tokens_per_s",
        "paged_kernel.greedy_matches_dense",
        "paged_kernel.residency_gain")
    p_ratio, p_ttft, p_bit = _maybe(
        root, "BENCH_paging.json",
        "differential.paged_over_dense_throughput",
        "prefix_admission.ttft_speedup", "differential.bit_identical")
    pl_speedup, pl_viol = _maybe(
        root, "BENCH_placement.json",
        "phone_p95.p95_speedup", "phone_p95.fleet_violations")
    f_goodput, f_mttd, f_mttr = _maybe(
        root, "BENCH_faults.json",
        "goodput.ratio", "detection.mean_mttd_s", "detection.mean_mttr_s")
    fl_v1, fl_v2 = _maybe(
        root, "BENCH_fleet.json",
        "violations.first_half", "violations.second_half")
    return {
        "label": label,
        "serving": {"tokens_per_s_slots4": s_tps,
                    "batched_speedup_slots4": s_speedup,
                    "bit_identical": s_bit,
                    "obs_overhead_factor": s_obs,
                    "paged_kernel_int8_tokens_per_s": pk_tps,
                    "paged_kernel_matches_dense": pk_match,
                    "int8_residency_gain": pk_gain},
        "paging": {"paged_over_dense_throughput": p_ratio,
                   "prefix_ttft_speedup": p_ttft,
                   "bit_identical": p_bit},
        "placement": {"phone_p95_speedup": pl_speedup,
                      "fleet_violations": pl_viol},
        "faults": {"goodput_ratio": f_goodput,
                   "mean_mttd_s": f_mttd, "mean_mttr_s": f_mttr},
        "fleet": {"violations_first_half": fl_v1,
                  "violations_second_half": fl_v2},
    }


def append_trajectory(path: Path, entry: dict) -> dict:
    """Upsert ``entry`` into the trajectory file by label; returns the
    full document written."""
    doc = {"entries": []}
    if path.exists():
        doc = json.loads(path.read_text(encoding="utf-8"))
    entries = [e for e in doc.get("entries", [])
               if e.get("label") != entry["label"]]
    entries.append(entry)
    doc = {"entries": entries}
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n",
                    encoding="utf-8")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES),
                    help="baseline checks file")
    ap.add_argument("--root", default=".",
                    help="directory holding the BENCH_*.json artifacts")
    ap.add_argument("--list", action="store_true",
                    help="print every check result, not just failures")
    args = ap.parse_args(argv)
    passed, failed = run_checks(Path(args.root), Path(args.baselines))
    if args.list:
        for msg in passed:
            print(f"ok      {msg}")
    for msg in failed:
        print(f"BAD     {msg}")
    print(f"checked {len(passed) + len(failed)} baselines: "
          f"{'FAIL' if failed else 'ok'} ({len(failed)} regressions)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
