"""Differential paging suite: the paged KV cache must be invisible.

* ``decode_mode="paged"`` produces token streams **bit-identical** to
  the dense batched decode over random request mixes (prompt lengths,
  budgets, admit times, sampling temperatures) and block sizes — the
  gather-to-dense view plus REPLACE masking means garbage beyond
  ``pos`` contributes exactly zero, so equality is exact, not approx.
* Block tables are runtime data: serving a second wave with different
  pool fragmentation (different table *contents*) and a second engine
  with the same geometry cost **zero** new program compiles.
* Prefix sharing is copy-on-write-safe: identical prompts share their
  prompt blocks (observable refcounts), post-fork decode never mutates
  a shared block, and freeing one sharer leaves the others' streams
  bit-identical.  Full-prompt prefix hits re-admit with
  ``prefill_calls += 0``.
* ``freeze``/``thaw`` round-trips are exact — same engine, across
  block sizes, across decode modes — and incompatible blobs fall back
  to the legacy requeue with zero token loss.
* A deliberately tight pool exercises allocation backpressure and
  decode-driven preemption without livelock or stream drift.
"""
import types

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # deterministic corpus still runs
    HAVE_HYPOTHESIS = False

import jax
import numpy as np

from repro.configs import get_config
from repro.faults import MigrationOutcome, plan_migration
from repro.models.model import init_params
from repro.models.runtime import DEFAULT_OPTIONS
from repro.serving import (CompileCache, PrefixCache, PrefixEntry, Request,
                           SamplingOpts, ServingEngine, block_hash_chain,
                           blocks_needed)
from repro.serving.paging import TRASH_BLOCK, BlockPool

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MAX_SEQ = 64
# one cache for the whole module: every example reuses compiled programs
CC = CompileCache()

# deterministic request mixes — (prompt length, token budget,
# submit-at-step, temperature) — covering the same space the hypothesis
# strategies below fuzz: single/short/long prompts, bucket boundaries,
# mid-stream admits, greedy and high-temperature sampling, duplicate specs
MIX_CORPUS = [
    [(1, 1, 0, 0.0)],
    [(40, 6, 0, 0.8)],
    [(5, 4, 0, 0.0), (20, 4, 1, 0.8), (33, 3, 2, 1.4), (9, 2, 2, 0.0)],
    [(16, 3, 0, 1.4), (16, 3, 0, 1.4), (17, 3, 3, 0.8)],
    [(7, 6, 1, 0.8), (22, 5, 2, 0.0), (11, 4, 3, 1.4), (3, 2, 0, 0.0),
     (28, 3, 1, 0.8), (13, 2, 2, 1.4)],
]

if HAVE_HYPOTHESIS:
    SETTINGS = settings(max_examples=12, deadline=None,
                        suppress_health_check=[HealthCheck.too_slow,
                                               HealthCheck.data_too_large])
    REQ_SPEC = st.tuples(st.integers(1, 40), st.integers(1, 6),
                         st.integers(0, 3),
                         st.sampled_from([0.0, 0.8, 1.4]))
    REQ_MIXES = st.lists(REQ_SPEC, min_size=1, max_size=6)
    BLOCK_SIZES = st.sampled_from([4, 8, 16])


def _prompt(length: int, rid: int) -> np.ndarray:
    rng = np.random.default_rng(31 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _requests(mix, rid_base=0):
    return [Request(rid=rid_base + i, prompt=_prompt(n, rid_base + i),
                    max_new_tokens=budget,
                    sampling=SamplingOpts(temperature=temp, seed=5))
            for i, (n, budget, _, temp) in enumerate(mix)]


def _engine(**kw):
    kw.setdefault("slots", 2)
    return ServingEngine(CFG, PARAMS, max_seq=MAX_SEQ, compile_cache=CC,
                         **kw)


def _drive(eng, reqs, mix, max_steps=200):
    """Admit on the mix's schedule and step until every request is done."""
    step = 0
    while any(not r.done for r in reqs):
        for r, (_, _, at, _) in zip(reqs, mix):
            if at == step:
                eng.submit(r)
        eng.step()
        step += 1
        assert step < max_steps, "engine failed to drain"
    return [tuple(r.generated) for r in reqs]


def _run(mix, *, rid_base=0, max_steps=200, **kw):
    eng = _engine(**kw)
    reqs = _requests(mix, rid_base)
    streams = _drive(eng, reqs, mix, max_steps)
    return streams, eng


# ------------------------------------------------- paged ≡ dense batched --
_DENSE = {}     # memoized dense baselines, shared across block sizes


def _dense_baseline(mix):
    key = tuple(mix)
    if key not in _DENSE:
        streams, eng = _run(mix, decode_mode="batched")
        _DENSE[key] = (streams, eng.stats.prefills,
                       eng.stats.prefill_calls)
    return _DENSE[key]


def _check_paged_matches_dense(mix, block_size):
    paged, peng = _run(mix, decode_mode="paged", block_size=block_size)
    dense, prefills, calls = _dense_baseline(mix)
    assert paged == dense                       # bit-identical streams
    assert peng.stats.prefills == prefills
    assert peng.stats.prefill_calls <= calls
    # the drained pool leaks nothing: every slot returned its blocks
    assert (peng.block_pool.tables == TRASH_BLOCK).all()


@pytest.mark.parametrize("block_size", [4, 8, 16])
@pytest.mark.parametrize("mix", MIX_CORPUS, ids=range(len(MIX_CORPUS)))
def test_paged_decode_matches_dense_batched(mix, block_size):
    _check_paged_matches_dense(mix, block_size)


@pytest.mark.parametrize("mix", MIX_CORPUS[2:], ids=range(2, 5))
def test_paged_matches_per_slot_reference(mix):
    paged, _ = _run(mix, decode_mode="paged", slots=3)
    ref, _ = _run(mix, decode_mode="per_slot", slots=3)
    assert paged == ref


if HAVE_HYPOTHESIS:
    @SETTINGS
    @given(mix=REQ_MIXES, block_size=BLOCK_SIZES)
    def test_paged_decode_matches_dense_batched_fuzzed(mix, block_size):
        _check_paged_matches_dense(mix, block_size)


# --------------------------------------------- block tables as runtime data --
def test_no_recompiles_across_block_table_shapes():
    """Different pool fragmentation / occupancy = different table
    *contents*, never different compiled programs.  The outer compile
    key stays ``(cfg, opts, slots, max_seq, domain)``."""
    mix = [(5, 4, 0, 0.0), (20, 4, 1, 0.8), (33, 3, 2, 1.4),
           (9, 2, 2, 0.0)]
    eng = _engine(decode_mode="paged", slots=2)
    _drive(eng, _requests(mix), mix)
    warm = eng.stats.recompiles
    # second wave on the same engine: same buckets/burst shapes but a
    # fragmented pool + populated prefix cache → different tables
    _drive(eng, _requests(mix, rid_base=100), mix)
    assert eng.stats.recompiles == warm

    # a second engine with identical geometry shares every program
    eng2 = _engine(decode_mode="paged", slots=2)
    _drive(eng2, _requests(mix, rid_base=200), mix)
    assert eng2.stats.recompiles == 0


def test_paged_rejects_invalid_block_size():
    for bad in (0, 3, 5, 32):
        with pytest.raises(ValueError):
            _engine(decode_mode="paged", block_size=bad)


# --------------------------------------------------------- prefix sharing --
def test_identical_prompts_share_prompt_blocks():
    """A burst of identical prompts dedups to one physical copy of the
    prompt blocks; divergent decode tails never touch them."""
    prompt = _prompt(20, 0)                 # bucket 32 → 2 prompt blocks
    solo = {}
    for rid in range(4):
        eng = _engine(decode_mode="paged", block_size=16, slots=1)
        req = Request(rid=rid, prompt=prompt.copy(), max_new_tokens=6,
                      sampling=SamplingOpts(temperature=1.2, seed=5))
        eng.submit(req)
        eng.drain()
        solo[rid] = tuple(req.generated)

    eng = _engine(decode_mode="paged", block_size=16, slots=4)
    # rid 0 finishes first: freeing one sharer must not disturb the rest
    reqs = [Request(rid=rid, prompt=prompt.copy(),
                    max_new_tokens=3 if rid == 0 else 6,
                    sampling=SamplingOpts(temperature=1.2, seed=5))
            for rid in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.step()                              # one burst admits all four
    pool = eng.block_pool
    tables = pool.tables
    first = [tuple(tables[s, :2]) for s in range(4)]
    assert first.count(first[0]) == 4       # all slots map the same blocks
    assert TRASH_BLOCK not in first[0]
    assert pool.shared_blocks >= 2
    assert all(int(pool.refs[b]) >= 4 for b in first[0])
    eng.drain()
    # post-fork writes never mutated the shared blocks: every sharer's
    # stream is bit-identical to its solo run, including after rid 0
    # finished and dropped its references
    for r in reqs:
        assert tuple(r.generated) == solo[r.rid][:r.max_new_tokens]


def test_prefix_hit_readmission_skips_prefill():
    """Re-admitting a full prompt already in the prefix cache costs zero
    prefill calls and stays bit-identical to a cold admission."""
    prompt = _prompt(18, 7)
    opts = SamplingOpts(temperature=0.9, seed=3)

    cold_eng = _engine(decode_mode="paged", slots=1)
    cold = Request(rid=7, prompt=prompt.copy(), max_new_tokens=5,
                   sampling=opts)
    cold_eng.submit(cold)
    cold_eng.drain()

    eng = _engine(decode_mode="paged", slots=1)
    warmer = Request(rid=99, prompt=prompt.copy(), max_new_tokens=5,
                     sampling=opts)
    eng.submit(warmer)
    eng.drain()
    calls = eng.stats.prefill_calls
    hit = Request(rid=7, prompt=prompt.copy(), max_new_tokens=5,
                  sampling=opts)
    eng.submit(hit)
    eng.drain()
    assert eng.stats.prefill_calls == calls     # prefill skipped entirely
    assert eng.stats.prefills == 2              # but still accounted
    assert tuple(hit.generated) == tuple(cold.generated)


# ------------------------------------------------------------ freeze/thaw --
def _freeze_after(eng, reqs, steps):
    for r in reqs:
        eng.submit(r)
    for _ in range(steps):
        eng.step()
    moved = eng.freeze_all("migrate") + eng.drain_waiting()
    assert not eng.has_work
    return moved


def test_freeze_thaw_same_engine_is_exact():
    mix = [(9, 6, 0, 1.2), (25, 6, 0, 0.0)]
    baseline, _ = _run(mix, decode_mode="paged")
    eng = _engine(decode_mode="paged")
    reqs = _requests(mix)
    moved = _freeze_after(eng, reqs, steps=3)
    assert all(r.frozen is not None for r in moved if r.generated)
    for r in moved:
        assert eng.thaw(r)
    eng.drain()
    assert [tuple(r.generated) for r in reqs] == baseline
    assert eng.stats.freezes >= 1 and eng.stats.thaws >= 1


@pytest.mark.parametrize("dst_kw", [
    dict(decode_mode="paged", block_size=4),
    dict(decode_mode="paged", block_size=16),
    dict(decode_mode="batched"),
    dict(decode_mode="per_slot"),
])
def test_freeze_thaw_migrates_across_geometries(dst_kw):
    """Freeze blobs are portable: a paged bs=8 source thaws on paged
    engines with other block sizes and on dense engines, with zero
    re-prefill and bit-identical continuations."""
    mix = [(9, 6, 0, 1.2), (25, 6, 0, 0.8), (30, 5, 0, 0.0)]
    baseline, _ = _run(mix, decode_mode="paged", slots=3)
    src = _engine(decode_mode="paged", block_size=8, slots=3)
    reqs = _requests(mix)
    moved = _freeze_after(src, reqs, steps=3)

    dst = _engine(slots=3, **dst_kw)
    plan = plan_migration(moved, dst.can_thaw)
    assert set(plan.migrated) == {r.rid for r in moved
                                  if r.frozen is not None}
    calls = dst.stats.prefill_calls
    for r in moved:
        assert dst.thaw(r)
    dst.drain()
    # only requests frozen *pre-admission* (blob-less) may prefill here
    assert dst.stats.prefill_calls - calls <= len(plan.fallback)
    assert [tuple(r.generated) for r in reqs] == baseline


def test_incompatible_blob_falls_back_without_token_loss():
    """A fingerprint mismatch can't thaw: the blob is dropped and the
    generated prefix folds into the prompt for an ordinary re-prefill.
    That path guarantees zero token *loss* — everything earned before
    the fallback is preserved verbatim and never re-emitted, and the
    request still reaches its full budget — but not bit-identity: the
    merged prompt re-buckets, so the continuation's cache layout (and
    therefore its sampled tokens) may legitimately differ from the
    uninterrupted run's."""
    mix = [(9, 6, 0, 1.2), (25, 6, 0, 0.0)]
    baseline, _ = _run(mix, decode_mode="paged")
    src = _engine(decode_mode="paged", params_version="v1")
    reqs = _requests(mix)
    moved = _freeze_after(src, reqs, steps=3)
    kept = {r.rid: tuple(r.generated) for r in moved}
    # pre-freeze decoding was undisturbed: earned tokens match baseline
    for r, base in zip(reqs, baseline):
        assert kept[r.rid] == base[:len(kept[r.rid])]

    dst = _engine(decode_mode="paged", params_version="v2")
    frozen = [r for r in moved if r.frozen is not None]
    assert frozen and all(not dst.can_thaw(r.frozen) for r in frozen)
    for r in moved:
        dst.thaw(r)                 # falls back to the legacy requeue
    assert all(r.frozen is None for r in moved)
    dst.drain()
    assert dst.stats.prefill_calls > 0      # the fallback did re-prefill
    assert dst.stats.thaws == 0
    for r, (_, budget, _, _) in zip(reqs, mix):
        assert tuple(r.generated)[:len(kept[r.rid])] == kept[r.rid]
        assert len(r.generated) == budget       # full budget, no loss


@pytest.mark.parametrize("decode_mode", ["batched", "paged"])
def test_swap_model_same_params_reprefills_nothing(decode_mode):
    """A same-variant ``swap_model`` (e.g. a placement-driven restart)
    freezes, rebuilds and thaws: zero extra prefill calls for in-flight
    requests, streams bit-identical to an unswapped run."""
    mix = [(9, 6, 0, 1.2), (25, 6, 0, 0.8), (14, 6, 0, 0.0)]
    baseline, _ = _run(mix, decode_mode=decode_mode, slots=3)
    eng = _engine(decode_mode=decode_mode, slots=3)
    reqs = _requests(mix)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    calls = eng.stats.prefill_calls
    eng.swap_model(CFG, PARAMS, DEFAULT_OPTIONS)
    eng.drain()
    assert eng.stats.prefill_calls == calls
    assert [tuple(r.generated) for r in reqs] == baseline


# ------------------------------------------------------- pool under stress --
def test_tight_pool_backpressure_and_preemption_stay_exact():
    """A pool one block above the single-slot minimum forces admission
    backpressure and decode-tail preemption; streams must not drift and
    the engine must not livelock (thaw uses backpressure, never
    preemption)."""
    mix = [(5, 30, 0, 0.7), (11, 30, 0, 0.0), (7, 25, 1, 1.4)]
    baseline, _ = _run(mix, decode_mode="batched", slots=2,
                       max_steps=600)
    eng = _engine(decode_mode="paged", block_size=16, slots=2,
                  pool_blocks=6)
    reqs = _requests(mix)
    streams = _drive(eng, reqs, mix, max_steps=600)
    assert streams == baseline
    assert eng.stats.freezes >= 1          # preemption actually happened
    assert eng.stats.thaws == eng.stats.freezes
    assert (eng.block_pool.tables == TRASH_BLOCK).all()


# ----------------------------------------------------------- pure pieces --
def test_block_pool_refcounts_and_release():
    pool = BlockPool(slots=2, num_blocks=9, block_size=4, max_seq=32)
    assert pool.free_blocks == 8            # trash block is pinned out
    ids = pool.alloc(3)
    assert len(ids) == 3 and TRASH_BLOCK not in ids
    assert pool.free_blocks == 5
    for i, bid in enumerate(ids):
        pool.assign(0, i, bid)
    pool.incref(ids[0])
    pool.assign(1, 0, ids[0])
    assert pool.shared_blocks == 1
    assert pool.alloc(100) is None          # all-or-nothing allocation
    assert pool.free_blocks == 5
    freed = pool.release_slot(0)
    assert freed == 2                       # shared block survives slot 0
    assert pool.free_blocks == 7
    assert pool.release_slot(1) == 1
    assert pool.free_blocks == 8
    assert (pool.tables[:, :] == TRASH_BLOCK).all()


def test_blocks_needed_arithmetic():
    assert blocks_needed(1, 4) == 1
    assert blocks_needed(4, 4) == 1
    assert blocks_needed(5, 4) == 2
    assert blocks_needed(64, 16) == 4


def test_block_hash_chain_is_prefix_sensitive():
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    b[12] = 999                             # diverge in the final block
    ha = block_hash_chain(a, 4, salt="s")
    hb = block_hash_chain(b, 4, salt="s")
    assert len(ha) == 4
    assert ha[:3] == hb[:3]                 # shared prefix, same hashes
    assert ha[3] != hb[3]
    c = a.copy()
    c[2] = 999                              # diverge in the *first* block
    hc = block_hash_chain(c, 4, salt="s")
    assert all(x != y for x, y in zip(ha, hc))   # chain poisons the rest
    assert block_hash_chain(a, 4, salt="other") != ha


def test_prefix_cache_lru_returns_blocks():
    pool = BlockPool(slots=1, num_blocks=9, block_size=4, max_seq=32)
    cache = PrefixCache(capacity=2)

    for key in ("a", "b", "c"):
        ids = pool.alloc(2)             # the writing slot's references
        cache.insert(key, PrefixEntry(block_ids=tuple(ids),
                                      logits_row=None, leaves={}, pos=8),
                     pool)              # insert takes the cache's own ref
        for bid in ids:
            pool.decref(bid)            # slot finishes; cache pin remains
    assert len(cache) == 2
    assert cache.lookup("a") is None        # LRU-evicted, blocks decref'd
    assert pool.free_blocks == 4
    cache.clear(pool)
    assert pool.free_blocks == 8


def test_plan_migration_accounting():
    def req(rid, frozen, tokens):
        return types.SimpleNamespace(rid=rid, frozen=frozen,
                                     generated=[0] * tokens)

    blob = object()
    plan = plan_migration(
        [req(1, blob, 4), req(2, None, 2), req(3, blob, 0)],
        can_thaw=lambda f: f is blob)
    assert plan == MigrationOutcome(migrated=(1, 3), fallback=(2,),
                                    recovered_tokens=6)
    assert plan.total == 3
