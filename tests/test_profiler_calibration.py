"""Profiler calibration: the paper's stated requirement is CONSISTENT
RANKING between estimated and actual performance.  Measure real CPU
wall-times for a ladder of variants and check Spearman rank agreement
with the Eq.(2) estimates."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import MOBILE_CPU, estimate_latency, layer_costs, rank_consistency
from repro.elastic import VariantSpec, derive_variant
from repro.models import forward, init_params

import time


def _walltime(fn, *args, iters=3):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def test_estimated_latency_ranks_match_measured():
    cfg = get_config("paper-backbone")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 256), 0,
                                cfg.vocab_size)
    ladder = [
        VariantSpec(),                                   # full
        VariantSpec(width_ratio=0.75),
        VariantSpec(width_ratio=0.5, depth_ratio=0.75),
        VariantSpec(width_ratio=0.5, depth_ratio=0.5),
    ]
    est, meas = [], []
    for spec in ladder:
        vcfg, vp = derive_variant(cfg, params, spec)
        costs = layer_costs(vcfg, 2, 256)
        est.append(estimate_latency(costs, 0.5, MOBILE_CPU))
        f = jax.jit(lambda p, t: forward(p, vcfg, t)[0])
        meas.append(_walltime(f, vp, tokens))
    rho = rank_consistency(est, meas)
    assert rho >= 0.79, (f"profiler ranking broke: est={est} meas={meas} "
                         f"rho={rho}")
