"""Per-architecture smoke tests: reduced same-family variants run one
forward + one train step + one decode step on CPU, asserting output shapes
and the absence of NaNs.  (The FULL configs are exercised only via the
dry-run — ShapeDtypeStruct, no allocation.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import (RuntimeOptions, decode_step, forward, init_cache,
                          init_params, lm_loss, prefill)

OPTS = RuntimeOptions(moe_capacity_factor=2.0)


def _inputs(cfg, key, batch=2, seq=16):
    kw = {}
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        kw["encoder_frames"] = jax.random.normal(
            key, (batch, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    if cfg.vision_embed_dim:
        kw["vision_embeds"] = jax.random.normal(
            key, (batch, cfg.num_vision_tokens, cfg.vision_embed_dim)) * 0.1
    return tokens, kw


@pytest.mark.parametrize("arch", list_archs())
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens, kw = _inputs(cfg, key)
    logits, aux = forward(params, cfg, tokens, OPTS, **kw)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    tokens, kw = _inputs(cfg, key)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(p, cfg, tokens, OPTS, **kw)
        return lm_loss(logits, labels) + cfg.router_aux_weight * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # at least one nonzero gradient per major component
    total = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens, kw = _inputs(cfg, key, seq=8)
    cache = init_cache(cfg, 2, 32, OPTS)
    logits, cache = prefill(params, cfg, tokens, cache, OPTS, **kw)
    assert int(cache["pos"]) == 8
    lg, cache = decode_step(params, cfg, cache, tokens[:, -1], OPTS)
    assert lg.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())
    assert int(cache["pos"]) == 9


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    """Decode with cache must agree with full forward at the last position
    (capacity set high enough that MoE drops nothing)."""
    cfg = get_config(arch).reduced()
    opts = RuntimeOptions(moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    tokens, kw = _inputs(cfg, key, seq=12)
    logits, _ = forward(params, cfg, tokens, opts, **kw)
    cache = init_cache(cfg, 2, 24, opts)
    _, cache = prefill(params, cfg, tokens[:, :11], cache, opts, **kw)
    lg, _ = decode_step(params, cfg, cache, tokens[:, 11], opts)
    ref = logits[:, -1].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(ref - lg.astype(jnp.float32)))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.06, f"{arch}: decode diverges from forward (rel={rel})"
