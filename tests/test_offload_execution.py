"""Offload placement EXECUTED: stage the model across real (forced-host)
devices per the placer's cuts and run it, verifying numerical equivalence
with single-device execution — the paper's cross-device inference path,
device_put standing in for the IP/PORT transport."""
import os
import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import forward, init_params
    from repro.models.transformer import apply_stack
    from repro.models.layers import cast_params, embed_lookup, rms_norm, unembed, mask_padded_logits_raw
    from repro.offload import build_model_graph, pre_partition, place_dp, DeviceProfile

    cfg = get_config("paper-backbone").with_updates(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    ref, _ = forward(params, cfg, tokens)

    # place at layer granularity over two equal devices
    g = build_model_graph(cfg, 1, 16)
    pp = pre_partition(g)
    devs = (DeviceProfile("d0", 50e9, 1e12, 10e9, 1e9),
            DeviceProfile("d1", 50e9, 1e12, 10e9, 0))
    pl = place_dp(pp, devs, level=2)
    units = pp.units(2)
    # map unit -> layer range; unit names layerK hold ops tagged with layer K
    assign = pl.assignment
    cut_layer = 0
    for i in range(len(units) - 1):
        if assign[i] != assign[i + 1]:
            # unit i is the last on device 0; its max op layer is the cut
            cut_layer = max(n.layer for name in units[:i+1][-1].node_names
                            for n in g.nodes if n.output == name) + 1
            break
    cut_layer = max(1, min(cut_layer, cfg.num_layers - 1))

    dev0, dev1 = jax.devices()[0], jax.devices()[1]
    import jax.tree_util as tu
    p = cast_params(params, jnp.bfloat16)
    stage0 = tu.tree_map(lambda a: jax.device_put(a[:cut_layer], dev0),
                         p["layers"])
    stage1 = tu.tree_map(lambda a: jax.device_put(a[cut_layer:], dev1),
                         p["layers"])
    embed0 = jax.device_put(p["embed"], dev0)
    embed1 = jax.device_put(p["embed"], dev1)
    fn1 = jax.jit(lambda s, t: apply_stack(s, embed_lookup(embed0, t)
                                           .astype(jnp.bfloat16), cfg,
                                           __import__("repro.models.runtime",
                                           fromlist=["DEFAULT_OPTIONS"])
                                           .DEFAULT_OPTIONS)[0],
                  device=dev0)
    def fn2_impl(s, x):
        from repro.models.runtime import DEFAULT_OPTIONS
        x, _ = apply_stack(s, x, cfg, DEFAULT_OPTIONS)
        x = rms_norm(x, jax.device_put(p["final_norm"], dev1), cfg.norm_eps)
        return mask_padded_logits_raw(unembed(embed1, x), cfg.vocab_size)
    fn2 = jax.jit(fn2_impl, device=dev1)

    h = fn1(stage0, jax.device_put(tokens, dev0))
    h = jax.device_put(h, dev1)        # the "offload transfer"
    out = fn2(stage1, h)
    err = float(jnp.max(jnp.abs(np.asarray(out, np.float32)
                                - np.asarray(ref, np.float32))))
    rel = err / (float(np.abs(np.asarray(ref, np.float32)).max()) + 1e-9)
    print("STAGED_OK", rel < 0.02, "rel", rel, "cut", cut_layer,
          "devices", out.devices(), ref.shape == out.shape)
""")


def test_offloaded_stages_execute_equivalently():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert "STAGED_OK True" in r.stdout, (r.stdout[-500:], r.stderr[-1500:])
