"""ServingEngine swap_model token accounting: re-queued in-flight
requests must not overshoot max_new_tokens or double-count tokens_out.
Runs against BOTH decode paths (slot-batched and the per-slot
reference) — swap semantics must not depend on the decode mode."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving import CompileCache, Request, ServingEngine

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
CC = CompileCache()


@pytest.fixture(params=["batched", "per_slot"])
def mode(request):
    return request.param


def _engine(mode, slots=2):
    return ServingEngine(CFG, PARAMS, slots=slots, max_seq=64,
                         decode_mode=mode, compile_cache=CC)


def test_swap_midflight_respects_token_budget(mode):
    eng = _engine(mode)
    prompt = np.arange(1, 9, dtype=np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    eng.step()                       # prefill token + one decode token
    assert eng.stats.tokens_out == 2
    eng.swap_model(CFG, PARAMS, eng.opts)     # re-queues the in-flight copy
    assert len(eng._queue) == 1
    requeued = eng._queue[0]
    eng.drain()
    assert requeued.done
    # re-prefill's argmax token completes the budget — exactly, not max+1
    assert len(requeued.generated) == 3
    # every generated token counted once across the swap
    assert eng.stats.tokens_out == 3


def test_swap_with_budget_already_spent_emits_nothing(mode):
    eng = _engine(mode)
    prompt = np.arange(1, 6, dtype=np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    eng.step()                       # generated: prefill + decode = 2 == max
    # request finished inside step(); nothing in flight survives the swap
    eng.swap_model(CFG, PARAMS, eng.opts)
    before = eng.stats.tokens_out
    eng.drain()
    assert eng.stats.tokens_out == before == 2


def test_zero_budget_request_never_prefills(mode):
    eng = _engine(mode)
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=0))
    eng.step()
    assert eng.stats.tokens_out == 0
    assert eng.stats.prefills == 0
    assert not any(eng._active) and not eng._queue


def test_prompt_longer_than_max_seq_is_truncated_not_crashed(mode):
    # covers both a fresh oversized submission and a swap re-queue whose
    # prompt grew past max_seq by the generated prefix
    eng = _engine(mode)
    eng.submit(Request(rid=0, prompt=np.arange(1, 101, dtype=np.int32),
                       max_new_tokens=2))
    eng.drain()
    assert eng.stats.prefills == 1
    assert eng.stats.tokens_out >= 1


def test_swap_preserves_first_token_stamp(mode):
    # TTFT is submit→first token; a swap re-queue's re-prefill must not
    # restamp it (the re-queued copy carries the original stamp)
    eng = _engine(mode)
    req = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=6)
    eng.submit(req)
    eng.step()
    stamp = req.first_token_s
    assert stamp is not None
    eng.swap_model(CFG, PARAMS, eng.opts)
    requeued = eng._queue[0]
    assert requeued.first_token_s == stamp
    eng.drain()
    assert requeued.done
    assert requeued.first_token_s == stamp


def test_step_timing_hook_fires(mode):
    eng = _engine(mode)
    seen = []
    eng.on_step = lambda dt, emitted, gen: seen.append((dt, emitted, gen))
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))
    eng.drain()
    assert len(eng.step_times) == eng.stats.steps == len(seen)
    assert all(dt > 0 for dt, _, _ in seen)
