"""Property suite for the observability layer: one timeline, no drift.

* **Span discipline** — every trace an engine emits is well-nested per
  ``(pid, tid)`` track and monotone on the wall clock, across both
  decode modes, random request mixes, and a mid-run ``swap_model``
  (which force-closes every in-flight slot span with a
  ``swap_requeue`` reason).  When ``hypothesis`` is installed the same
  property runs over generated mixes; otherwise a fixed-seed
  parametrization covers the same space.
* **Accounting** — one ``req.first_token`` instant per admission, one
  ``engine.prefill`` span per prefill jit call, and per-rid
  ``admissions + decode instants == len(generated)`` (so the trace and
  the token streams can never disagree about throughput).
* **TTFT bit-equality** — ``request_ttft_s`` equals the legacy
  ``first_token_s - arrived_s`` subtraction exactly, because the
  instants carry the very floats the engine stamps on the request.
* **Views, not copies** — ``ServeStats`` attributes and
  ``step_time_ewma_s`` read the metrics registry; :class:`EwmaGauge`
  reproduces the historical ``0.8*prev + 0.2*x`` fold bit-for-bit; P²
  histogram quantiles track ``np.percentile`` on a heavy-tailed stream.
* **Fleet timeline** — a placement-enabled fleet run with an
  engine-backed device and a mid-run ``drop_device`` produces events in
  all four layers, every one stamped on the simulated clock, monotone
  per track, exporting to a Chrome trace that ``tools/check_trace.py``
  accepts; report totals equal the records-derived sums.
* **Null path** — the default :data:`NULL_RECORDER` records nothing and
  token streams are bit-identical with tracing on and off.
"""
import importlib.util
import json
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.monitor import ResourceContext
from repro.fleet import FleetController, build_fleet, fleet_report
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.obs import (LAYERS, NULL_RECORDER, EwmaGauge, Histogram,
                       MetricsRegistry, SLOClass, SLOTracker, TraceRecorder,
                       chrome_trace, instants, request_token_counts,
                       request_ttft_s, spans, write_trace)
from repro.serving import CompileCache, Request, ServingEngine

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
CC = CompileCache()          # shared: each program compiles exactly once

_ct_spec = importlib.util.spec_from_file_location(
    "check_trace",
    Path(__file__).resolve().parents[1] / "tools" / "check_trace.py")
check_trace = importlib.util.module_from_spec(_ct_spec)
_ct_spec.loader.exec_module(check_trace)


def _prompt(length, rid):
    rng = np.random.default_rng(101 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _run_engine(mix, mode, swap=False):
    """Run a request mix to completion under a TraceRecorder; optionally
    swap the model after the first step (re-queueing whatever is in
    flight).  Returns (recorder, engine, requests)."""
    rec = TraceRecorder()
    eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                        decode_mode=mode, compile_cache=CC,
                        recorder=rec, pid="dev0")
    reqs = [Request(rid=i, prompt=_prompt(n, i), max_new_tokens=budget)
            for i, (n, budget) in enumerate(mix)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    if swap:
        eng.swap_model(CFG, PARAMS, eng.opts)
    eng.drain()
    return rec, eng, reqs


def _assert_trace_properties(rec, eng, reqs):
    # well-nested per track: spans() raises on any mismatched edge
    all_spans = spans(rec)
    # wall clock monotone within each (pid, tid) track
    last = {}
    for e in rec.events:
        key = (e.pid, e.tid)
        assert e.wall_s >= last.get(key, float("-inf")), \
            f"wall clock went backwards on {key} at {e.name}"
        last[key] = e.wall_s
    # standalone engine: no sim clock anywhere
    assert all(e.sim_s is None for e in rec.events)
    # accounting: admissions match first-token instants, every slot
    # occupancy is either a prefill admission or a thaw re-admission
    # (swap_model requeues in-flight requests via freeze/thaw, which
    # opens a fresh req.slot span without a new first token), prefill
    # spans match prefill jit calls, decodes complete the streams
    counts = request_token_counts(rec)
    admissions = sum(d["admissions"] for d in counts.values())
    decodes = sum(d["decodes"] for d in counts.values())
    assert admissions == eng.stats.prefills
    assert len(spans(rec, name="req.slot")) == admissions + eng.stats.thaws
    assert len(spans(rec, name="engine.prefill")) == eng.stats.prefill_calls
    assert admissions + decodes == eng.stats.tokens_out
    for r in reqs:
        # a swap freezes and re-queues the SAME object; its stream is
        # complete only once it finished (the aggregate tokens_out
        # check above covers anything still in flight)
        if not r.done or not r.generated:
            continue
        d = counts[r.rid]
        assert d["admissions"] + d["decodes"] == len(r.generated)
    # TTFT from spans == legacy subtraction, bit for bit
    span_ttft = request_ttft_s(rec)
    for r in reqs:
        if r.first_token_s is None:
            assert r.rid not in span_ttft
        else:
            assert span_ttft[r.rid] == r.first_token_s - r.arrived_s
    return all_spans


FIXED_MIXES = [
    [(8, 3), (24, 5)],
    [(1, 1)],
    [(40, 2), (3, 6), (17, 4)],
    [(12, 4), (12, 4), (12, 4)],         # same bucket: a burst
]


@pytest.mark.parametrize("mode", ["batched", "per_slot"])
@pytest.mark.parametrize("swap", [False, True])
@pytest.mark.parametrize("mix", FIXED_MIXES,
                         ids=[f"mix{i}" for i in range(len(FIXED_MIXES))])
def test_trace_properties_fixed(mode, swap, mix):
    rec, eng, reqs = _run_engine(mix, mode, swap=swap)
    _assert_trace_properties(rec, eng, reqs)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(mix=st.lists(st.tuples(st.integers(1, 40), st.integers(1, 6)),
                        min_size=1, max_size=5),
           mode=st.sampled_from(["batched", "per_slot"]),
           swap=st.booleans())
    def test_trace_properties_hypothesis(mix, mode, swap):
        rec, eng, reqs = _run_engine(mix, mode, swap=swap)
        _assert_trace_properties(rec, eng, reqs)


@pytest.mark.parametrize("mode", ["batched", "per_slot"])
def test_swap_requeues_thaw_without_second_admission(mode):
    # budget outlives the first step, so the swap freezes and re-queues
    # the request; swapping to the SAME variant thaws it back with zero
    # re-prefill — one first_token instant, one thaw, and a second slot
    # span, while the interrupted span closes with reason=swap_requeue
    rec, eng, reqs = _run_engine([(8, 6)], mode, swap=True)
    counts = request_token_counts(rec)
    assert counts[0]["admissions"] == 1
    assert eng.stats.thaws == 1
    reasons = [s.args.get("reason") for s in spans(rec, name="req.slot")]
    assert reasons.count("swap_requeue") == 1
    assert len(spans(rec, name="req.slot")) == 2


def test_stats_are_views_over_registry():
    rec, eng, _ = _run_engine([(8, 3)], "batched")
    m = eng.metrics
    assert eng.stats.steps == m.counter("engine.steps").value
    assert eng.stats.tokens_out == m.counter("engine.tokens_out").value
    assert eng.stats.prefills == m.counter("engine.prefills").value
    assert eng.step_time_ewma_s == m.ewma("engine.step_time_s").value
    assert m.histogram("engine.step_time_hist_s").count == eng.stats.steps


def test_ewma_gauge_bit_identical_to_legacy_fold():
    rng = np.random.default_rng(0)
    xs = rng.uniform(1e-4, 5e-2, size=200).tolist()
    g = EwmaGauge("t", alpha=0.2)
    legacy = None
    for x in xs:
        got = g.update(x)
        legacy = x if legacy is None else 0.8 * legacy + 0.2 * x
        assert got == legacy          # exact: same float ops, same order


def test_p2_histogram_tracks_numpy_percentiles():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=-6.0, sigma=0.8, size=4000)
    h = Histogram("t", quantiles=(0.5, 0.95, 0.99))
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.min == xs.min() and h.max == xs.max()
    for q in (0.5, 0.95):
        exact = float(np.percentile(xs, q * 100))
        assert abs(h.quantile(q) - exact) / exact < 0.15
    # exact below five samples (nearest-rank fallback)
    small = Histogram("s", quantiles=(0.5,))
    for x in (3.0, 1.0, 2.0):
        small.observe(x)
    assert small.quantile(0.5) == 2.0


def test_registry_name_means_one_thing():
    m = MetricsRegistry()
    c = m.counter("a.b")
    assert m.counter("a.b") is c
    with pytest.raises(TypeError):
        m.gauge("a.b")
    m.ewma("a.e").update(1.0)
    assert set(m.names()) == {"a.b", "a.e"}
    snap = m.snapshot()
    assert snap["a.b"] == 0 and snap["a.e"] == 1.0


def test_null_recorder_default_and_stream_equality():
    def streams(recorder):
        eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                            compile_cache=CC, recorder=recorder)
        reqs = [Request(rid=i, prompt=_prompt(9 + i, i), max_new_tokens=5)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.drain()
        return [tuple(r.generated) for r in reqs]

    default_eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                                compile_cache=CC)
    assert default_eng.recorder is NULL_RECORDER
    rec = TraceRecorder()
    assert streams(NULL_RECORDER) == streams(rec)
    assert len(rec.events) > 0


def test_exporter_closes_dangling_spans_and_picks_wall_clock():
    rec = TraceRecorder()
    rec.begin("outer", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.instant("tick", pid="p", tid="t", cat="engine", wall_s=2.0)
    doc = chrome_trace(rec)
    assert doc["otherData"]["clock"] == "wall"     # no sim clock anywhere
    ends = [e for e in doc["traceEvents"] if e["ph"] == "E"]
    assert len(ends) == 1 and ends[0]["args"]["open_at_export"]
    # synthetic end lands at the track's LAST ts, keeping it monotone
    assert ends[0]["ts"] == 2.0 * 1e6


def _fleet_run(tmp_path):
    cfg = CFG
    shape = InputShape("obs_t", 128, 2, "decode")
    fleet = build_fleet(5, seed=0)
    rec = TraceRecorder()
    ctl = FleetController(fleet, cfg, shape, trace_ticks=400,
                          warmup_ticks=2, placement=True, recorder=rec)
    engine_dev = next(d for d in fleet if d.tier == "light")
    eng = ctl.build_engine(engine_dev.device_id, PARAMS, cfg=cfg,
                           slots=2, max_seq=64, steps_per_tick=2)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=_prompt(6 + i, i),
                           max_new_tokens=8))
    ctl.run_for(4.0)
    dropped = next(d.device_id for d in fleet
                   if d.device_id != engine_dev.device_id)
    ctl.drop_device(dropped)
    ctl.run_for(4.0)
    eng.drain()                       # close in-flight request spans
    return rec, ctl, dropped


def test_fleet_trace_all_layers_one_sim_timebase(tmp_path):
    rec, ctl, dropped = _fleet_run(tmp_path)
    # every layer present, every event on the simulated clock
    cats = {e.cat for e in rec.events}
    assert cats == set(LAYERS)
    assert all(e.sim_s is not None for e in rec.events)
    # sim clock monotone per (pid, tid) track, spans well-nested
    last = {}
    for e in rec.events:
        key = (e.pid, e.tid)
        assert e.sim_s >= last.get(key, float("-inf"))
        last[key] = e.sim_s
    spans(rec)
    assert instants(rec, name="fleet.drop_device")
    assert spans(rec, name="placement.sweep")
    # the exported trace validates under the CI checker, all layers on
    doc = chrome_trace(rec)
    assert doc["otherData"]["clock"] == "sim"
    path = tmp_path / "fleet_trace.json"
    write_trace(rec, str(path))
    assert check_trace.check(path, require_layers=LAYERS) == 0
    # report totals are registry views that match the raw records
    rep = fleet_report(ctl)
    assert rep.total_violations == sum(1 for r in ctl.records if r.violated)
    assert rep.total_energy_j == pytest.approx(
        sum(r.observed_energy_j for r in ctl.records))
    assert ctl.wakes == len(ctl.records)
    # the placer left an audit trail and each decision also landed in
    # the trace as a placement.decide instant
    assert len(ctl.placer.audits) == len(
        instants(rec, name="placement.decide"))


# ------------------------------------------------------ exporter edges ----
def test_exporter_auto_clock_mixed_events_and_sim_raise():
    rec = TraceRecorder()
    rec.instant("a", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.sim_clock = lambda: 5.0          # later events carry a sim stamp
    rec.instant("b", pid="p", tid="t", cat="engine", wall_s=2.0)
    # mixed sim/wall: "auto" must fall back to the wall clock (one
    # timeline, one timebase — never a mix)
    doc = chrome_trace(rec)
    assert doc["otherData"]["clock"] == "wall"
    with pytest.raises(ValueError):
        chrome_trace(rec, clock="sim")
    # the event that does carry a sim stamp preserves it in args
    rows = [r for r in doc["traceEvents"] if r["ph"] == "i"]
    assert rows[1]["args"]["sim_s"] == 5.0
    assert "args" not in rows[0]


def test_open_at_export_and_orphan_ends_roundtrip_check_trace(tmp_path):
    rec = TraceRecorder()
    rec.begin("outer", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.begin("inner", pid="p", tid="t", cat="engine", wall_s=2.0)
    rec.instant("tick", pid="p", tid="t", cat="engine", wall_s=3.0)
    path = tmp_path / "dangling.json"
    write_trace(rec, str(path))
    assert check_trace.check(path) == 0      # synthetic ends validate
    doc = json.loads(path.read_text())
    ends = [e for e in doc["traceEvents"] if e["ph"] == "E"]
    assert len(ends) == 2
    assert all(e["args"]["open_at_export"] for e in ends)
    # inner closes before outer (reverse stack order), both at last ts
    assert [e["name"] for e in ends] == ["inner", "outer"]
    # an END whose BEGIN never existed is skipped and counted, so even
    # that malformed recorder exports a validating document
    rec2 = TraceRecorder()
    rec2.end("ghost", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec2.instant("tick", pid="p", tid="t", cat="engine", wall_s=2.0)
    doc2 = chrome_trace(rec2)
    assert doc2["otherData"]["orphaned_ends"] == 1
    assert not [e for e in doc2["traceEvents"] if e["ph"] == "E"]
    path2 = tmp_path / "orphan.json"
    path2.write_text(json.dumps(doc2))
    assert check_trace.check(path2) == 0


# -------------------------------------------------------- slo feedback ----
SHAPE = InputShape("obs_t", 128, 2, "decode")


def _slo_fleet(slo, cc, *, backlog_s=None, n_req=4, budget=6):
    """A placement-free fleet with one engine-backed light device.  With
    ``backlog_s`` the submitted requests claim to have arrived that far
    in the past — a deterministic load spike: their TTFTs are at least
    ``backlog_s`` regardless of machine speed."""
    fleet = build_fleet(5, seed=0)
    rec = TraceRecorder()
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=400,
                          warmup_ticks=2, recorder=rec, compile_cache=cc,
                          slo=slo)
    dev = next(d for d in fleet if d.tier == "light")
    eng = ctl.build_engine(dev.device_id, PARAMS, cfg=CFG, slots=2,
                           max_seq=64, steps_per_tick=2)
    reqs = [Request(rid=i, prompt=_prompt(6 + i, i), max_new_tokens=budget)
            for i in range(n_req)]
    if backlog_s is not None:
        now = time.perf_counter()
        for r in reqs:
            r.arrived_s = now - backlog_s
    for r in reqs:
        eng.submit(r)
    ctl.run_for(4.0)
    eng.drain()
    return [tuple(r.generated) for r in reqs], eng, ctl, rec, dev.device_id


def test_slo_spike_pages_and_downshifts_within_two_wakes():
    # TTFT target 1s against a 10s backlog: the very first window burns
    # at 1/(1-0.95) = 20x, far past the page threshold (min_count=2:
    # the two engine slots admit two backlogged requests on the first
    # wake, which is all the evidence this spike needs)
    slo = SLOTracker(SLOClass(name="interactive", ttft_p95_s=1.0),
                     window_s=30.0, min_count=2)
    _, eng, ctl, rec, pid = _slo_fleet(slo, CompileCache(), backlog_s=10.0)
    assert eng.slo is slo                 # controller shared its tracker
    pages = instants(rec, name="slo.page")
    assert len(pages) == 1 and pages[0].args["burn"] > 1.0
    assert slo.pressure > 1.0             # long window: never released
    assert ctl.metrics.counter("fleet.slo_pressure_events").value == 1
    t_page = pages[0].sim_s
    # every device's FIRST decision after the page is the latency-first
    # downshift — pressure propagated within one wake of paging
    decides = instants(rec, name="loop.decide")
    after = {}
    for e in decides:
        if e.sim_s > t_page:
            after.setdefault(e.pid, e)
    assert after, "no fleet wakes after the page"
    for pid_, first in after.items():
        assert first.args["reason"] == "slo_pressure", \
            f"{pid_} first post-page decision was {first.args['reason']}"
        assert first.args["pressure"] > 1.0
    # the downshift is real: under a nominal context the pressure-picked
    # action is no slower than the device's last healthy choice
    loop = ctl._devices[pid].loop
    healthy = [d for d in loop.decisions if d.reason != "slo_pressure"]
    pressed = [d for d in loop.decisions if d.reason == "slo_pressure"]
    assert healthy and pressed
    nominal = ResourceContext()

    def raw_latency(d):
        return loop.evaluator.evaluate(d.action, nominal,
                                       calibrate=False).latency_s

    assert raw_latency(pressed[-1]) <= raw_latency(healthy[-1])
    # the burn window and page both landed on the fault/SLO report
    from repro.faults import summarize_faults
    summ = summarize_faults(rec.events)
    assert summ["slo_pages"] == 1


def test_slo_healthy_run_bit_identical_to_untracked_and_no_recompiles():
    cc = CompileCache()
    warm, _, _, _, _ = _slo_fleet(None, cc)          # compile everything
    base, base_eng, _, base_rec, _ = _slo_fleet(None, cc)
    assert base == warm
    slo = SLOTracker(SLOClass(ttft_p95_s=1e3, tpot_p95_s=1e3))
    got, eng, ctl, rec, pid = _slo_fleet(slo, cc)
    # bit-identical token streams, and the warm cache stayed warm: the
    # feedback path compiled nothing and decided nothing differently
    assert got == base
    assert eng.stats.recompiles == 0 and base_eng.stats.recompiles == 0
    assert slo.pressure == 0.0
    assert not instants(rec, name="slo.page")
    assert not instants(rec, name="slo.burn")
    assert ctl.metrics.counter("fleet.slo_pressure_events").value == 0
    assert not any(d.reason == "slo_pressure"
                   for dd in ctl._devices.values()
                   for d in dd.loop.decisions)
    # the tracker did observe the healthy traffic (it wasn't bypassed);
    # the 4s horizon rotated several 1s windows, so count across the
    # closed-window history plus the live window
    ttft = sum(w["counts"]["ttft"] for w in slo.history)
    tpot = sum(w["counts"]["tpot"] for w in slo.history)
    if slo._live is not None:
        ttft += slo._live.counts["ttft"]
        tpot += slo._live.counts["tpot"]
    assert ttft >= 2 and tpot > 0
    assert all(w["burn"] == 0.0 for w in slo.history)
    # tracker state serializes with full histogram marker state
    state = slo.state()
    assert state["pressure"] == 0.0
    json.dumps(state)                      # fully serializable
