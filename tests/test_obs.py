"""Property suite for the observability layer: one timeline, no drift.

* **Span discipline** — every trace an engine emits is well-nested per
  ``(pid, tid)`` track and monotone on the wall clock, across both
  decode modes, random request mixes, and a mid-run ``swap_model``
  (which force-closes every in-flight slot span with a
  ``swap_requeue`` reason).  When ``hypothesis`` is installed the same
  property runs over generated mixes; otherwise a fixed-seed
  parametrization covers the same space.
* **Accounting** — one ``req.first_token`` instant per admission, one
  ``engine.prefill`` span per prefill jit call, and per-rid
  ``admissions + decode instants == len(generated)`` (so the trace and
  the token streams can never disagree about throughput).
* **TTFT bit-equality** — ``request_ttft_s`` equals the legacy
  ``first_token_s - arrived_s`` subtraction exactly, because the
  instants carry the very floats the engine stamps on the request.
* **Views, not copies** — ``ServeStats`` attributes and
  ``step_time_ewma_s`` read the metrics registry; :class:`EwmaGauge`
  reproduces the historical ``0.8*prev + 0.2*x`` fold bit-for-bit; P²
  histogram quantiles track ``np.percentile`` on a heavy-tailed stream.
* **Fleet timeline** — a placement-enabled fleet run with an
  engine-backed device and a mid-run ``drop_device`` produces events in
  all four layers, every one stamped on the simulated clock, monotone
  per track, exporting to a Chrome trace that ``tools/check_trace.py``
  accepts; report totals equal the records-derived sums.
* **Null path** — the default :data:`NULL_RECORDER` records nothing and
  token streams are bit-identical with tracing on and off.
"""
import importlib.util
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.fleet import FleetController, build_fleet, fleet_report
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.obs import (LAYERS, NULL_RECORDER, EwmaGauge, Histogram,
                       MetricsRegistry, TraceRecorder, chrome_trace,
                       instants, request_token_counts, request_ttft_s,
                       spans, write_trace)
from repro.serving import CompileCache, Request, ServingEngine

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
CC = CompileCache()          # shared: each program compiles exactly once

_ct_spec = importlib.util.spec_from_file_location(
    "check_trace",
    Path(__file__).resolve().parents[1] / "tools" / "check_trace.py")
check_trace = importlib.util.module_from_spec(_ct_spec)
_ct_spec.loader.exec_module(check_trace)


def _prompt(length, rid):
    rng = np.random.default_rng(101 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _run_engine(mix, mode, swap=False):
    """Run a request mix to completion under a TraceRecorder; optionally
    swap the model after the first step (re-queueing whatever is in
    flight).  Returns (recorder, engine, requests)."""
    rec = TraceRecorder()
    eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                        decode_mode=mode, compile_cache=CC,
                        recorder=rec, pid="dev0")
    reqs = [Request(rid=i, prompt=_prompt(n, i), max_new_tokens=budget)
            for i, (n, budget) in enumerate(mix)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    if swap:
        eng.swap_model(CFG, PARAMS, eng.opts)
    eng.drain()
    return rec, eng, reqs


def _assert_trace_properties(rec, eng, reqs):
    # well-nested per track: spans() raises on any mismatched edge
    all_spans = spans(rec)
    # wall clock monotone within each (pid, tid) track
    last = {}
    for e in rec.events:
        key = (e.pid, e.tid)
        assert e.wall_s >= last.get(key, float("-inf")), \
            f"wall clock went backwards on {key} at {e.name}"
        last[key] = e.wall_s
    # standalone engine: no sim clock anywhere
    assert all(e.sim_s is None for e in rec.events)
    # accounting: admissions match first-token instants, every slot
    # occupancy is either a prefill admission or a thaw re-admission
    # (swap_model requeues in-flight requests via freeze/thaw, which
    # opens a fresh req.slot span without a new first token), prefill
    # spans match prefill jit calls, decodes complete the streams
    counts = request_token_counts(rec)
    admissions = sum(d["admissions"] for d in counts.values())
    decodes = sum(d["decodes"] for d in counts.values())
    assert admissions == eng.stats.prefills
    assert len(spans(rec, name="req.slot")) == admissions + eng.stats.thaws
    assert len(spans(rec, name="engine.prefill")) == eng.stats.prefill_calls
    assert admissions + decodes == eng.stats.tokens_out
    for r in reqs:
        # a swap freezes and re-queues the SAME object; its stream is
        # complete only once it finished (the aggregate tokens_out
        # check above covers anything still in flight)
        if not r.done or not r.generated:
            continue
        d = counts[r.rid]
        assert d["admissions"] + d["decodes"] == len(r.generated)
    # TTFT from spans == legacy subtraction, bit for bit
    span_ttft = request_ttft_s(rec)
    for r in reqs:
        if r.first_token_s is None:
            assert r.rid not in span_ttft
        else:
            assert span_ttft[r.rid] == r.first_token_s - r.arrived_s
    return all_spans


FIXED_MIXES = [
    [(8, 3), (24, 5)],
    [(1, 1)],
    [(40, 2), (3, 6), (17, 4)],
    [(12, 4), (12, 4), (12, 4)],         # same bucket: a burst
]


@pytest.mark.parametrize("mode", ["batched", "per_slot"])
@pytest.mark.parametrize("swap", [False, True])
@pytest.mark.parametrize("mix", FIXED_MIXES,
                         ids=[f"mix{i}" for i in range(len(FIXED_MIXES))])
def test_trace_properties_fixed(mode, swap, mix):
    rec, eng, reqs = _run_engine(mix, mode, swap=swap)
    _assert_trace_properties(rec, eng, reqs)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(mix=st.lists(st.tuples(st.integers(1, 40), st.integers(1, 6)),
                        min_size=1, max_size=5),
           mode=st.sampled_from(["batched", "per_slot"]),
           swap=st.booleans())
    def test_trace_properties_hypothesis(mix, mode, swap):
        rec, eng, reqs = _run_engine(mix, mode, swap=swap)
        _assert_trace_properties(rec, eng, reqs)


@pytest.mark.parametrize("mode", ["batched", "per_slot"])
def test_swap_requeues_thaw_without_second_admission(mode):
    # budget outlives the first step, so the swap freezes and re-queues
    # the request; swapping to the SAME variant thaws it back with zero
    # re-prefill — one first_token instant, one thaw, and a second slot
    # span, while the interrupted span closes with reason=swap_requeue
    rec, eng, reqs = _run_engine([(8, 6)], mode, swap=True)
    counts = request_token_counts(rec)
    assert counts[0]["admissions"] == 1
    assert eng.stats.thaws == 1
    reasons = [s.args.get("reason") for s in spans(rec, name="req.slot")]
    assert reasons.count("swap_requeue") == 1
    assert len(spans(rec, name="req.slot")) == 2


def test_stats_are_views_over_registry():
    rec, eng, _ = _run_engine([(8, 3)], "batched")
    m = eng.metrics
    assert eng.stats.steps == m.counter("engine.steps").value
    assert eng.stats.tokens_out == m.counter("engine.tokens_out").value
    assert eng.stats.prefills == m.counter("engine.prefills").value
    assert eng.step_time_ewma_s == m.ewma("engine.step_time_s").value
    assert m.histogram("engine.step_time_hist_s").count == eng.stats.steps


def test_ewma_gauge_bit_identical_to_legacy_fold():
    rng = np.random.default_rng(0)
    xs = rng.uniform(1e-4, 5e-2, size=200).tolist()
    g = EwmaGauge("t", alpha=0.2)
    legacy = None
    for x in xs:
        got = g.update(x)
        legacy = x if legacy is None else 0.8 * legacy + 0.2 * x
        assert got == legacy          # exact: same float ops, same order


def test_p2_histogram_tracks_numpy_percentiles():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=-6.0, sigma=0.8, size=4000)
    h = Histogram("t", quantiles=(0.5, 0.95, 0.99))
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.min == xs.min() and h.max == xs.max()
    for q in (0.5, 0.95):
        exact = float(np.percentile(xs, q * 100))
        assert abs(h.quantile(q) - exact) / exact < 0.15
    # exact below five samples (nearest-rank fallback)
    small = Histogram("s", quantiles=(0.5,))
    for x in (3.0, 1.0, 2.0):
        small.observe(x)
    assert small.quantile(0.5) == 2.0


def test_registry_name_means_one_thing():
    m = MetricsRegistry()
    c = m.counter("a.b")
    assert m.counter("a.b") is c
    with pytest.raises(TypeError):
        m.gauge("a.b")
    m.ewma("a.e").update(1.0)
    assert set(m.names()) == {"a.b", "a.e"}
    snap = m.snapshot()
    assert snap["a.b"] == 0 and snap["a.e"] == 1.0


def test_null_recorder_default_and_stream_equality():
    def streams(recorder):
        eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                            compile_cache=CC, recorder=recorder)
        reqs = [Request(rid=i, prompt=_prompt(9 + i, i), max_new_tokens=5)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.drain()
        return [tuple(r.generated) for r in reqs]

    default_eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                                compile_cache=CC)
    assert default_eng.recorder is NULL_RECORDER
    rec = TraceRecorder()
    assert streams(NULL_RECORDER) == streams(rec)
    assert len(rec.events) > 0


def test_exporter_closes_dangling_spans_and_picks_wall_clock():
    rec = TraceRecorder()
    rec.begin("outer", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.instant("tick", pid="p", tid="t", cat="engine", wall_s=2.0)
    doc = chrome_trace(rec)
    assert doc["otherData"]["clock"] == "wall"     # no sim clock anywhere
    ends = [e for e in doc["traceEvents"] if e["ph"] == "E"]
    assert len(ends) == 1 and ends[0]["args"]["open_at_export"]
    # synthetic end lands at the track's LAST ts, keeping it monotone
    assert ends[0]["ts"] == 2.0 * 1e6


def _fleet_run(tmp_path):
    cfg = CFG
    shape = InputShape("obs_t", 128, 2, "decode")
    fleet = build_fleet(5, seed=0)
    rec = TraceRecorder()
    ctl = FleetController(fleet, cfg, shape, trace_ticks=400,
                          warmup_ticks=2, placement=True, recorder=rec)
    engine_dev = next(d for d in fleet if d.tier == "light")
    eng = ctl.build_engine(engine_dev.device_id, PARAMS, cfg=cfg,
                           slots=2, max_seq=64, steps_per_tick=2)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=_prompt(6 + i, i),
                           max_new_tokens=8))
    ctl.run_for(4.0)
    dropped = next(d.device_id for d in fleet
                   if d.device_id != engine_dev.device_id)
    ctl.drop_device(dropped)
    ctl.run_for(4.0)
    eng.drain()                       # close in-flight request spans
    return rec, ctl, dropped


def test_fleet_trace_all_layers_one_sim_timebase(tmp_path):
    rec, ctl, dropped = _fleet_run(tmp_path)
    # every layer present, every event on the simulated clock
    cats = {e.cat for e in rec.events}
    assert cats == set(LAYERS)
    assert all(e.sim_s is not None for e in rec.events)
    # sim clock monotone per (pid, tid) track, spans well-nested
    last = {}
    for e in rec.events:
        key = (e.pid, e.tid)
        assert e.sim_s >= last.get(key, float("-inf"))
        last[key] = e.sim_s
    spans(rec)
    assert instants(rec, name="fleet.drop_device")
    assert spans(rec, name="placement.sweep")
    # the exported trace validates under the CI checker, all layers on
    doc = chrome_trace(rec)
    assert doc["otherData"]["clock"] == "sim"
    path = tmp_path / "fleet_trace.json"
    write_trace(rec, str(path))
    assert check_trace.check(path, require_layers=LAYERS) == 0
    # report totals are registry views that match the raw records
    rep = fleet_report(ctl)
    assert rep.total_violations == sum(1 for r in ctl.records if r.violated)
    assert rep.total_energy_j == pytest.approx(
        sum(r.observed_energy_j for r in ctl.records))
    assert ctl.wakes == len(ctl.records)
    # the placer left an audit trail and each decision also landed in
    # the trace as a placement.decide instant
    assert len(ctl.placer.audits) == len(
        instants(rec, name="placement.decide"))
