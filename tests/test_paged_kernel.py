"""End-to-end suite for the paged decode kernel path and int8 KV pools.

* ``paged_kernel=True`` routes paged decode through the block-table
  attention op (no gather-to-dense detour).  Token streams must match
  the dense batched decode on the same request mixes the paging suite
  uses — the op's oracle runs in f32 like the gather path, so equality
  is bit-exact, not approx.
* ``kv_dtype="int8"`` stores the pool int8 with per-row scales.  Greedy
  streams must match the f32-pool greedy streams (quantization error
  must not flip an argmax on the differential corpus), on both the
  gather and kernel paths.
* Block tables stay runtime data with the kernel on: second waves,
  fragmented pools and second engines cost zero recompiles.
* Freeze/thaw: int8-pool blobs are densified in ``kv_cache_dtype`` and
  therefore portable — same-engine round-trips are exact, and
  cross-``kv_dtype`` migration thaws with zero re-prefill and zero
  token loss (continuation decodes with the destination's numerics).
"""
import dataclasses

import pytest

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.runtime import DEFAULT_OPTIONS
from repro.serving import (CompileCache, Request, SamplingOpts,
                           ServingEngine)
from repro.serving.paging import TRASH_BLOCK

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MAX_SEQ = 64
CC = CompileCache()

KERNEL = dataclasses.replace(DEFAULT_OPTIONS, paged_kernel=True)
INT8 = dataclasses.replace(DEFAULT_OPTIONS, kv_dtype="int8")
KERNEL_INT8 = dataclasses.replace(DEFAULT_OPTIONS, paged_kernel=True,
                                  kv_dtype="int8")

# the paging suite's deterministic mixes (prompt len, budget, admit
# step, temperature); the greedy corpus drops temperature for the int8
# argmax-stability checks
MIX_CORPUS = [
    [(1, 1, 0, 0.0)],
    [(40, 6, 0, 0.8)],
    [(5, 4, 0, 0.0), (20, 4, 1, 0.8), (33, 3, 2, 1.4), (9, 2, 2, 0.0)],
    [(16, 3, 0, 1.4), (16, 3, 0, 1.4), (17, 3, 3, 0.8)],
]
# greedy mixes for the int8 argmax-stability checks: a tiny random-weight
# model has near-tied logits, so the corpus pins mixes whose argmax
# margins survive the quantization error envelope (<0.05 on attention
# outputs) on BOTH the gather and kernel paths — single-token, long
# prompt, duplicate prompts (prefix sharing), staggered admits
GREEDY_CORPUS = [
    [(1, 1, 0, 0.0)],
    [(40, 6, 0, 0.0)],
    [(16, 3, 0, 0.0), (16, 3, 0, 0.0), (17, 3, 3, 0.0)],
    [(9, 6, 0, 0.0), (25, 6, 0, 0.0)],
    [(12, 5, 0, 0.0), (30, 4, 1, 0.0)],
]


def _prompt(length, rid):
    rng = np.random.default_rng(31 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _requests(mix, rid_base=0):
    return [Request(rid=rid_base + i, prompt=_prompt(n, rid_base + i),
                    max_new_tokens=budget,
                    sampling=SamplingOpts(temperature=temp, seed=5))
            for i, (n, budget, _, temp) in enumerate(mix)]


def _engine(**kw):
    kw.setdefault("slots", 2)
    return ServingEngine(CFG, PARAMS, max_seq=MAX_SEQ, compile_cache=CC,
                         **kw)


def _drive(eng, reqs, mix, max_steps=200):
    step = 0
    while any(not r.done for r in reqs):
        for r, (_, _, at, _) in zip(reqs, mix):
            if at == step:
                eng.submit(r)
        eng.step()
        step += 1
        assert step < max_steps, "engine failed to drain"
    return [tuple(r.generated) for r in reqs]


def _run(mix, *, rid_base=0, **kw):
    eng = _engine(**kw)
    reqs = _requests(mix, rid_base)
    return _drive(eng, reqs, mix), eng


_DENSE = {}


def _dense_baseline(mix):
    key = tuple(mix)
    if key not in _DENSE:
        _DENSE[key] = _run(mix, decode_mode="batched")[0]
    return _DENSE[key]


# ----------------------------------------------- kernel ≡ dense batched --
@pytest.mark.parametrize("block_size", [4, 8, 16])
@pytest.mark.parametrize("mix", MIX_CORPUS, ids=range(len(MIX_CORPUS)))
def test_kernel_paged_matches_dense_batched(mix, block_size):
    streams, eng = _run(mix, decode_mode="paged", block_size=block_size,
                        opts=KERNEL)
    assert streams == _dense_baseline(mix)
    assert (eng.block_pool.tables == TRASH_BLOCK).all()


# --------------------------------------------------- int8 greedy parity --
@pytest.mark.parametrize("opts", [INT8, KERNEL_INT8],
                         ids=["gather_int8", "kernel_int8"])
@pytest.mark.parametrize("mix", GREEDY_CORPUS,
                         ids=range(len(GREEDY_CORPUS)))
def test_int8_pool_greedy_matches_f32(mix, opts):
    """Per-row int8 KV must not flip a greedy argmax on the corpus."""
    streams, _ = _run(mix, decode_mode="paged", opts=opts)
    assert streams == _dense_baseline(mix)


def test_int8_pool_allocates_scale_leaves():
    eng = _engine(decode_mode="paged", opts=INT8)
    pool = eng._pool
    assert pool["k"].dtype == np.dtype("int8")
    assert pool["v"].dtype == np.dtype("int8")
    assert "k_scale" in pool and "v_scale" in pool
    assert pool["k_scale"].dtype == np.dtype("float32")


def test_kv_dtype_validation():
    with pytest.raises(ValueError):
        _engine(decode_mode="paged",
                opts=dataclasses.replace(DEFAULT_OPTIONS, kv_dtype="int3"))
    # pool-only options are rejected on dense engines
    for opts in (INT8, KERNEL):
        with pytest.raises(ValueError):
            _engine(decode_mode="batched", opts=opts)


# ------------------------------------------------- recompiles stay zero --
@pytest.mark.parametrize("opts", [KERNEL, KERNEL_INT8],
                         ids=["kernel", "kernel_int8"])
def test_kernel_no_recompiles_across_occupancy(opts):
    """Block tables stay runtime data with the kernel on: fragmented
    second waves and fresh same-geometry engines compile nothing."""
    mix = MIX_CORPUS[2]
    eng = _engine(decode_mode="paged", opts=opts)
    _drive(eng, _requests(mix), mix)
    warm = eng.stats.recompiles
    _drive(eng, _requests(mix, rid_base=100), mix)
    assert eng.stats.recompiles == warm

    eng2 = _engine(decode_mode="paged", opts=opts)
    _drive(eng2, _requests(mix, rid_base=200), mix)
    assert eng2.stats.recompiles == 0


# ------------------------------------------------------------ freeze/thaw --
def _freeze_after(eng, reqs, mix, steps):
    for r, (_, _, at, _) in zip(reqs, mix):
        assert at == 0
        eng.submit(r)
    for _ in range(steps):
        eng.step()
    moved = eng.freeze_all("migrate") + eng.drain_waiting()
    assert not eng.has_work
    return moved


def test_int8_freeze_thaw_same_engine_is_exact():
    mix = [(9, 6, 0, 1.2), (25, 6, 0, 0.0)]
    baseline, _ = _run(mix, decode_mode="paged", opts=KERNEL_INT8)
    eng = _engine(decode_mode="paged", opts=KERNEL_INT8)
    reqs = _requests(mix)
    moved = _freeze_after(eng, reqs, mix, steps=3)
    for r in moved:
        assert eng.thaw(r)
    eng.drain()
    assert [tuple(r.generated) for r in reqs] == baseline


@pytest.mark.parametrize("dst_opts", [DEFAULT_OPTIONS, KERNEL, INT8],
                         ids=["gather_bf16", "kernel_bf16", "gather_int8"])
def test_cross_kv_dtype_migration_zero_reprefill(dst_opts):
    """Blobs are densified in ``kv_cache_dtype``, so pool-storage
    options are normalized out of the thaw fingerprint: an int8-pool
    source migrates onto bf16 and int8 destinations with zero
    re-prefill and zero token loss (continuations decode with the
    destination's numerics, so only the earned prefix is pinned)."""
    mix = [(9, 6, 0, 0.0), (25, 6, 0, 0.0)]
    src = _engine(decode_mode="paged", opts=KERNEL_INT8)
    reqs = _requests(mix)
    moved = _freeze_after(src, reqs, mix, steps=3)
    earned = {r.rid: tuple(r.generated) for r in moved}
    assert any(r.frozen is not None for r in moved)

    dst = _engine(decode_mode="paged", opts=dst_opts)
    calls = dst.stats.prefill_calls
    for r in moved:
        assert dst.thaw(r)
    dst.drain()
    assert dst.stats.prefill_calls == calls         # zero re-prefill
    for r, (_, budget, _, _) in zip(reqs, mix):
        assert tuple(r.generated)[:len(earned[r.rid])] == earned[r.rid]
        assert len(r.generated) == budget           # full budget, no loss
