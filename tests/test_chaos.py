"""Chaos suite: the fleet must survive what the injector throws at it.

Invariants pinned here, per ISSUE 7:

* **zero token loss/duplication** — OOMed admissions and failover
  requeues resume every rid's stream exactly where it stopped (the
  deterministic ``(seed, rid, consumed)`` sampling contract makes this
  a bit-equality assertion, not a statistical one);
* **bounded detection** — every silent fault (crash, long freeze) is
  suspected/evicted within a bounded number of the victim's own wake
  periods;
* **graceful degradation** — a requester whose offload chain loses a
  hop keeps producing records via its local elastic variants, never
  stalls;
* **quarantine hysteresis** — a flapping helper is readmitted but not
  *selected* until its quarantine expires; recovery placements pass the
  normal hysteresis gate (they go through ``FleetPlacer.place``);
* **live migration** (ISSUE 8) — an evicted engine-backed member's
  in-flight requests freeze and thaw on a same-domain peer with zero
  token loss and zero re-prefill, bit-identical to the unfaulted run;
  without a peer they requeue locally and nothing is lost;
* **observability** — every fault/detection/recovery run exports a
  trace that still validates under ``tools/check_trace.py``;
* **fault-free bit-identity** — the detector enabled on a healthy
  fleet changes nothing.

Randomized schedules are hypothesis-drawn when hypothesis is installed;
otherwise (and always in CI's quick job) fixed seeds from
``CHAOS_SEEDS`` cover the same code path deterministically.
"""
import importlib.util
import os
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.monitor import ResourceContext, constant_trace
from repro.faults import (CRASH, FREEZE, SILENT_KINDS, ChainOutcome,
                          DetectorConfig, FaultInjector, FaultSpec,
                          HeartbeatDetector, RetryPolicy, TelemetryFault,
                          execute_chain, random_schedule,
                          summarize_faults)
from repro.fleet import FleetController, make_device
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.obs import (LAYERS, TraceRecorder, attribute_fleet,
                       attribute_requests, write_trace)
from repro.serving import CompileCache, Request, ServingEngine

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = get_config("paper-backbone")
SHAPE = InputShape("chaos_t", 256, 4, "prefill")
LOADED = ResourceContext(cpu_temp_derate=0.45, competing_procs=4)

TINY = CFG.with_updates(num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=2, head_dim=16, d_ff=128,
                        vocab_size=300)
PARAMS = init_params(TINY, jax.random.PRNGKey(0))
CC = CompileCache()

# CI runs the suite under two fixed seeds; locally override with e.g.
# CHAOS_SEEDS=0,1,2,3 for a wider sweep
CHAOS_SEEDS = tuple(int(s) for s in
                    os.environ.get("CHAOS_SEEDS", "7,23").split(","))

_ct_spec = importlib.util.spec_from_file_location(
    "check_trace",
    Path(__file__).resolve().parents[1] / "tools" / "check_trace.py")
check_trace = importlib.util.module_from_spec(_ct_spec)
_ct_spec.loader.exec_module(check_trace)


def _fleet():
    """Loaded phone + two same-site helpers + a WAN server — the
    placement acceptance scenario, now under fire."""
    return [make_device("pixel_6_cpu", 0, site="home"),
            make_device("jetson_agx_orin", 0, site="home"),
            make_device("jetson_agx_orin", 1, site="home"),
            make_device("edge_server_a100", 0, site="dc")]


def _trace_factory(phone_id):
    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone_id else ResourceContext(), n)
    return tf


def _controller(fleet, *, recorder=None, placement=True, detection=True,
                detector_config=None, seed=0):
    kw = {} if recorder is None else {"recorder": recorder}
    ctl = FleetController(
        list(fleet), CFG, SHAPE, trace_ticks=4000,
        trace_factory=_trace_factory(fleet[0].device_id),
        placement=placement, allow_offload=False, detection=detection,
        detector_config=detector_config, warmup_ticks=4,
        recalibrate_every=2, seed=seed, **kw)
    ctl.set_sla(fleet[0].device_id, 0.5)
    return ctl


def _placed_helper(ctl, phone, warm_s=8.0):
    ctl.run_for(warm_s)
    dec = ctl.placement_of(phone)
    assert dec is not None and dec.offloaded, dec
    return dec.hosts[1]


# ---------------------------------------------------------------- units ----
def test_detector_state_machine_and_flap_quarantine():
    cfg = DetectorConfig(suspect_after=2.0, dead_after=4.0,
                         quarantine_periods=4.0, flap_backoff_cap=4.0)
    det = HeartbeatDetector(cfg)
    det.track("d", period_s=1.0, now_s=0.0)
    assert det.sweep(1.5) == []                  # within grace
    [sus] = det.sweep(2.5)
    assert sus.state == "suspect" and det.state("d") == "suspect"
    edges = det.sweep(4.5)
    assert [e.state for e in edges] == ["dead"]
    # heartbeat returns it to life: flap #1, quarantined 4 periods
    rec = det.beat("d", 5.0)
    assert rec.state == "recovered" and rec.was == "dead"
    assert det.flaps("d") == 1
    assert det.quarantined_until("d") == pytest.approx(9.0)
    assert det.quarantined("d", 8.0) and not det.quarantined("d", 9.5)
    # a long-silent device takes both edges in ONE sweep
    det2 = HeartbeatDetector(cfg)
    det2.track("e", period_s=1.0, now_s=0.0)
    assert [e.state for e in det2.sweep(10.0)] == ["suspect", "dead"]
    # second flap doubles the quarantine (2^(flaps-1), capped)
    det.sweep(5.0 + 3.0)
    det.sweep(5.0 + 5.0)
    rec2 = det.beat("d", 12.0)
    assert rec2.flaps == 2
    assert rec2.quarantined_until_s == pytest.approx(12.0 + 8.0)


def test_untracked_devices_never_alarm():
    det = HeartbeatDetector()
    det.track("d", period_s=1.0)
    det.untrack("d")
    assert det.sweep(100.0) == []
    assert det.beat("d", 100.0) is None


def test_retry_policy_bounded_backoff_and_chain_outcomes():
    p = RetryPolicy(max_retries=2, base_backoff_s=0.1, backoff_factor=2.0,
                    max_backoff_s=0.15, timeout_scale=3.0,
                    min_timeout_s=0.05)
    assert p.backoff_s(0) == pytest.approx(0.1)
    assert p.backoff_s(1) == pytest.approx(0.15)      # capped
    assert p.timeout_s(0.001) == pytest.approx(0.05)  # floored
    ok = execute_chain(("a", "b", "c"), 0.1, lambda h: True, p)
    assert ok == ChainOutcome(True, 2, 0, 0.0)
    bad = execute_chain(("a", "b", "c"), 0.1, lambda h: h != "c", p)
    assert not bad.ok and bad.failed_hop == "c"
    assert bad.attempts == 1 + 3                      # b once, c exhausted
    assert bad.penalty_s == pytest.approx(p.worst_case_s(0.1))
    assert bad.penalty_s < float("inf")
    # a host revived between retries is observed
    calls = {"n": 0}

    def flaky(h):
        calls["n"] += 1
        return calls["n"] > 2
    again = execute_chain(("a", "b"), 0.1, flaky, p)
    assert again.ok and again.retries == 2 and again.penalty_s > 0


def test_fault_spec_validates_kind_and_schedule_is_deterministic():
    with pytest.raises(ValueError):
        FaultSpec("meteor_strike", "d", 1.0)
    fleet = _fleet()
    s1 = random_schedule(fleet, 20.0, seed=3)
    s2 = random_schedule(fleet, 20.0, seed=3)
    s3 = random_schedule(fleet, 20.0, seed=4)
    assert s1 == s2 and s1 != s3
    protected = random_schedule(fleet, 20.0, seed=3,
                                protect=[fleet[0].device_id])
    assert all(f.target != fleet[0].device_id for f in protected)


# --------------------------------------------------- detection + eviction --
def test_crash_detected_and_evicted_within_bounded_wake_periods():
    fleet = _fleet()
    phone = fleet[0].device_id
    dcfg = DetectorConfig(suspect_after=2.5, dead_after=5.0)
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec, detector_config=dcfg)
    helper = _placed_helper(ctl, phone)
    t0 = ctl.now_s
    ctl.fail_device(helper, mode="crash")
    ctl.run_for(20.0)
    assert ctl.detector.state(helper) == "dead"
    assert helper not in ctl.placer.members
    # detection bound: dead_after × the victim's period ceiling, plus a
    # pre-fault beat up to one period old, plus one sweep interval
    env = next(d for d in fleet if d.device_id == helper).tick_envelope
    bound = (dcfg.dead_after + 1.0) * env.max_s + ctl._detect_period_s
    dead = [e for e in rec.events if e.name == "detector.dead"
            and e.args["device"] == helper]
    assert dead and dead[0].sim_s - t0 <= bound
    # the requester was re-placed (or fell back local) — and kept waking
    after = ctl.placement_of(phone)
    assert helper not in after.hosts
    summ = summarize_faults(rec.events)
    assert summ["mean_mttd_s"] is None       # fail_device ≠ fault.inject


def test_short_freeze_suspects_then_recovers_without_eviction():
    fleet = _fleet()
    phone = fleet[0].device_id
    dcfg = DetectorConfig(suspect_after=2.0, dead_after=40.0)
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec, detector_config=dcfg)
    helper = _placed_helper(ctl, phone)
    FaultInjector(ctl, [FaultSpec(FREEZE, helper, at_s=ctl.now_s + 0.5,
                                  duration_s=3.0)]).arm()
    ctl.run_for(10.0)
    # suspected while silent, never dead, never evicted
    assert any(e.name == "detector.suspect" and e.args["device"] == helper
               for e in rec.events)
    assert not any(e.name == "detector.dead" for e in rec.events)
    assert helper in ctl.placer.members
    assert ctl.detector.state(helper) == "alive"
    assert ctl.detector.flaps(helper) == 1
    assert ctl.placer.member(helper).quarantined_until_s > ctl.now_s - 10.0


def test_long_freeze_evicts_then_readmits_under_quarantine():
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    # long quarantine so probation is still in force when we assert
    ctl = _controller(fleet, recorder=rec,
                      detector_config=DetectorConfig(
                          quarantine_periods=60.0))
    helper = _placed_helper(ctl, phone)
    freeze_at = ctl.now_s + 1.0
    FaultInjector(ctl, [FaultSpec(FREEZE, helper, at_s=freeze_at,
                                  duration_s=6.0)]).arm()
    ctl.run_for(9.0)
    # evicted while frozen; the phone moved off it
    assert any(e.name == "fleet.evict" and e.args["device"] == helper
               and e.args["cause"] == "detected" for e in rec.events)
    moved = ctl.placement_of(phone)
    assert helper not in moved.hosts
    ctl.run_for(2.0)
    # thawed: readmitted to membership but on probation
    assert helper in ctl.placer.members
    q_until = ctl.placer.member(helper).quarantined_until_s
    assert q_until > ctl.now_s
    assert helper not in ctl.placer.candidate_helpers(phone,
                                                      now_s=ctl.now_s)
    assert ctl.metrics.counter("fleet.readmissions").value == 1
    # after the quarantine expires it is offerable again
    assert helper in ctl.placer.candidate_helpers(phone,
                                                  now_s=q_until + 1.0)
    # recovery placements went through place(): the decision log's HOLD/
    # PLACED reasons prove the hysteresis gate stayed in the path
    assert all(a.reason in ("local", "placed", "hold", "fallback",
                            "infeasible") for a in ctl.placer.audits)


def test_chain_loss_degrades_to_local_and_keeps_producing():
    # detection OFF: the requester's only defense is the per-wake chain
    # guard — retry/backoff penalty once, then local re-decision
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec, detection=False)
    helper = _placed_helper(ctl, phone)
    ticks_before = ctl.tick_counts[phone]
    ctl.fail_device(helper, mode="crash")
    ctl.run_for(6.0)
    assert ctl.tick_counts[phone] > ticks_before      # never stalled
    retries = [e for e in rec.events if e.name == "recovery.retry"
               and e.pid == phone]
    assert retries and retries[0].args["failed_hop"] == helper
    assert retries[0].args["penalty_s"] > 0
    # the degraded wakes decided locally (no fleet peers in the action)
    t_fail = retries[0].sim_s
    late = [r for r in ctl.records if r.device_id == phone
            and r.timestamp_s >= t_fail]
    assert late and all(not r.decision.action.offload.peers
                        or helper not in r.decision.action.offload.peers
                        for r in late)
    # the penalty landed in observed latency, not a side channel
    assert max(r.observed_s for r in late) > ctl.retry_policy.min_timeout_s


def test_straggler_cap_slows_device_and_triggers_replacement():
    fleet = _fleet()
    phone = fleet[0].device_id
    ctl = _controller(fleet)
    helper = _placed_helper(ctl, phone)
    before = ctl.tick_counts[helper]
    span = 6.0
    ctl.set_derate_cap(helper, 0.15)
    ctl.run_for(span)
    slowed_rate = (ctl.tick_counts[helper] - before) / span
    env = next(d for d in fleet if d.device_id == helper).tick_envelope
    # DVFS collapse pins the period at the envelope ceiling
    assert slowed_rate == pytest.approx(1.0 / env.max_s, rel=0.35)
    after = ctl.placement_of(phone)
    assert helper not in after.hosts                 # fleet routed around


def test_telemetry_faults_drop_delay_corrupt_without_breaking_loop():
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec)
    helper = _placed_helper(ctl, phone)
    ctl.set_telemetry_fault(helper, TelemetryFault(loss_p=0.9,
                                                   corrupt_scale=5.0))
    ctl.run_for(8.0)
    dropped = ctl.metrics.counter("fleet.telemetry_dropped").value
    assert dropped > 0
    assert any(e.name == "telemetry.lost" for e in rec.events)
    # the fleet keeps running and calibrations stay finite
    assert ctl.tick_counts[phone] > 0
    cal = ctl.calibration_of(phone)
    assert cal is None or np.isfinite(cal.latency_scale)
    ctl.set_telemetry_fault(helper, None)
    ctl.run_for(2.0)
    assert ctl.metrics.counter("fleet.telemetry_dropped").value == dropped


# ------------------------------------------------------ engine: zero loss --
def _streams(engine_requests):
    return {r.rid: tuple(r.generated) for r in engine_requests}


def _mk_engine(**kw):
    return ServingEngine(TINY, PARAMS, slots=2, max_seq=64,
                         compile_cache=CC, **kw)


def _submit_mix(eng):
    reqs = []
    for i in range(4):
        rng = np.random.default_rng(31 * i + 5)
        r = Request(rid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        size=5 + i).astype(np.int32),
                    max_new_tokens=6)
        reqs.append(r)
        eng.submit(r)
    return reqs


def _baseline_streams():
    eng = _mk_engine()
    reqs = _submit_mix(eng)
    eng.drain()
    return _streams(reqs)


def test_oom_injection_zero_token_loss_and_backoff():
    want = _baseline_streams()
    eng = _mk_engine()
    reqs = _submit_mix(eng)
    eng.step()
    eng.inject_oom(2)
    eng.drain()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == want                 # bit-identical streams
    assert eng.stats.oom_events == 2
    # backoff resets once an admission finally succeeds
    assert eng._oom_backoff == 0 and eng._oom_pending == 0
    # growth probe: consecutive OOMs double the admission holdoff
    eng2 = _mk_engine()
    _submit_mix(eng2)
    eng2.inject_oom(3)
    holdoffs = []
    while eng2._oom_pending:
        eng2._admit()
        holdoffs.append(eng2._admit_holdoff)
        eng2._admit_holdoff = 0                   # fast-forward the wait
    assert holdoffs == [1, 2, 4]


def test_requeue_active_preserves_streams_and_counts():
    want = _baseline_streams()
    eng = _mk_engine()
    reqs = _submit_mix(eng)
    eng.step()                                    # some rids in flight
    n = eng.requeue_active(reason="failover")
    assert n == 2 and eng.stats.requeues == 2
    assert all(s is None for s in eng._active)
    # the requeue replaces in-flight Requests (the swap-requeue
    # contract) — the continuations live in the queue now, carrying the
    # already-generated prefix forward
    final = {r.rid: r for r in reqs}
    pre = {r.rid: tuple(r.generated) for r in reqs}
    final.update({r.rid: r for r in eng._queue})
    eng.drain()
    for rid, r in final.items():
        assert r.done
        assert tuple(r.generated)[:len(pre[rid])] == pre[rid]  # no replay
        assert len(r.generated) == len(want[rid])   # no loss, no dupes
    total = sum(len(r.generated) for r in final.values())
    assert eng.stats.tokens_out == total          # each token counted once


# ------------------------------------------------ freeze/thaw migration --
def _submit_long_mix(eng, budget=30):
    """The chaos mix with budgets long enough that nothing finishes
    before a mid-run fault lands."""
    reqs = []
    for i in range(4):
        rng = np.random.default_rng(31 * i + 5)
        r = Request(rid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        size=5 + i).astype(np.int32),
                    max_new_tokens=budget)
        reqs.append(r)
        eng.submit(r)
    return reqs


def _long_baseline(budget=30, slots=2):
    eng = ServingEngine(TINY, PARAMS, slots=slots, max_seq=64,
                        compile_cache=CC)
    reqs = _submit_long_mix(eng, budget)
    eng.drain()
    return _streams(reqs)


def test_injected_crash_migrates_in_flight_requests_exactly(tmp_path):
    """CRASH on an engine-backed helper: the detector evicts it, the
    controller freezes its in-flight requests (paged source) and thaws
    them on the same-domain peer (dense destination) — exact unfaulted
    streams, zero token loss, zero re-prefill, all audited from the
    same trace the rest of the stack exports."""
    want = _long_baseline()
    fleet = _fleet()
    src_id, dst_id = fleet[1].device_id, fleet[2].device_id
    rec = TraceRecorder()
    dcfg = DetectorConfig(suspect_after=2.5, dead_after=5.0)
    ctl = _controller(fleet, recorder=rec, detector_config=dcfg)
    src = ctl.build_engine(src_id, PARAMS, cfg=TINY, slots=2, max_seq=64,
                           decode_mode="paged", steps_per_tick=1)
    dst = ctl.build_engine(dst_id, PARAMS, cfg=TINY, slots=2, max_seq=64,
                           steps_per_tick=4)
    reqs = _submit_long_mix(src)
    src.step()
    src.step()                          # rids 0/1 mid-decode, 2/3 queued
    assert all(len(r.generated) >= 2 for r in reqs[:2])
    FaultInjector(ctl, [FaultSpec(CRASH, src_id,
                                  at_s=ctl.now_s + 0.5)]).arm()
    ctl.run_for(20.0)
    dst.drain()
    assert any(e.name == "fleet.evict" and e.args["device"] == src_id
               for e in rec.events)
    assert ctl.migrations == 4          # 2 frozen + 2 waiting moved
    assert _streams(reqs) == want       # bit-identical to unfaulted run
    # the frozen rids thawed — they never prefilled on the destination
    assert dst.stats.thaws == 2 and dst.stats.prefills == 2
    [mig] = [e for e in rec.events if e.name == "fleet.migrate"]
    assert sorted(mig.args["zero_reprefill"]) == [0, 1]
    assert mig.args["fallback"] == []
    assert mig.args["recovered_tokens"] >= 4
    # the trace-derived audit agrees: no migrated rid ever re-prefilled
    summ = summarize_faults(rec.events)
    assert summ["migrated_requests"] == 2
    assert summ["migrated_reprefills"] == 0
    assert all(m["dst"] == dst_id for m in summ["migrations"])
    path = tmp_path / "migration.json"
    write_trace(rec, str(path))
    assert check_trace.check(path, require_layers=LAYERS) == 0
    # critical-path attribution over the same trace: the components of
    # every request sum bit-equal to its span-derived end-to-end
    # latency, and the migrated rids carry a nonzero offload_link
    # component (freeze on src, thaw on a *different* engine = the
    # frozen blob crossing a link)
    attrs = attribute_requests(rec)
    assert sorted(attrs) == [0, 1, 2, 3]
    for a in attrs.values():
        assert sum(a.components_ns.values()) == a.end_to_end_ns
        assert a.complete and a.pid == src_id
    for rid in (0, 1):                  # frozen mid-decode, thawed on dst
        assert attrs[rid].components_ns["offload_link"] > 0
    # fleet rollup totals are exactly the per-request integer sums
    fa = attribute_fleet(rec)
    assert fa.fleet.requests == 4
    for c in fa.fleet.components_ns:
        assert fa.fleet.components_ns[c] == \
            sum(a.components_ns[c] for a in attrs.values())
    assert fa.fleet.end_to_end_ns == \
        sum(a.end_to_end_ns for a in attrs.values())
    assert fa.per_device[src_id].requests == 4


def test_eviction_without_peer_requeues_locally_nothing_lost():
    """No same-domain engine-backed peer: eviction falls back to the
    local requeue — zero migrations, but the engine still holds every
    request and finishes them with the earned prefix intact."""
    want = _baseline_streams()
    fleet = _fleet()
    src_id = fleet[3].device_id         # the only engine in the fleet
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec)
    src = ctl.build_engine(src_id, PARAMS, cfg=TINY, slots=2, max_seq=64,
                           decode_mode="paged", steps_per_tick=1)
    reqs = _submit_long_mix(src, budget=6)
    src.step()
    pre = {r.rid: tuple(r.generated) for r in reqs}
    ctl.drop_device(src_id)             # announced eviction, no peer
    assert ctl.migrations == 0
    assert src.stats.requeues == 2      # actives went back to the queue
    assert not any(e.name == "req.migrate" for e in rec.events)
    assert summarize_faults(rec.events)["migrated_requests"] == 0
    src.drain()
    assert _streams(reqs) == want
    for rid, prefix in pre.items():
        assert _streams(reqs)[rid][:len(prefix)] == prefix  # no replay


def test_same_params_swap_does_not_grow_prefill_calls():
    """Swap-requeue regression: a same-variant ``swap_model`` freezes
    and thaws every in-flight request — ``prefill_calls`` must not grow
    and the streams must match the unswapped run bit for bit."""
    from repro.models.runtime import DEFAULT_OPTIONS
    want = _long_baseline(budget=6, slots=4)
    eng = ServingEngine(TINY, PARAMS, slots=4, max_seq=64,
                        compile_cache=CC)
    reqs = _submit_long_mix(eng, budget=6)
    eng.step()                          # all four admitted and decoding
    calls = eng.stats.prefill_calls
    eng.swap_model(TINY, PARAMS, DEFAULT_OPTIONS)
    eng.drain()
    assert eng.stats.prefill_calls == calls     # zero re-prefill
    assert eng.stats.thaws == 4
    assert _streams(reqs) == want


# ----------------------------------------------------------- regressions --
def test_unknown_device_raises_keyerror_naming_known_ids():
    fleet = _fleet()
    ctl = _controller(fleet)
    for call in (lambda: ctl.inject_load("nope#9", 0.5),
                 lambda: ctl.drop_device("nope#9"),
                 lambda: ctl.attach_engine("nope#9", object()),
                 lambda: ctl.fail_device("nope#9")):
        with pytest.raises(KeyError, match="known devices.*pixel_6_cpu#0"):
            call()


def test_remove_member_racing_pending_placement_wake():
    # drop a member while a pulled-forward placement wake is already in
    # the heap: the wake fires after the member is gone and must fall
    # the requester back to local without raising
    fleet = _fleet()
    phone = fleet[0].device_id
    ctl = _controller(fleet)
    helper = _placed_helper(ctl, phone)
    ctl.inject_load(helper, 0.9)           # schedules an imminent wake
    ctl.drop_device(helper)                # member gone before it fires
    ctl.run_for(6.0)                       # wake fires: must not raise
    dec = ctl.placement_of(phone)
    assert helper not in dec.hosts
    assert phone in ctl.placer.members


def test_fault_free_run_with_detector_is_bit_identical():
    fleet = _fleet()

    def run(detection):
        ctl = _controller(fleet, detection=detection)
        ctl.run_for(10.0)
        return ctl

    a, b = run(True), run(False)
    assert [(r.device_id, r.tick, r.observed_s, r.predicted_s,
             r.violated) for r in a.records] == \
           [(r.device_id, r.tick, r.observed_s, r.predicted_s,
             r.violated) for r in b.records]
    assert [(t, d.hosts) for t, _, d in a.placement_log] == \
           [(t, d.hosts) for t, _, d in b.placement_log]


# ------------------------------------------------------- randomized chaos --
def _chaos_run(seed, tmp_path=None):
    """One randomized chaos scenario; returns everything the invariant
    assertions need."""
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    dcfg = DetectorConfig(suspect_after=2.5, dead_after=5.0)
    ctl = _controller(fleet, recorder=rec, detector_config=dcfg,
                      seed=seed)
    horizon = 24.0
    schedule = random_schedule(fleet, horizon, seed=seed, n_faults=4,
                               protect=[phone])
    inj = FaultInjector(ctl, schedule).arm()
    ctl.run_for(horizon)
    return ctl, rec, inj, phone, dcfg


def _assert_chaos_invariants(ctl, rec, inj, phone, dcfg):
    # 1. the protected requester kept producing throughout
    assert ctl.tick_counts[phone] > 0
    phone_ts = [r.timestamp_s for r in ctl.records
                if r.device_id == phone]
    env = ctl._devices[phone].spec.tick_envelope
    gaps = np.diff([0.0] + sorted(phone_ts))
    # no stall longer than a few of its own periods — degradation, not
    # starvation (placement sweeps and chain recovery are wake-local)
    assert gaps.max() <= 5.0 * env.max_s + ctl.retry_policy.worst_case_s(1.0)
    # 2. every applied silent fault that outlived the detection grace
    #    was suspected within its bound
    for f in inj.applied:
        if f.kind not in SILENT_KINDS:
            continue
        venv = ctl._devices[f.target].spec.tick_envelope
        bound = (dcfg.suspect_after + 1.0) * venv.max_s \
            + ctl._detect_period_s
        if f.kind == FREEZE and f.duration_s <= bound:
            continue                    # too brief to be detectable
        sus = [e.sim_s for e in rec.events
               if e.name == "detector.suspect"
               and e.args["device"] == f.target and e.sim_s >= f.at_s]
        assert sus, f"undetected silent fault: {f}"
        assert sus[0] - f.at_s <= bound, f
    # 3. trace still validates (spans balanced, clocks monotone)
    doc_problems = _validate(rec)
    assert doc_problems == 0
    # 4. no fault ever duplicated a wake record
    keys = [(r.device_id, r.tick) for r in ctl.records]
    assert len(keys) == len(set(keys))


def _validate(rec):
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "chaos.json"
        write_trace(rec, str(path))
        return check_trace.check(path,
                                 require_layers=("fleet", "placement"))


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_randomized_chaos_schedule_invariants(seed):
    ctl, rec, inj, phone, dcfg = _chaos_run(seed)
    assert inj.applied or inj.skipped        # the schedule actually ran
    _assert_chaos_invariants(ctl, rec, inj, phone, dcfg)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_randomized_chaos_schedule_invariants_hypothesis(seed):
        ctl, rec, inj, phone, dcfg = _chaos_run(seed)
        _assert_chaos_invariants(ctl, rec, inj, phone, dcfg)


def test_chaos_trace_has_all_four_layers_with_engine(tmp_path):
    # an engine-backed requester under faults: the exported timeline
    # carries request/engine/fleet/placement events and validates
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    ctl = _controller(fleet, recorder=rec)
    eng = ctl.build_engine(fleet[1].device_id, PARAMS, cfg=TINY,
                           slots=2, max_seq=64, steps_per_tick=2)
    for i in range(3):
        rng = np.random.default_rng(i)
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, TINY.vocab_size,
                                               size=6).astype(np.int32),
                           max_new_tokens=6))
    ctl.run_for(8.0)
    victim = fleet[2].device_id
    FaultInjector(ctl, [FaultSpec(CRASH, victim,
                                  at_s=ctl.now_s + 0.5)]).arm()
    ctl.run_for(8.0)
    rng = np.random.default_rng(99)
    eng.submit(Request(rid=9, prompt=rng.integers(
        0, TINY.vocab_size, size=6).astype(np.int32), max_new_tokens=4))
    eng.inject_oom(1)         # the queued request hits one failed admit
    eng.drain()
    path = tmp_path / "chaos_layers.json"
    write_trace(rec, str(path))
    assert check_trace.check(path, require_layers=LAYERS) == 0
    assert any(e.name == "engine.oom" for e in rec.events)
    assert any(e.name == "fault.inject" for e in rec.events)
    assert any(e.name == "detector.dead" for e in rec.events)
