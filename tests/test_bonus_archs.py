"""Bonus architectures (beyond the assigned grid): smoke + dry-run-style
reduced compile, proving the framework generalizes past the assignment."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import BONUS_ARCHS, get_config
from repro.models import (RuntimeOptions, decode_step, forward, init_cache,
                          init_params, prefill)


@pytest.mark.parametrize("arch", BONUS_ARCHS)
def test_bonus_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOptions(moe_capacity_factor=8.0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    logits, _ = forward(params, cfg, tokens, opts)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    cache = init_cache(cfg, 2, 24, opts)
    _, cache = prefill(params, cfg, tokens[:, :11], cache, opts)
    lg, _ = decode_step(params, cfg, cache, tokens[:, 11], opts)
    ref = logits[:, -1].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(ref - lg.astype(jnp.float32)))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.06, arch


@pytest.mark.parametrize("arch", BONUS_ARCHS)
def test_bonus_param_specs_divisible(arch):
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_specs
    from repro.launch.steps import params_spec_struct
    cfg = get_config(arch)
    tree = params_spec_struct(cfg)
    specs = param_specs(cfg, tree)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(tree),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                assert dim % 16 == 0, (arch, leaf.shape, spec)
