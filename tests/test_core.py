"""Middleware core: profiler Eq.1/2, optimizer Pareto/AHP (property-based),
adaptation loop behavior under context traces."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.core import (ActionEvaluator, Budgets, ResourceContext,
                        ahp_weights, budget_sweep_trace, case_study_trace,
                        context_ahp, estimate_energy, estimate_latency,
                        layer_costs, nondominated_front, rank_consistency,
                        select_online, AdaptationLoop, TPU_V5E)
from repro.core.actions import Action, default_action_space
from repro.core.profiler import analytic_step_costs, collective_bytes_from_hlo
from repro.elastic import VariantSpec
from repro.models.configs import INPUT_SHAPES, InputShape

CFG = get_config("paper-backbone")
SHAPE = InputShape("t", 512, 8, "prefill")


def test_layer_costs_structure():
    costs = layer_costs(CFG, 2, 128)
    # attn + ffn per layer + lm head
    assert len(costs) == 2 * CFG.num_layers + 1
    assert all(c.macs > 0 and c.bytes > 0 for c in costs)


def test_eq2_latency_monotone_in_eps():
    """Higher cache-hit-rate must never increase latency (paper Eq. 2)."""
    costs = layer_costs(CFG, 2, 128)
    lats = [estimate_latency(costs, eps) for eps in (0.1, 0.5, 0.9)]
    assert lats[0] > lats[1] > lats[2]


def test_eq1_energy_monotone_in_eps():
    costs = layer_costs(CFG, 2, 128)
    es = [estimate_energy(costs, eps) for eps in (0.1, 0.5, 0.9)]
    assert es[0] > es[1] > es[2]


def test_profiler_ranks_model_sizes():
    """Bigger variants must rank strictly slower/hungrier — the paper's
    'consistent ranking' requirement."""
    sizes = [0.5, 0.75, 1.0]
    lats, ens = [], []
    for r in sizes:
        c = CFG.with_updates(d_ff=int(CFG.d_ff * r),
                             num_layers=max(1, int(CFG.num_layers * r)))
        costs = layer_costs(c, 2, 128)
        lats.append(estimate_latency(costs, 0.5))
        ens.append(estimate_energy(costs, 0.5))
    assert rank_consistency(lats, [1, 2, 3]) == 1.0
    assert rank_consistency(ens, [1, 2, 3]) == 1.0


def test_analytic_step_costs_scale_with_work():
    f_tr, b_tr = analytic_step_costs(CFG, INPUT_SHAPES["train_4k"], "full")
    f_fw, _ = analytic_step_costs(CFG, INPUT_SHAPES["train_4k"])
    f_pf, b_pf = analytic_step_costs(CFG, INPUT_SHAPES["prefill_32k"])
    f_dc, b_dc = analytic_step_costs(CFG, INPUT_SHAPES["decode_32k"])
    assert f_tr > f_fw          # remat adds recompute
    assert f_tr > f_dc and f_pf > f_dc
    assert b_dc > 0


def test_collective_parse_handles_layouts():
    hlo = """
ENTRY %main (p: bf16[8,128]) -> bf16[8,128] {
  %ag = bf16[64,5120]{1,0} all-gather(%p), replica_groups={}
  %ar = f32[16,4096,5120]{2,1,0} all-reduce(%x), to_apply=%add
  %ags = (bf16[2,4]{1,0}, bf16[2,4]{1,0}) all-gather-start(%p)
  %agd = bf16[2,4]{1,0} all-gather-done(%ags)
}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 64 * 5120 * 2 + 2 * (2 * 4 * 2)
    assert out["all-reduce"] == 16 * 4096 * 5120 * 4


# ------------------------------------------------------------- optimizer ---
def test_pareto_front_is_nondominated():
    ev = ActionEvaluator(CFG, SHAPE)
    ctx = ResourceContext()
    actions = default_action_space(
        (VariantSpec(), VariantSpec(depth_ratio=0.5),
         VariantSpec(width_ratio=0.5)), allow_offload=False)
    evals = [ev.evaluate(a, ctx) for a in actions]
    front = nondominated_front(evals)
    assert front
    for e in front:
        for f in evals:
            assert not (f.accuracy > e.accuracy and f.energy_j < e.energy_j)


def test_select_online_respects_budgets():
    ev = ActionEvaluator(CFG, SHAPE)
    ctx = ResourceContext(battery_frac=0.5)
    actions = default_action_space(
        (VariantSpec(), VariantSpec(depth_ratio=0.5)), allow_offload=False)
    evals = [ev.evaluate(a, ctx) for a in actions]
    front = nondominated_front(evals)
    mem_cap = np.median([e.memory_bytes for e in front])
    choice = select_online(front, ctx, Budgets(memory_bytes=mem_cap))
    assert choice is not None
    assert choice.memory_bytes <= mem_cap


def test_mu_tradeoff_direction():
    """Low battery (μ→0) must pick lower-energy actions than high battery."""
    ev = ActionEvaluator(CFG, SHAPE)
    actions = default_action_space(
        (VariantSpec(), VariantSpec(depth_ratio=0.5, width_ratio=0.5)),
        allow_offload=False)
    front = nondominated_front(
        [ev.evaluate(a, ResourceContext()) for a in actions])
    rich = select_online(front, ResourceContext(battery_frac=0.95), Budgets())
    poor = select_online(front, ResourceContext(battery_frac=0.05), Budgets())
    assert poor.energy_j <= rich.energy_j


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_ahp_weights_valid(bat, mem):
    w = context_ahp(ResourceContext(battery_frac=bat, mem_free_frac=mem))
    assert abs(float(w.sum()) - 1.0) < 1e-6
    assert all(float(x) >= 0 for x in w)


def test_ahp_pairwise_eigenvector():
    m = np.array([[1.0, 3.0], [1 / 3.0, 1.0]])
    w = ahp_weights(m)
    assert w[0] > w[1]
    np.testing.assert_allclose(w[0] / w[1], 3.0, rtol=1e-6)


# ------------------------------------------------------------ the loop -----
def test_loop_budget_sweep_shrinks_memory():
    """Paper Table II: tighter memory budgets -> smaller selected memory."""
    loop = AdaptationLoop(cfg=CFG, shape=SHAPE, allow_offload=False,
                          hysteresis=0.0)
    loop.build_pareto(evolve=False)
    mems = []
    for ctx in budget_sweep_trace((1.0, 0.5, 0.25)):
        # scale hbm budget context: 8GB baseline
        ctx = dataclasses.replace(ctx, chips_available=1)
        d = loop.tick(ctx)
        mems.append(d.eval.memory_bytes)
    assert mems[-1] <= mems[0]


def test_loop_hysteresis_holds():
    loop = AdaptationLoop(cfg=CFG, shape=SHAPE, allow_offload=False,
                          hysteresis=10.0)  # huge: never switch
    ctx0 = ResourceContext()
    d0 = loop.tick(ctx0)
    d1 = loop.tick(dataclasses.replace(ctx0, battery_frac=0.5))
    assert d1.action == d0.action
    assert "hold" in d1.reason


def test_case_study_trace_shape():
    tr = list(case_study_trace(10))
    assert len(tr) == 10
    assert tr[0].battery_frac > tr[-1].battery_frac
    assert any(c.mem_free_frac < 0.4 for c in tr)
