"""Engine: memory planner (property-based), remat ladder, quantization,
fusion accounting, parallel plan bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.engine import (POLICY_LADDER, activation_bytes, choose_policy,
                          compression_error, fuse_graph, greedy_no_reuse,
                          peak_live_bytes, plan_memory, plan_parallelism,
                          quantize_int4, quantize_int8, dequantize_int8,
                          dequantize_int4, sub_batch_split, swap_plan,
                          backprop_reorder_savings)
from repro.offload import Graph, OpNode, build_model_graph

CFG = get_config("paper-backbone")
G = build_model_graph(CFG, 1, 128)


# -------------------------------------------------------- memory planner ---
def test_memory_plan_valid_and_bounded():
    plan = plan_memory(G)
    plan.validate()  # raises on temporal+address overlap
    assert plan.peak_bytes <= plan.naive_bytes
    assert plan.peak_bytes >= peak_live_bytes(G) - 1  # cannot beat liveness


@st.composite
def chain_graphs(draw):
    n = draw(st.integers(3, 20))
    nodes = []
    names = ["x"]
    for i in range(n):
        # random fan-in from earlier tensors; random sizes
        k = draw(st.integers(1, min(2, len(names))))
        ins = tuple(draw(st.sampled_from(names)) for _ in range(k))
        size = draw(st.integers(1, 10_000))
        nodes.append(OpNode(f"n{i}", "add", ins, f"n{i}", out_bytes=size))
        names.append(f"n{i}")
    return Graph(nodes=nodes, inputs=("x",), outputs=(names[-1],))


@settings(max_examples=40, deadline=None)
@given(chain_graphs())
def test_memory_plan_property(g):
    plan = plan_memory(g, alignment=1)
    plan.validate()
    assert plan.peak_bytes <= greedy_no_reuse(g)
    assert plan.peak_bytes >= peak_live_bytes(g)


# ----------------------------------------------------------------- remat ---
def test_remat_ladder_monotone():
    bases = [keep for _, keep, _ in POLICY_LADDER]
    assert bases == sorted(bases, reverse=True)
    overheads = [o for _, _, o in POLICY_LADDER]
    assert overheads == sorted(overheads)


def test_choose_policy_progressive():
    full = activation_bytes(CFG, 8, 512)
    d = choose_policy(CFG, 8, 512, budget_bytes=full * 2)
    assert d.policy == "none"
    d = choose_policy(CFG, 8, 512, budget_bytes=full * 0.5)
    assert d.policy == "dots"
    d = choose_policy(CFG, 8, 512, budget_bytes=full * 0.01)
    assert d.policy == "full"


def test_sub_batch_split_fits_budget():
    budget = activation_bytes(CFG, 1, 512) * 0.08 * 2.5  # fits ~2 examples
    n = sub_batch_split(CFG, 8, 512, budget, policy="full")
    per = activation_bytes(CFG, 8 // n, 512) * 0.08
    assert per <= budget


# ---------------------------------------------------------- quantization ---
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.1, 100.0))
def test_int8_roundtrip_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 256)) * scale
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, jnp.float32)
    blockmax = jnp.max(jnp.abs(x.reshape(4, 2, 128)), -1, keepdims=True)
    bound = jnp.repeat(blockmax / 127.0, 128, -1).reshape(4, 256) * 0.51 + 1e-9
    assert bool(jnp.all(jnp.abs(y - x) <= bound))


def test_int4_worse_than_int8():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 384))
    assert compression_error(x, 4) > compression_error(x, 8)
    assert compression_error(x, 8) < 0.02


def test_int4_pack_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 256))
    packed, s = quantize_int4(x)
    assert packed.shape == (2, 128)
    y = dequantize_int4(packed, s, 256, jnp.float32)
    assert float(jnp.abs(y - x).max()) < float(jnp.abs(x).max()) * 0.2


# -------------------------------------------------------------- fusion -----
def test_fusion_preserves_flops_and_reduces_ops():
    g2, reports = fuse_graph(G)
    assert abs(g2.total_flops() - G.total_flops()) < 1e-6
    assert len(g2.nodes) < len(G.nodes)
    assert sum(r.bytes_saved for r in reports) > 0


# ------------------------------------------------------------- schedule ----
def test_parallel_plan_bounds():
    p1 = plan_parallelism(G, streams=1)
    p2 = plan_parallelism(G, streams=2)
    p4 = plan_parallelism(G, streams=4)
    assert 1.0 <= p2.speedup <= 2.0 + 1e-9
    assert p2.speedup <= p4.speedup + 1e-9
    assert abs(p1.speedup - 1.0) < 1e-6


def test_backprop_reorder_savings():
    full, reordered = backprop_reorder_savings(24, 10_000_000)
    assert full == 24 * reordered


def test_swap_plan_meets_budget():
    per_layer = [100] * 10
    swapped, resident = swap_plan(per_layer, budget_bytes=450)
    assert resident <= 450
    assert swapped == list(range(6))  # earliest layers first
