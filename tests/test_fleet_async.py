"""Event-driven fleet stepping: per-device tick rates, out-of-order
telemetry arrival, lockstep parity, and the tick-rate envelope."""
import dataclasses
import random

import numpy as np
import pytest

from repro.configs import get_config
from repro.fleet import (ENGINE, FleetController, LIGHT, SIMULATED,
                         TIER_TICK_S, EwmaLsqCalibrator, MeasurementRecord,
                         TelemetryStore, build_fleet, fleet_report,
                         make_device)
from repro.models.configs import InputShape

CFG = get_config("paper-backbone")
SHAPE = InputShape("fleet_a", 256, 4, "prefill")


# ------------------------------------------------------- tick envelope ----
def test_tick_envelope_scales_and_clamps():
    spec = make_device("pixel_6_cpu", 0)
    env = spec.tick_envelope
    assert env.nominal_s == pytest.approx(TIER_TICK_S[LIGHT])
    assert env.min_s == env.nominal_s
    assert env.max_s == pytest.approx(env.nominal_s / spec.dvfs_floor)
    # clamp bounds a DVFS-derated period into the envelope
    assert env.clamp(0.0) == env.min_s
    assert env.clamp(1e9) == env.max_s
    slowed = dataclasses.replace(spec, tick_scale=8.0)
    assert slowed.tick_envelope.nominal_s == pytest.approx(8 * env.nominal_s)


def test_heavy_tier_ticks_faster_than_light():
    heavy = make_device("tpu_v5e", 0)
    light = make_device("pixel_6_cpu", 0)
    assert heavy.tick_envelope.nominal_s < light.tick_envelope.nominal_s


# ------------------------------------------- out-of-order telemetry -------
def _records(n, seed=0, tier=LIGHT, channel=SIMULATED, devices=("a", "b")):
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        p = float(rng.uniform(0.1, 1.0))
        recs.append(MeasurementRecord(
            device_id=devices[i % len(devices)], tier=tier, tick=i,
            predicted_latency_s=p, observed_latency_s=1.5 * p + 0.02,
            predicted_energy_j=p, observed_energy_j=1.3 * p,
            channel=channel, timestamp_s=float(rng.uniform(0, 50))))
    return recs


def test_shuffled_arrival_gives_identical_tier_fit():
    """The acceptance property: any arrival permutation of one record
    set produces the bit-identical (tier, channel) calibrator fit."""
    recs = _records(48)
    in_order = TelemetryStore()
    for r in sorted(recs, key=lambda r: r.timestamp_s):
        in_order.record(r)
    rng = random.Random(7)
    for trial in range(3):
        shuffled = TelemetryStore()
        perm = list(recs)
        rng.shuffle(perm)
        for r in perm:
            shuffled.record(r)
        assert shuffled.calibration_for_tier(LIGHT) \
            == in_order.calibration_for_tier(LIGHT)
        assert shuffled.calibration_for_device("a") \
            == in_order.calibration_for_device("a")


def test_shuffled_arrival_identical_per_channel():
    recs = _records(30, seed=1) + _records(
        30, seed=2, channel=ENGINE, devices=("e",))
    a, b = TelemetryStore(), TelemetryStore()
    for r in recs:
        a.record(r)
    perm = list(recs)
    random.Random(3).shuffle(perm)
    for r in perm:
        b.record(r)
    for chan in (SIMULATED, ENGINE):
        assert a.calibration_for_tier(LIGHT, chan) \
            == b.calibration_for_tier(LIGHT, chan)


def test_calibrator_timestamp_merge_matches_in_order():
    """Direct calibrator API: late-arriving older samples land in their
    sorted position, so the fit equals the in-order one."""
    rng = np.random.default_rng(4)
    samples = [(float(t), float(p), 1.4 * float(p) + 0.1)
               for t, p in zip(rng.uniform(0, 9, 24), rng.uniform(0.5, 2, 24))]
    fwd, shuf = EwmaLsqCalibrator(), EwmaLsqCalibrator()
    for t, p, o in sorted(samples):
        fwd.observe(p, o, p, 1.2 * p, timestamp_s=t, key=("d", 0))
    perm = list(samples)
    random.Random(5).shuffle(perm)
    for t, p, o in perm:
        shuf.observe(p, o, p, 1.2 * p, timestamp_s=t, key=("d", 0))
    assert fwd.calibration() == shuf.calibration()
    assert fwd.calibration().latency_scale == pytest.approx(1.4, rel=0.05)


def test_event_fleet_reports_arrive_out_of_order():
    """Under event stepping with reporting jitter, the store's arrival
    log is NOT sorted by observation timestamp — yet fits stay clean."""
    ctl = FleetController(build_fleet(6, seed=0), CFG, SHAPE,
                          trace_ticks=16)
    ctl.run(16)
    stamps = [r.timestamp_s for r in ctl.telemetry.records]
    assert stamps != sorted(stamps)          # genuinely out of order
    rep = fleet_report(ctl)
    for t in rep.tiers:
        assert t.mape_after < t.mape_before


# -------------------------------------------------- differential rates ----
def test_fast_devices_accumulate_3x_ticks_of_slowed_device():
    """Acceptance: with one artificially slowed member, fast-tier
    devices take ≥3× as many wakes over the same simulated horizon."""
    fast = make_device("tpu_v5e", 0)
    slow = dataclasses.replace(make_device("pixel_6_cpu", 0),
                               tick_scale=8.0)
    ctl = FleetController([fast, slow], CFG, SHAPE, trace_ticks=400)
    ctl.run_for(40.0)
    ticks = ctl.tick_counts
    assert ticks[slow.device_id] >= 1
    assert ticks[fast.device_id] >= 3 * ticks[slow.device_id]
    # every record of the slow device is strictly ordered on the clock,
    # and fast-device records interleave between them
    rep = fleet_report(ctl)
    assert rep.device_ticks == ticks
    assert rep.clock_skew_s > 0


def test_event_mode_slow_device_never_gates_fast():
    """The fast device's wake cadence is independent of the slow one:
    removing the slow member leaves the fast member's tick count (and
    its decision sequence) unchanged."""
    fast = make_device("tpu_v5e", 0)
    slow = dataclasses.replace(make_device("pixel_6_cpu", 0),
                               tick_scale=16.0)
    ctl_pair = FleetController([fast, slow], CFG, SHAPE, trace_ticks=200,
                               share_calibration=False)
    ctl_solo = FleetController([fast], CFG, SHAPE, trace_ticks=200,
                               share_calibration=False)
    ctl_pair.run_for(20.0)
    ctl_solo.run_for(20.0)
    assert ctl_pair.tick_counts[fast.device_id] \
        == ctl_solo.tick_counts[fast.device_id]


# --------------------------------------------------------- lockstep -------
def test_lockstep_reproduces_per_tick_parity():
    """Acceptance: step_mode='lockstep' keeps every device on the same
    global tick — per-step record sets cover the whole fleet, tick
    counts stay equal, and the report shows zero clock skew."""
    fleet = build_fleet(6, seed=0)
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=12,
                          step_mode="lockstep")
    for step in range(1, 13):
        recs = ctl.step()
        assert len(recs) == len(fleet)
        assert {r.tick for r in recs} == {step}
        assert {r.timestamp_s for r in recs} == {float(step)}
    assert set(ctl.tick_counts.values()) == {12}
    assert fleet_report(ctl).clock_skew_s == 0.0
    # the fleet-clock violation window agrees with the tick window under
    # lockstep (timestamps ARE the global ticks) and the halves add up
    assert ctl.violations(first_s=1.0, last_s=6.0) \
        == ctl.violations(first_tick=1, last_tick=6)
    assert ctl.violations(last_s=6.0) + ctl.violations(first_s=6.5) \
        == ctl.violations()


def test_lockstep_and_event_modes_are_both_deterministic():
    for mode in ("event", "lockstep"):
        runs = []
        for _ in range(2):
            ctl = FleetController(build_fleet(6, seed=0), CFG, SHAPE,
                                  trace_ticks=10, step_mode=mode, seed=0)
            ctl.run(10)
            runs.append([(r.device_id, r.tick, r.timestamp_s, r.observed_s)
                         for r in ctl.records])
        assert runs[0] == runs[1], mode


def test_run_for_requires_event_mode():
    ctl = FleetController(build_fleet(3, seed=0), CFG, SHAPE,
                          trace_ticks=4, step_mode="lockstep")
    with pytest.raises(RuntimeError):
        ctl.run_for(1.0)
    with pytest.raises(ValueError):
        FleetController(build_fleet(3, seed=0), CFG, SHAPE,
                        step_mode="async")


# ------------------------------------------------- engine timing hook -----
def test_engine_step_ewma_feeds_next_wake():
    """An engine-backed device's wake period grows by steps_per_tick ×
    the engine's measured step-time EWMA."""
    class _Eng:
        has_work = True
        step_times = []
        step_time_ewma_s = 0.5

        def step(self):
            self.step_times.append(0.5)

    fleet = [make_device("pixel_6_cpu", 0)]
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=100)
    base = FleetController(fleet, CFG, SHAPE, trace_ticks=100)
    ctl.attach_engine(fleet[0].device_id, _Eng(), steps_per_tick=2)
    ctl.run_for(12.0)
    base.run_for(12.0)
    # period ≈ 1.0s envelope + 2 × 0.5s measured = ~2× slower cadence
    assert ctl.tick_counts[fleet[0].device_id] \
        < base.tick_counts[fleet[0].device_id]
