"""Hypothesis property tests on model-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.models import (RuntimeOptions, forward, init_params, lm_loss)
from repro.models.layers import (apply_rotary, mask_padded_logits_raw,
                                 rms_norm, rotary_embedding)

CFG = get_config("paper-backbone").with_updates(num_layers=2, d_model=64,
                                                num_heads=4, num_kv_heads=2,
                                                head_dim=16, d_ff=128,
                                                vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.5, 8.0))
def test_rms_norm_scale_invariance(seed, scale):
    """rms_norm(a*x) == rms_norm(x) — the property TTA's norm-only
    updates rely on."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
    g = jnp.zeros((32,))
    np.testing.assert_allclose(np.asarray(rms_norm(x * scale, g)),
                               np.asarray(rms_norm(x, g)), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 500))
def test_rotary_preserves_norm_and_relative_phase(seed, offset):
    """Rotary embedding is an isometry and depends only on relative
    positions for dot products."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, 2, hd))
    pos = jnp.arange(4)[None, :] + offset
    sin, cos = rotary_embedding(pos, hd)
    qr = apply_rotary(q, sin, cos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(qr), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5)
    # relative phase: <rot(q,p1), rot(k,p2)> == <rot(q,p1+d), rot(k,p2+d)>
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 4, 2, hd))
    kr = apply_rotary(k, sin, cos)
    dot1 = np.einsum("bshd,bthd->bst", np.asarray(qr), np.asarray(kr))
    sin2, cos2 = rotary_embedding(pos + 37, hd)
    qr2 = apply_rotary(q, sin2, cos2)
    kr2 = apply_rotary(k, sin2, cos2)
    dot2 = np.einsum("bshd,bthd->bst", np.asarray(qr2), np.asarray(kr2))
    np.testing.assert_allclose(dot1, dot2, atol=1e-3)


def test_model_causality():
    """Changing token t must not change logits before t."""
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 300)
    lg1, _ = forward(PARAMS, CFG, tokens,
                     RuntimeOptions(attn_impl="full"))
    tokens2 = tokens.at[0, 10].set((tokens[0, 10] + 7) % 300)
    lg2, _ = forward(PARAMS, CFG, tokens2,
                     RuntimeOptions(attn_impl="full"))
    np.testing.assert_allclose(np.asarray(lg1[:, :10], np.float32),
                               np.asarray(lg2[:, :10], np.float32),
                               atol=1e-3)
    assert not np.allclose(np.asarray(lg1[:, 10:], np.float32),
                           np.asarray(lg2[:, 10:], np.float32))


def test_padded_vocab_masked_everywhere():
    """Vocab 300 pads to 512; padded logits must never win an argmax."""
    assert CFG.padded_vocab == 512
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 300)
    logits, _ = forward(PARAMS, CFG, tokens)
    assert logits.shape[-1] == 512
    arg = np.asarray(jnp.argmax(logits, -1))
    assert (arg < 300).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lm_loss_bounds(seed):
    """Cross entropy of uniform logits == log(V); mask semantics hold."""
    v = 64
    logits = jnp.zeros((2, 8, v))
    labels = jax.random.randint(jax.random.PRNGKey(seed), (2, 8), 0, v)
    np.testing.assert_allclose(float(lm_loss(logits, labels)), np.log(v),
                               rtol=1e-5)
    mask = jnp.zeros((2, 8)).at[:, 0].set(1.0)
    assert float(lm_loss(logits, labels, mask)) == pytest.approx(np.log(v),
                                                                 rel=1e-5)


def test_moe_capacity_drops_bounded():
    """With capacity factor 1.0 at most (1 - 1/cf_overhead) of gate mass is
    dropped; with a big factor nothing drops."""
    from repro.models import moe as moe_mod
    cfg = get_config("olmoe-1b-7b").reduced()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    y_small, _ = moe_mod.moe_apply(params, x, cfg, capacity_factor=1.0)
    y_big, _ = moe_mod.moe_apply(params, x, cfg, capacity_factor=16.0)
    assert y_small.shape == y_big.shape
    # big capacity is the reference; small capacity differs only via drops
    diff = float(jnp.abs(y_small - y_big).mean())
    ref = float(jnp.abs(y_big).mean())
    assert diff < ref  # drops lose mass; they never add energy
