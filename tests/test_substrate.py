"""Substrate: data pipeline, checkpointing, AdamW, serving engine."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM, make_batch_fn
from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.models import init_params
from repro.models.configs import InputShape
from repro.optim import AdamWConfig
from repro.optim import apply as adamw_apply
from repro.optim import init as adamw_init
from repro.optim.schedule import warmup_cosine
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------- data -----
def test_data_deterministic_and_seekable():
    d = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, batch_size=4))
    b1 = d.batch(7)
    b2 = d.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels shifted by one
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_induction_structure():
    d = SyntheticLM(DataConfig(vocab_size=128, seq_len=64, batch_size=4,
                               copy_period=16))
    b = d.batch(0)
    full = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    for off in range(16, 64, 16):
        np.testing.assert_array_equal(full[:, off], full[:, off - 16])


def test_data_drift_changes_distribution():
    base = SyntheticLM(DataConfig(vocab_size=512, seq_len=64, batch_size=32))
    drift = SyntheticLM(DataConfig(vocab_size=512, seq_len=64, batch_size=32,
                                   drift=0.9))
    h1 = np.bincount(base.batch(0)["tokens"].ravel(), minlength=512)
    h2 = np.bincount(drift.batch(0)["tokens"].ravel(), minlength=512)
    tv = 0.5 * np.abs(h1 / h1.sum() - h2 / h2.sum()).sum()
    assert tv > 0.1


def test_make_batch_fn_modality_stubs():
    cfg = get_config("whisper-small").reduced()
    shape = InputShape("t", 32, 2, "train")
    b = make_batch_fn(cfg, shape)(0)
    assert b["encoder_frames"].shape == (2, cfg.encoder_seq_len, cfg.d_model)
    cfg2 = get_config("internvl2-26b").reduced()
    b2 = make_batch_fn(cfg2, shape)(0)
    assert b2["vision_embeds"].shape == (2, cfg2.num_vision_tokens,
                                         cfg2.vision_embed_dim)


# ------------------------------------------------------------ checkpoint ---
def test_checkpoint_roundtrip():
    cfg = get_config("paper-backbone").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(f"{td}/step_000010", params, step=10,
                        metadata={"arch": cfg.name})
        like = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(1)))
        restored, step = restore_checkpoint(f"{td}/step_000010", like)
        assert step == 10
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert latest_checkpoint(td).name == "step_000010"


def test_checkpoint_shape_mismatch_raises():
    cfg = get_config("paper-backbone").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(f"{td}/c", params)
        wrong = jax.eval_shape(lambda: init_params(
            cfg.with_updates(d_ff=cfg.d_ff * 2), jax.random.PRNGKey(0)))
        with pytest.raises(ValueError):
            restore_checkpoint(f"{td}/c", wrong)


# ----------------------------------------------------------------- adamw ---
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_apply(grads, params, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state.step) == 200


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    p1, _ = adamw_apply({"w": jnp.asarray([1e6, 0.0, 0.0])}, params, state,
                        cfg)
    assert float(jnp.abs(p1["w"]).max()) < 2.0


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0)) == 0.0
    assert float(warmup_cosine(100)) == pytest.approx(1.0, abs=1e-3)
    assert float(warmup_cosine(10_000)) == pytest.approx(0.1, abs=1e-3)


# --------------------------------------------------------------- serving ---
def test_serving_engine_end_to_end():
    cfg = get_config("paper-backbone").with_updates(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, 256, size=8).astype(np.int32), max_new_tokens=4))
    eng.drain(max_steps=200)
    assert eng.stats.prefills == 5
    assert eng.stats.tokens_out >= 5 * 4
    assert not eng._queue and not any(eng._active)


def test_serving_variant_swap_preserves_requests():
    cfg = get_config("paper-backbone").with_updates(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, 256, size=8).astype(np.int32), max_new_tokens=6))
    eng.step()
    from repro.elastic import ElasticSupernet, VariantSpec
    sn = ElasticSupernet(cfg, params)
    vcfg, vparams = sn.variant(VariantSpec(depth_ratio=0.5))
    eng.swap_model(vcfg, vparams, eng.opts)
    eng.drain(max_steps=200)
    assert eng.generation == 1
    assert eng.stats.tokens_out >= 3 * 6
