"""Slot-batched decode path: greedy streams must be bit-identical to the
per-slot reference under mixed prompt lengths, mid-stream admissions,
slot recycling and mid-decode variant swaps; the donated stacked cache
must never be reused; engines sharing a CompileCache must not recompile
shared programs (even with heterogeneous per-slot sampling and
mixed-size admission bursts); a burst of k same-bucket requests costs
exactly ONE prefill jit call; and batched admission never starves an
earlier waiter from another bucket."""
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving import (CompileCache, Request, SamplingOpts,
                           ServingEngine)

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
# one cache for the whole module so the two modes share programs and the
# suite compiles each program exactly once
CC = CompileCache()

MODES = ("per_slot", "batched")


def _engine(mode, slots=2, cfg=CFG, params=PARAMS, cc=CC):
    return ServingEngine(cfg, params, slots=slots, max_seq=64,
                         decode_mode=mode, compile_cache=cc)


def _mixed_requests(n=6, seed=0, vocab=CFG.vocab_size):
    rng = np.random.default_rng(seed)
    lengths = [3, 10, 17, 33, 40, 5, 12, 26][:n]
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=lengths[i])
                    .astype(np.int32),
                    max_new_tokens=4 + i % 4)
            for i in range(n)]


def _streams(eng, reqs):
    return [tuple(r.generated) for r in reqs]


# ------------------------------------------------------------ equivalence --
def test_mixed_prompt_lengths_and_slot_recycling_match_reference():
    results = {}
    for mode in MODES:
        eng = _engine(mode, slots=2)     # 6 requests / 2 slots → recycling
        reqs = _mixed_requests()
        for r in reqs:
            eng.submit(r)
        eng.drain()
        assert all(r.done for r in reqs)
        results[mode] = (_streams(eng, reqs), eng.stats.tokens_out,
                         eng.stats.prefills, eng.stats.steps)
    assert results["batched"] == results["per_slot"]


def test_midstream_admissions_match_reference():
    results = {}
    for mode in MODES:
        eng = _engine(mode, slots=2)
        reqs = _mixed_requests(5, seed=3)
        for r in reqs[:2]:
            eng.submit(r)
        eng.step()
        eng.step()
        for r in reqs[2:]:
            eng.submit(r)
        eng.drain()
        results[mode] = _streams(eng, reqs)
    assert results["batched"] == results["per_slot"]


def test_swap_model_mid_decode_matches_reference():
    from repro.elastic import ElasticSupernet, VariantSpec
    sn = ElasticSupernet(CFG, PARAMS)
    vcfg, vparams = sn.variant(VariantSpec(depth_ratio=0.5))
    results = {}
    for mode in MODES:
        eng = _engine(mode, slots=2)
        reqs = _mixed_requests(4, seed=5)
        for r in reqs:
            r.max_new_tokens = 6
            eng.submit(r)
        eng.step()
        eng.step()
        eng.swap_model(vcfg, vparams, eng.opts)
        eng.drain()
        assert eng.generation == 1
        # in-flight requests were re-queued as copies; collect the live
        # objects the engine actually finished
        done = sorted({id(r): r for r in reqs}.values(), key=lambda r: r.rid)
        results[mode] = [tuple(r.generated[:6]) for r in done]
    assert results["batched"] == results["per_slot"]


def test_ssm_arch_matches_reference():
    cfg = get_config("mamba2-370m").reduced(d_model=64).with_updates(
        vocab_size=300, ssm_chunk=16)
    params = init_params(cfg, jax.random.PRNGKey(1))
    cc = CompileCache()
    results = {}
    for mode in MODES:
        eng = ServingEngine(cfg, params, slots=2, max_seq=64,
                            decode_mode=mode, compile_cache=cc)
        reqs = _mixed_requests(3, seed=7, vocab=cfg.vocab_size)
        for r in reqs:
            eng.submit(r)
        eng.drain()
        results[mode] = _streams(eng, reqs)
    assert results["batched"] == results["per_slot"]


# --------------------------------------------------------------- donation --
def test_donated_stacked_cache_is_not_reused():
    eng = _engine("batched")
    eng.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=8))
    eng.step()                       # admit + prefill + first decode
    old_leaves = jax.tree_util.tree_leaves(eng._cache)
    eng.step()                       # decode donates the stacked cache
    assert all(leaf.is_deleted() for leaf in old_leaves), \
        "decode step must consume (donate) the previous stacked cache"
    # the engine held no stale reference: it keeps stepping fine
    emitted = eng.step()
    assert emitted == 1


def test_slot_write_donates_previous_stacked_cache():
    eng = _engine("batched")
    old_leaves = jax.tree_util.tree_leaves(eng._cache)
    eng.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=4))
    eng.step()                       # admission writes the prefilled slot
    assert all(leaf.is_deleted() for leaf in old_leaves)


# ---------------------------------------------------------- compile cache --
def test_engines_share_programs_through_compile_cache():
    cc = CompileCache()
    streams = []
    recompiles = []
    for _ in range(2):
        eng = _engine("batched", cc=cc)
        reqs = _mixed_requests(4, seed=9)
        for r in reqs:
            eng.submit(r)
        eng.drain()
        streams.append(_streams(eng, reqs))
        recompiles.append(eng.stats.recompiles)
    assert recompiles[0] > 0          # first engine pays for the programs
    assert recompiles[1] == 0         # second engine compiles NOTHING
    assert streams[0] == streams[1]
    assert cc.hits > 0


def test_compile_domain_isolates_platforms():
    cc = CompileCache()
    e1 = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                       compile_cache=cc, compile_domain="pixel_6_cpu")
    assert e1.stats.recompiles == 1
    e2 = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                       compile_cache=cc, compile_domain="pixel_6_cpu")
    assert e2.stats.recompiles == 0   # same platform: shared
    e3 = ServingEngine(CFG, PARAMS, slots=2, max_seq=64,
                       compile_cache=cc, compile_domain="jetson_agx_orin")
    assert e3.stats.recompiles == 1   # other platform: own programs


# ------------------------------------------------------ batched admission --
def test_burst_admission_issues_exactly_one_prefill_call():
    """Acceptance pin: admitting a burst of k same-bucket requests runs
    ONE prefill jit call, with streams bit-identical to sequential
    per-request admission (k calls)."""
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, CFG.vocab_size, size=9).astype(np.int32)
               for _ in range(4)]

    def run(prefill_mode):
        eng = ServingEngine(CFG, PARAMS, slots=4, max_seq=64,
                            prefill_mode=prefill_mode, compile_cache=CC)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new_tokens=4)
                for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.step()
        calls_after_admit = eng.stats.prefill_calls
        eng.drain()
        return [tuple(r.generated) for r in reqs], calls_after_admit, eng

    batched_streams, batched_calls, eng = run("batched")
    sequential_streams, sequential_calls, _ = run("per_request")
    assert batched_calls == 1
    assert sequential_calls == 4
    assert eng.stats.prefills == 4        # still one *prefill* per request
    assert batched_streams == sequential_streams


def test_per_slot_decode_forces_per_request_admission():
    eng = _engine("per_slot")
    assert eng.prefill_mode == "per_request"


def test_heterogeneous_sampling_and_mixed_bursts_share_programs():
    """Fleet regression: same-platform engines with different per-slot
    sampling policies and different admission burst sizes must find every
    program warm — sampling state and burst membership are runtime data,
    never compile keys."""
    cc = CompileCache()

    def serve(sampling_for, burst_sizes):
        eng = ServingEngine(CFG, PARAMS, slots=4, max_seq=64,
                            compile_cache=cc, compile_domain="pixel_6_cpu")
        rng = np.random.default_rng(3)
        rid = 0
        for size in burst_sizes:
            for _ in range(size):
                eng.submit(Request(
                    rid=rid, sampling=sampling_for(rid),
                    prompt=rng.integers(0, CFG.vocab_size, size=int(
                        rng.integers(4, 15))).astype(np.int32),
                    max_new_tokens=3))
                rid += 1
            eng.drain()
        return eng

    # first engine warms every (bucket, k-bucket) admission program
    e0 = serve(lambda rid: None, burst_sizes=(4, 2, 1, 3))
    assert e0.stats.recompiles > 0
    # second same-platform engine: heterogeneous per-request sampling and
    # a different burst mix — compiles NOTHING
    e1 = serve(lambda rid: SamplingOpts(temperature=0.3 * rid,
                                        top_k=rid % 3, seed=rid),
               burst_sizes=(3, 1, 4, 2))
    assert e1.stats.recompiles == 0
    assert e1.stats.sampled_tokens > 0    # the sampled slots really sampled
    # another platform still pays for its own binaries
    e2 = ServingEngine(CFG, PARAMS, slots=4, max_seq=64,
                       compile_cache=cc, compile_domain="jetson_agx_orin")
    assert e2.stats.recompiles == 1


def test_earlier_cross_bucket_waiter_is_not_starved():
    """A stream of short same-bucket arrivals can share a burst's free
    slots, but the head of the queue anchors every burst — an earlier
    waiter from another bucket is always admitted before anything
    submitted behind it."""
    rng = np.random.default_rng(13)

    def short():
        return rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)

    eng = _engine("batched", slots=2)
    # occupy both slots with bucket-16 requests
    for i in range(2):
        eng.submit(Request(rid=i, prompt=short(), max_new_tokens=12))
    eng.step()
    # an odd-bucket request waits...
    other = Request(rid=100,
                    prompt=rng.integers(0, CFG.vocab_size, size=20)
                    .astype(np.int32), max_new_tokens=2)
    eng.submit(other)
    # ...while short bucket-16 requests keep arriving behind it
    late = [Request(rid=200 + i, prompt=short(), max_new_tokens=2)
            for i in range(6)]
    for r in late:
        eng.submit(r)
    eng.drain()
    assert other.done
    assert other.first_token_s is not None
    assert all(other.first_token_s < r.first_token_s for r in late)


# -------------------------------------------------------------- scheduler --
def test_queue_is_constant_time_deque_and_fifo():
    eng = _engine("batched", slots=1)
    assert isinstance(eng._queue, deque)
    reqs = _mixed_requests(4, seed=11)
    for r in reqs:
        eng.submit(r)
    # single slot → strict FIFO: rid i must finish before rid i+1 starts
    finish_order = []
    while any(eng._active) or eng._queue:
        eng.step()
        for r in reqs:
            if r.done and r.rid not in finish_order:
                finish_order.append(r.rid)
    assert finish_order == [0, 1, 2, 3]
