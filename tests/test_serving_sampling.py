"""Property suite pinning the whole serving surface.

* Batched prefill admission (one ``(k, bucket)`` jit call per same-bucket
  burst) produces token streams bit-identical to sequential per-request
  prefill, over random request mixes (lengths, buckets, admit times,
  budgets, per-request sampling policies).
* ``temperature=0`` sampling is bit-identical to the *pre-change* greedy
  decode, pinned against a manual prefill→argmax→``decode_ref``→argmax
  loop over the raw program set (exactly the historical per-slot path).
* Fixed seeds give identical streams across runs and across
  ``decode_mode="batched"``/``"per_slot"``; ``top_k=1`` equals greedy;
  a high-temperature chi-squared check that sampled tokens are not
  degenerate.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_cache, init_params
from repro.models.runtime import DEFAULT_OPTIONS
from repro.serving import (CompileCache, Request, SamplingOpts,
                           ServingEngine)

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MAX_SEQ = 64
# one cache for the whole module: every hypothesis example reuses the
# same compiled programs, so the suite compiles each program exactly once
CC = CompileCache()

SETTINGS = settings(max_examples=12, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow,
                                           HealthCheck.data_too_large])

# a request mix: (prompt length, token budget, submit-at-step, temperature)
REQ_SPEC = st.tuples(st.integers(1, 40), st.integers(1, 6),
                     st.integers(0, 3), st.sampled_from([0.0, 0.8, 1.4]))
REQ_MIXES = st.lists(REQ_SPEC, min_size=1, max_size=6)


def _prompt(length: int, rid: int) -> np.ndarray:
    rng = np.random.default_rng(31 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _requests(mix):
    return [Request(rid=i, prompt=_prompt(n, i), max_new_tokens=budget,
                    sampling=SamplingOpts(temperature=temp, seed=5))
            for i, (n, budget, _, temp) in enumerate(mix)]


def _run(mix, *, decode_mode="batched", prefill_mode="batched", slots=2):
    """Drive an engine over the mix's admit schedule; returns per-request
    streams plus the prefill accounting."""
    eng = ServingEngine(CFG, PARAMS, slots=slots, max_seq=MAX_SEQ,
                        decode_mode=decode_mode, prefill_mode=prefill_mode,
                        compile_cache=CC)
    reqs = _requests(mix)
    step = 0
    while any(not r.done for r in reqs):
        for r, (_, _, at, _) in zip(reqs, mix):
            if at == step:
                eng.submit(r)
        eng.step()
        step += 1
        assert step < 200, "engine failed to drain"
    return ([tuple(r.generated) for r in reqs], eng.stats.prefills,
            eng.stats.prefill_calls)


# ------------------------------------------------- admission equivalence --
@SETTINGS
@given(mix=REQ_MIXES, slots=st.integers(2, 3))
def test_batched_admission_matches_sequential_prefill(mix, slots):
    batched = _run(mix, prefill_mode="batched", slots=slots)
    sequential = _run(mix, prefill_mode="per_request", slots=slots)
    assert batched[0] == sequential[0]          # bit-identical streams
    assert batched[1] == sequential[1]          # same requests prefilled
    assert batched[2] <= sequential[2]          # never more jit calls


@SETTINGS
@given(mix=REQ_MIXES)
def test_batched_and_per_slot_decode_agree(mix):
    assert _run(mix, decode_mode="batched")[0] \
        == _run(mix, decode_mode="per_slot")[0]


# --------------------------------------------------- greedy equivalence --
@SETTINGS
@given(mix=st.lists(st.tuples(st.integers(1, 40), st.integers(1, 6)),
                    min_size=1, max_size=4))
def test_temperature_zero_is_bit_identical_to_prechange_greedy(mix):
    """The sampling engine at temperature 0 must reproduce the historical
    greedy decode exactly: per-bucket prefill → host argmax → batch=1
    ``decode_ref`` → host argmax, which is what the pre-sampling per-slot
    path computed."""
    programs, _ = CC.entry_for(CFG, DEFAULT_OPTIONS, 2, MAX_SEQ, "")
    reference = []
    for i, (n, budget) in enumerate(mix):
        prompt = _prompt(n, i)
        bucket = 16
        while bucket < n:
            bucket *= 2
        bucket = min(bucket, MAX_SEQ)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - n:] = prompt
        cache = init_cache(CFG, 1, MAX_SEQ, DEFAULT_OPTIONS)
        prefill_fn, _ = programs.prefill(bucket)
        logits, cache = prefill_fn(PARAMS, cache, jnp.asarray(toks))
        stream = [int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))]
        while len(stream) < budget:
            logits, cache = programs.decode_ref(
                PARAMS, cache, jnp.asarray([stream[-1]], jnp.int32))
            stream.append(int(jnp.argmax(logits[0, :CFG.vocab_size])))
            if int(cache["pos"]) >= MAX_SEQ - 1:
                break                # engine terminates after the emit
        reference.append(tuple(stream))

    greedy_mix = [(n, budget, 0, 0.0) for (n, budget) in mix]
    assert _run(greedy_mix)[0] == reference
    assert _run(greedy_mix, decode_mode="per_slot")[0] == reference


# ----------------------------------------------- sampling reproducibility --
@SETTINGS
@given(seed=st.integers(0, 2 ** 16), temp=st.sampled_from([0.6, 1.0, 1.7]),
       top_k=st.sampled_from([0, 5, 40]))
def test_fixed_keys_reproduce_across_runs_and_modes(seed, temp, top_k):
    opts = SamplingOpts(temperature=temp, top_k=top_k, seed=seed)
    mix = [(7, 6, 0, temp), (22, 5, 1, temp), (11, 4, 1, temp)]

    def run(decode_mode):
        eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=MAX_SEQ,
                            decode_mode=decode_mode, sampling=opts,
                            compile_cache=CC)
        reqs = [Request(rid=i, prompt=_prompt(n, i), max_new_tokens=b)
                for i, (n, b, _, _) in enumerate(mix)]
        step = 0
        while any(not r.done for r in reqs):
            for r, (_, _, at, _) in zip(reqs, mix):
                if at == step:
                    eng.submit(r)
            eng.step()
            step += 1
        return [tuple(r.generated) for r in reqs]

    first = run("batched")
    assert first == run("batched")             # identical across runs
    assert first == run("per_slot")            # identical across modes


def test_different_seeds_or_rids_give_different_streams():
    def stream(seed, rid):
        eng = ServingEngine(CFG, PARAMS, slots=1, max_seq=MAX_SEQ,
                            sampling=SamplingOpts(temperature=1.2, seed=seed),
                            compile_cache=CC)
        req = Request(rid=rid, prompt=_prompt(9, 0), max_new_tokens=12)
        eng.submit(req)
        eng.drain()
        return tuple(req.generated)

    assert stream(0, 0) != stream(1, 0)
    assert stream(0, 0) != stream(0, 1)


def test_top_k_one_equals_greedy():
    mix_args = dict(prompt=_prompt(13, 0), max_new_tokens=10)
    streams = {}
    for name, opts in (("greedy", SamplingOpts()),
                       ("topk1", SamplingOpts(temperature=2.5, top_k=1,
                                              seed=3))):
        eng = ServingEngine(CFG, PARAMS, slots=1, max_seq=MAX_SEQ,
                            sampling=opts, compile_cache=CC)
        req = Request(rid=0, **mix_args)
        eng.submit(req)
        eng.drain()
        streams[name] = tuple(req.generated)
    assert streams["topk1"] == streams["greedy"]


def test_high_temperature_sampling_is_not_degenerate():
    """Chi-squared sanity: at high temperature the sampled token histogram
    must be nowhere near the degenerate (single-token) distribution a
    broken sampler — or an accidental argmax path — would produce."""
    eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=MAX_SEQ,
                        sampling=SamplingOpts(temperature=5.0, seed=11),
                        compile_cache=CC)
    reqs = [Request(rid=i, prompt=_prompt(6 + i, i), max_new_tokens=50)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    tokens = [t for r in reqs for t in r.generated]
    n, v = len(tokens), CFG.vocab_size
    counts = np.bincount(tokens, minlength=v).astype(np.float64)
    expected = n / v
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    # degenerate sampling concentrates all mass on one token, which scores
    # chi2 ≈ n*v; anything vaguely spread stays far below half of that
    assert chi2 < 0.5 * n * v, f"chi2={chi2:.0f} vs degenerate {n * v}"
    assert len(set(tokens)) > 10
    assert counts.max() / n < 0.5
    assert eng.stats.sampled_tokens == n
