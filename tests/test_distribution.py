"""Distribution layer: sharding rules + a small-mesh lower/compile of the
real steps (subprocess so the forced device count never leaks into the
main test process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.sharding import leaf_spec, param_specs
from repro.launch.steps import input_specs, options_for, params_spec_struct
from repro.models.configs import INPUT_SHAPES


def test_param_specs_cover_all_archs():
    """Every leaf gets a spec, and sharded dims are divisible by 16."""
    for arch in list_archs():
        cfg = get_config(arch)
        tree = params_spec_struct(cfg)
        specs = param_specs(cfg, tree)
        flat_t = jax.tree_util.tree_leaves(tree)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_t) == len(flat_s)
        for leaf, spec in zip(flat_t, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= 16
                assert dim % size == 0, (arch, leaf.shape, spec)


def test_serve_mode_drops_fsdp():
    cfg = get_config("yi-34b")
    tree = params_spec_struct(cfg)
    train = param_specs(cfg, tree, mode="train")
    serve = param_specs(cfg, tree, mode="serve")
    t = jax.tree_util.tree_leaves(train, is_leaf=lambda x: isinstance(x, P))
    s = jax.tree_util.tree_leaves(serve, is_leaf=lambda x: isinstance(x, P))
    assert any("data" in tuple(x) for x in t)
    assert not any("data" in tuple(x) for x in s)
    assert any("model" in tuple(x) for x in s)


def test_input_specs_shapes():
    for arch in ("qwen1.5-32b", "whisper-small", "internvl2-26b",
                 "mamba2-370m"):
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            sp = input_specs(cfg, shape)
            if shape.is_decode:
                assert sp["token"].shape == (shape.global_batch,)
            else:
                assert sp["tokens"].shape == (shape.global_batch,
                                              shape.seq_len)


def test_options_for_long_decode_is_subquadratic():
    cfg = get_config("yi-34b")
    opts = options_for(cfg, INPUT_SHAPES["long_500k"])
    assert opts.decode_window > 0
    assert options_for(cfg, INPUT_SHAPES["decode_32k"]).decode_window == 0


SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import get_config
    from repro.launch.dryrun import build_args
    from repro.launch.sharding import to_shardings
    from repro.launch.steps import make_step, options_for
    from repro.models.configs import InputShape

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         devices=jax.devices()[:8])
    cfg = get_config("{arch}").reduced(num_layers=2, d_model=256)
    cfg = cfg.with_updates(vocab_size=1024)
    shape = InputShape("mini", {seq}, {batch}, "{kind}")
    opts = options_for(cfg, shape)
    step = make_step(cfg, shape, opts)
    structs, in_specs, out_specs, donate = build_args(cfg, shape, mesh, opts)
    with mesh:
        compiled = jax.jit(step, in_shardings=to_shardings(in_specs, mesh),
                           out_shardings=to_shardings(out_specs, mesh),
                           donate_argnums=donate).lower(*structs).compile()
    print("COMPILED_OK", compiled.as_text().count(chr(10)) > 0)
""")


@pytest.mark.parametrize("arch,kind", [
    ("qwen1.5-32b", "train"), ("olmoe-1b-7b", "decode"),
    ("mamba2-370m", "prefill"), ("zamba2-1.2b", "decode"),
])
def test_reduced_step_compiles_on_8way_mesh(arch, kind):
    """Lower+compile the real step for a reduced config on a 2x4 mesh in a
    subprocess (device-count isolation)."""
    prog = SUBPROCESS_PROG.format(arch=arch, kind=kind,
                                  seq=64, batch=8)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert "COMPILED_OK True" in r.stdout, r.stderr[-2000:]
