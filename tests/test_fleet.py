"""Fleet subsystem: trace determinism, registry, telemetry calibration,
and the FleetController's crowd-shared feedback loop."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Calibration, ResourceContext, case_study_trace,
                        constant_trace, dvfs_spike_trace, shape_context)
from repro.fleet import (ENGINE, FleetController, HEAVY, LIGHT, MEDIUM,
                         PLATFORMS, SIMULATED, TIERS, EwmaLsqCalibrator,
                         TelemetryStore, build_fleet, device_trace,
                         fleet_report, make_device)
from repro.fleet.telemetry import MeasurementRecord
from repro.models.configs import InputShape

CFG = get_config("paper-backbone")
SHAPE = InputShape("fleet_t", 256, 4, "prefill")


# ------------------------------------------------------ trace determinism --
def test_case_study_trace_deterministic_under_seed():
    a = list(case_study_trace(24, seed=3))
    b = list(case_study_trace(24, seed=3))
    assert a == b
    c = list(case_study_trace(24, seed=4))
    assert a != c


def test_dvfs_spike_trace_deterministic():
    a = list(dvfs_spike_trace(10))
    b = list(dvfs_spike_trace(10))
    assert a == b
    derates = [ctx.cpu_temp_derate for ctx in a]
    assert min(derates) < 1.0 and derates[0] == 1.0 and derates[-1] == 1.0


def test_shape_context_respects_envelope():
    ctx = ResourceContext(battery_frac=0.8, mem_free_frac=0.9,
                          cpu_temp_derate=0.5)
    shaped = shape_context(ctx, battery_scale=0.5, mem_scale=0.5,
                           derate_floor=0.7, chips=2, extra_procs=1)
    assert shaped.battery_frac == pytest.approx(0.4)
    assert shaped.mem_free_frac == pytest.approx(0.45)
    assert shaped.cpu_temp_derate == 0.7          # floored
    assert shaped.chips_available == 2
    assert shaped.competing_procs == 1


# --------------------------------------------------------------- registry --
def test_registry_spans_three_tiers_with_15_platforms():
    assert len(PLATFORMS) == 15
    for tier in TIERS:
        assert any(p.tier == tier for p in PLATFORMS.values())


def test_build_fleet_deterministic_and_heterogeneous():
    a = build_fleet(12, seed=0)
    b = build_fleet(12, seed=0)
    assert [d.device_id for d in a] == [d.device_id for d in b]
    assert [d.latent_latency_factor for d in a] \
        == [d.latent_latency_factor for d in b]
    assert {d.tier for d in a} == set(TIERS)
    # small fleets interleave tiers too
    assert {d.tier for d in build_fleet(3, seed=0)} == set(TIERS)


def test_device_trace_deterministic_and_enveloped():
    spec = make_device("cortex_a55_quad", 0, seed=1)
    a = list(device_trace(spec, 12))
    b = list(device_trace(spec, 12))
    assert a == b
    assert all(ctx.cpu_temp_derate >= spec.dvfs_floor for ctx in a)
    assert all(ctx.chips_available == spec.chips for ctx in a)


# -------------------------------------------------------------- telemetry --
def test_calibrator_recovers_affine_truth():
    cal = EwmaLsqCalibrator(min_lsq_samples=8)
    rng = np.random.default_rng(0)
    for _ in range(32):
        p = float(rng.uniform(0.5, 2.0))
        o = 1.4 * p + 0.1
        cal.observe(p, o, p, 1.2 * p)
    c = cal.calibration()
    assert c.latency_scale == pytest.approx(1.4, rel=0.05)
    assert c.latency_bias_s == pytest.approx(0.1, rel=0.1)
    assert c.energy_scale == pytest.approx(1.2, rel=0.05)
    assert c.latency(1.0) == pytest.approx(1.5, rel=0.05)


def test_telemetry_mape_drops_with_calibration():
    store = TelemetryStore()
    rng = np.random.default_rng(1)
    for i in range(40):
        p = float(rng.uniform(0.1, 1.0))
        store.record(MeasurementRecord(
            device_id="d0", tier=LIGHT, tick=i,
            predicted_latency_s=p, observed_latency_s=1.6 * p,
            predicted_energy_j=p, observed_energy_j=1.5 * p))
    before = store.mape(tier=LIGHT)
    after = store.mape(tier=LIGHT,
                       calibration=store.calibration_for_tier(LIGHT))
    assert before > 0.3
    assert after < 0.05 < before


# ------------------------------------------------- per-channel pooling ----
def test_channel_pooling_prevents_cross_contamination():
    """Engine wall-times and simulated-silicon observations live on
    unrelated scales; pooling them into one tier fit used to wreck both."""
    store = TelemetryStore()
    rng = np.random.default_rng(2)
    for i in range(32):
        p = float(rng.uniform(0.1, 1.0))
        store.record(MeasurementRecord(
            device_id="sim0", tier=LIGHT, tick=i,
            predicted_latency_s=p, observed_latency_s=1.6 * p,
            predicted_energy_j=p, observed_energy_j=1.5 * p))
        # an engine-backed peer reporting ~constant millisecond step times
        store.record(MeasurementRecord(
            device_id="eng0", tier=LIGHT, tick=i,
            predicted_latency_s=p, observed_latency_s=2e-3,
            predicted_energy_j=p, observed_energy_j=2e-2,
            channel=ENGINE))
    sim = store.calibration_for_tier(LIGHT)              # default: simulated
    assert sim.latency(1.0) == pytest.approx(1.6, rel=0.05)
    eng = store.calibration_for_tier(LIGHT, ENGINE)
    assert eng.latency(0.5) == pytest.approx(2e-3, rel=0.3)
    assert store.device_channel("eng0") == ENGINE
    assert store.device_channel("sim0") == SIMULATED
    # channel-filtered MAPE sees only its own records
    assert store.mape(tier=LIGHT, channel=SIMULATED,
                      calibration=sim) < 0.05


class _FakeEngine:
    """Duck-typed ServingEngine: always busy, constant step wall-time."""

    def __init__(self, step_s: float):
        self.has_work = True
        self.step_times = []
        self._dt = step_s

    def step(self) -> None:
        self.step_times.append(self._dt)


def test_mixed_channel_fleet_keeps_simulated_fit_clean():
    fleet = build_fleet(6, seed=0)
    lights = [d for d in fleet if d.tier == LIGHT]
    assert len(lights) >= 2
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=16, warmup_ticks=4)
    # wall-clock steps ~6 orders of magnitude off the analytic scale —
    # any cross-contamination would be unmissable
    ctl.attach_engine(lights[0].device_id, _FakeEngine(2e-3))
    ctl.run(16)
    sim_cal = ctl.telemetry.calibration_for_tier(LIGHT)
    # the simulated light-tier fit still recovers the remaining device's
    # latent silicon bias, unpolluted by the engine's wall-times
    assert sim_cal.latency_scale == pytest.approx(
        lights[1].latent_latency_factor, rel=0.1)
    # and each device's loop got its own channel's correction
    eng_cal = ctl.calibration_of(lights[0].device_id)
    assert eng_cal == ctl.telemetry.calibration_for_tier(LIGHT, ENGINE)
    assert ctl.calibration_of(lights[1].device_id) == sim_cal
    assert eng_cal != sim_cal


# ---------------------------------------------- fleet-level compile cache --
def test_same_platform_fleet_engines_share_compiled_programs():
    import jax as _jax
    from repro.models.model import init_params as _init_params
    tiny = CFG.with_updates(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=300)
    tparams = _init_params(tiny, _jax.random.PRNGKey(0))
    fleet = [make_device("pixel_6_cpu", 0), make_device("pixel_6_cpu", 1),
             make_device("raspberry_pi4", 0)]
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=8)

    def serve_on(device_id):
        from repro.serving import Request
        eng = ctl.build_engine(device_id, tparams, cfg=tiny, slots=2,
                               max_seq=64)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, 300, size=8).astype(np.int32), max_new_tokens=4))
        eng.drain()
        return eng

    e0 = serve_on("pixel_6_cpu#0")
    assert e0.stats.recompiles > 0           # first engine builds programs
    e1 = serve_on("pixel_6_cpu#1")
    assert e1.stats.recompiles == 0          # same platform: zero compiles
    assert e1.stats.tokens_out == e0.stats.tokens_out
    e2 = serve_on("raspberry_pi4#0")
    assert e2.stats.recompiles > 0           # cross-platform: own programs


# ------------------------------------------------------- fleet controller --
@pytest.fixture(scope="module")
def fleet_run():
    fleet = build_fleet(12, seed=0)
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=24)
    ctl.run(24)
    return ctl


def test_violations_decrease_after_calibration_warmup(fleet_run):
    ctl = fleet_run
    rep = fleet_report(ctl)
    assert rep.violations_second_half < rep.violations_first_half


def test_calibration_reduces_prediction_error(fleet_run):
    rep = fleet_report(fleet_run)
    for t in rep.tiers:
        assert not math.isnan(t.mape_before)
        assert t.mape_after < t.mape_before


def test_same_tier_devices_share_calibration(fleet_run):
    ctl = fleet_run
    by_tier = {}
    for spec in ctl.devices:
        by_tier.setdefault(spec.tier, []).append(spec.device_id)
    cals = {}
    for tier, ids in by_tier.items():
        assert len(ids) >= 2, f"fleet should have ≥2 {tier} devices"
        tier_cals = [ctl.calibration_of(i) for i in ids]
        assert all(c is not None for c in tier_cals)
        assert all(c == tier_cals[0] for c in tier_cals), \
            f"{tier} devices diverged: {tier_cals}"
        cals[tier] = tier_cals[0]
    # ...but the *tiers* learned different corrections
    scales = [c.latency_scale for c in cals.values()]
    assert len({round(s, 3) for s in scales}) == len(scales)


def test_per_device_calibration_when_sharing_disabled():
    fleet = build_fleet(6, seed=0)
    ctl = FleetController(fleet, CFG, SHAPE, trace_ticks=16,
                          share_calibration=False, warmup_ticks=4)
    ctl.run(16)
    same_tier = [d for d in fleet if d.tier == HEAVY]
    assert len(same_tier) >= 2
    c0 = ctl.calibration_of(same_tier[0].device_id)
    c1 = ctl.calibration_of(same_tier[1].device_id)
    assert c0 != c1                    # each learned its own silicon


def test_tier_decisions_diverge_for_same_context(fleet_run):
    ctl = fleet_run
    probe = ResourceContext(battery_frac=0.95, mem_free_frac=0.7)
    chosen = {}
    for spec in ctl.devices:
        if spec.tier in chosen:
            continue
        chosen[spec.tier] = ctl.probe_loop(spec).tick(probe).action
    assert len(chosen) == 3
    assert len(set(chosen.values())) > 1


def test_controller_run_is_deterministic():
    r1 = FleetController(build_fleet(6, seed=0), CFG, SHAPE,
                         trace_ticks=12, seed=0)
    r1.run(12)
    r2 = FleetController(build_fleet(6, seed=0), CFG, SHAPE,
                         trace_ticks=12, seed=0)
    r2.run(12)
    a = [(r.device_id, r.observed_s, r.violated) for r in r1.records]
    b = [(r.device_id, r.observed_s, r.violated) for r in r2.records]
    assert a == b


# --------------------------------------------------- core calibration hook --
def test_evaluator_applies_installed_calibration():
    from repro.core import ActionEvaluator, TPU_V5E
    from repro.core.actions import Action
    ev = ActionEvaluator(CFG, SHAPE, TPU_V5E)
    ctx = ResourceContext()
    raw = ev.evaluate(Action(), ctx)
    ev.calibration = Calibration(latency_scale=2.0, latency_bias_s=0.01,
                                 energy_scale=1.5, samples=10)
    cal = ev.evaluate(Action(), ctx)
    assert cal.latency_s == pytest.approx(2.0 * raw.latency_s + 0.01)
    assert cal.energy_j == pytest.approx(1.5 * raw.energy_j)
    raw2 = ev.evaluate(Action(), ctx, calibrate=False)
    assert raw2.latency_s == pytest.approx(raw.latency_s)
