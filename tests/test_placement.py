"""Fleet-aware cross-device placement: topology links, live profile
synthesis, FleetPlacer search/hysteresis/migration, controller
re-placement clock events, failure modes, and the telemetry accuracy
channel feeding ``ActionEvaluator.measured``."""
import math

import pytest

from repro.configs import get_config
from repro.core.monitor import ResourceContext, constant_trace
from repro.core.optimizer import DRIFT_ACCURACY_COST
from repro.elastic.operators import FULL_SPEC
from repro.fleet import (LIGHT, AccuracyRecord, FleetController,
                         FleetPlacer, LinkSpec, SiteTopology,
                         TelemetryStore, build_fleet, make_device)
from repro.fleet.placement import (FALLBACK, INFEASIBLE, PLACED,
                                   MemberState, synthesize_profile)
from repro.models.configs import InputShape
from repro.offload import DEVICE_POOLS, NO_NEXT_LINK, place_dp

CFG = get_config("paper-backbone")
SHAPE = InputShape("fleet_t", 256, 4, "prefill")
LOADED = ResourceContext(cpu_temp_derate=0.45, competing_procs=4,
                         battery_frac=0.8, mem_free_frac=0.7)


def _trio():
    """Loaded phone + idle same-site jetson + idle cross-site server."""
    phone = make_device("pixel_6_cpu", 0, site="home")
    jetson = make_device("jetson_agx_orin", 0, site="home")
    far = make_device("edge_server_a100", 0, site="dc")
    return phone, jetson, far


def _placer(*specs, **kw):
    placer = FleetPlacer(CFG, **kw)
    for s in specs:
        placer.register(s)
    return placer


# ---------------------------------------------------------------- topology --
def test_topology_lan_wan_and_overrides():
    a = make_device("pixel_6_cpu", 0, site="home")
    b = make_device("jetson_agx_orin", 0, site="home")
    c = make_device("edge_server_a100", 0, site="dc")
    topo = SiteTopology()
    assert topo.same_site(a, b) and not topo.same_site(a, c)
    assert topo.link_between(a, b) is topo.lan
    assert topo.link_between(a, c) is topo.wan
    fat = LinkSpec(bandwidth_bytes_s=1e9, rtt_s=1e-3, kind="fiber")
    topo2 = SiteTopology(overrides={("dc", "home"): fat})
    assert topo2.link_between(a, c) is fat
    assert topo2.link_between(c, a) is fat        # unordered pair


def test_link_effective_bw_folds_rtt():
    link = LinkSpec(bandwidth_bytes_s=1e8, rtt_s=0.02)
    # tiny tensors are RTT-dominated: effective bw collapses
    assert link.effective_bw(1e3) < 1e5
    # huge tensors approach the wire rate
    assert link.effective_bw(1e9) == pytest.approx(1e8, rel=0.01)
    assert link.transfer_s(1e8) == pytest.approx(1.02)


def test_build_fleet_assigns_sites_round_robin():
    fleet = build_fleet(6, seed=0, sites=("a", "b"))
    assert [d.site for d in fleet] == ["a", "b", "a", "b", "a", "b"]
    # default: legacy single-site fleet
    assert {d.site for d in build_fleet(4, seed=0)} == {"site0"}


def test_no_next_link_sentinel_terminates_static_pools():
    for pool in DEVICE_POOLS.values():
        assert pool[-1].link_bw == NO_NEXT_LINK


# ------------------------------------------------------------ live profiles --
def test_profile_derates_with_calibration_and_context():
    from repro.core.profiler import Calibration
    spec = make_device("jetson_agx_orin", 0)
    idle = MemberState(spec=spec)
    base = synthesize_profile(idle)
    assert base.name == spec.device_id
    cal = Calibration(latency_scale=2.0, samples=16)
    slowed = synthesize_profile(MemberState(spec=spec, calibration=cal))
    assert slowed.flops == pytest.approx(base.flops / 2.0)
    throttled = synthesize_profile(MemberState(
        spec=spec, ctx=ResourceContext(cpu_temp_derate=0.5)))
    assert throttled.flops == pytest.approx(base.flops / 2.0)
    squeezed = synthesize_profile(MemberState(
        spec=spec, ctx=ResourceContext(mem_free_frac=0.5)))
    assert squeezed.mem_bytes == pytest.approx(base.mem_bytes / 2.0)


def test_multi_tenant_host_looks_slower_to_third_requester():
    """A jetson already helping two phones must advertise less capacity
    to the next one."""
    phone, jetson, _ = _trio()
    p2 = make_device("pixel_6_cpu", 1, site="home")
    p3 = make_device("pixel_6_cpu", 2, site="home")
    placer = _placer(phone, jetson, p2, p3)
    for p in (phone, p2, p3):
        placer.update_member(p.device_id, ctx=LOADED)
    d1 = placer.place(phone.device_id)
    d2 = placer.place(p2.device_id)
    d3 = placer.place(p3.device_id)
    assert d1.reason == PLACED and d1.hosts[1] == jetson.device_id
    assert placer.member(jetson.device_id).tenant_load() > 0
    # each successive tenant sees a busier host → worse predicted latency
    assert d2.latency_s > d1.latency_s
    assert d3.latency_s > d2.latency_s


# ------------------------------------------------- placer: the acceptance ---
def test_fleet_placement_beats_local_and_static_pool():
    """The ISSUE's headline: a loaded phone with an idle same-site
    helper must beat both local-only execution and the static
    ``edge_pair`` pool on predicted end-to-end latency."""
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    placer.update_member(phone.device_id, ctx=LOADED)
    dec = placer.place(phone.device_id)
    assert dec.reason == PLACED
    # same-site jetson, not the faster-but-WAN-remote server
    assert dec.hosts == (phone.device_id, jetson.device_id)
    local = placer.local_decision(phone.device_id)
    static = place_dp(placer.pp, DEVICE_POOLS["edge_pair"])
    assert dec.latency_s < 0.5 * local.latency_s
    assert dec.latency_s < static.latency_s


def test_same_site_helpers_rank_before_cross_site():
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    cands = placer.candidate_helpers(phone.device_id)
    assert cands[0] == jetson.device_id      # LAN before WAN, despite
    assert far.device_id in cands            # the a100's raw capability


def test_migration_cost_charged_on_new_hosts_only():
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    placer.update_member(phone.device_id, ctx=LOADED)
    first = placer.place(phone.device_id)
    assert first.migration_s > 0             # params must ship to jetson
    again = placer.place(phone.device_id)
    # same hosts, same cuts → nothing moves
    assert again.hosts == first.hosts
    assert again.migration_s == 0.0 or again.reason == "hold"


# ------------------------------------------------------------ failure modes --
def test_helper_disappears_mid_run_falls_back_to_local():
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    placer.update_member(phone.device_id, ctx=LOADED)
    dec = placer.place(phone.device_id)
    assert dec.offloaded
    affected = placer.remove_member(jetson.device_id)
    assert affected == [phone.device_id]
    cur = placer.current(phone.device_id)
    assert cur.hosts == (phone.device_id,) and cur.reason == FALLBACK
    # the evaluator-facing resolver drops the dead peer instead of
    # crashing the optimizer
    profs = placer.resolve_profiles(dec.hosts)
    assert [p.name for p in profs] == [phone.device_id]
    # next sweep re-places onto whatever is left (the WAN server or
    # local) without raising
    nxt = placer.place(phone.device_id)
    assert jetson.device_id not in nxt.hosts


def test_controller_drop_device_falls_back_and_keeps_running():
    phone, jetson, far = _trio()

    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone.device_id
            else ResourceContext(), n)

    ctl = FleetController([phone, jetson, far], CFG, SHAPE,
                          trace_ticks=400, trace_factory=tf,
                          placement=True, allow_offload=False,
                          warmup_ticks=4, recalibrate_every=2)
    ctl.set_sla(phone.device_id, 0.5)
    ctl.run_for(6.0)
    assert ctl.placement_of(phone.device_id).offloaded
    t_drop = ctl.now_s
    affected = ctl.drop_device(jetson.device_id)
    assert affected == [phone.device_id]
    assert ctl.placement_of(phone.device_id).hosts == (phone.device_id,)
    before = len(ctl.records)
    ctl.run_for(6.0)                        # keeps running, no crash
    assert len(ctl.records) > before
    post = [r for r in ctl.records if r.device_id == phone.device_id
            and r.timestamp_s > t_drop]
    assert post, "phone stopped waking after the helper died"
    # the dead helper never reappears in a post-drop decision
    for r in post:
        assert jetson.device_id not in r.decision.action.offload.peers


def test_departed_requester_releases_its_helpers():
    """A requester that leaves the fleet must stop counting against its
    helpers' capacity — dead tenants would permanently derate them."""
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    placer.update_member(phone.device_id, ctx=LOADED)
    assert placer.place(phone.device_id).offloaded
    assert placer.member(jetson.device_id).tenant_load() > 0
    placer.remove_member(phone.device_id)
    assert placer.member(jetson.device_id).tenant_load() == 0


def test_lockstep_drop_device_stops_ticking():
    """A dropped member must stop waking under lockstep too — a dead
    device emitting telemetry would contaminate its tier's fit."""
    phone, jetson, far = _trio()
    ctl = FleetController([phone, jetson, far], CFG, SHAPE,
                          trace_ticks=16, placement=True,
                          allow_offload=False, warmup_ticks=2,
                          recalibrate_every=2, step_mode="lockstep")
    ctl.run(4)
    ctl.drop_device(jetson.device_id)
    n = sum(1 for r in ctl.records if r.device_id == jetson.device_id)
    ctl.run(4)
    assert sum(1 for r in ctl.records
               if r.device_id == jetson.device_id) == n


def test_memory_infeasible_fleet_subset_never_raises():
    phone, jetson, far = _trio()
    placer = _placer(phone, jetson, far)
    starving = ResourceContext(mem_free_frac=1e-9)
    for s in (phone, jetson, far):
        placer.update_member(s.device_id, ctx=starving)
    dec = placer.place(phone.device_id)
    assert dec.reason == INFEASIBLE
    assert math.isinf(dec.latency_s) and dec.placement is None


def test_hysteresis_prevents_ping_pong_between_near_equal_helpers():
    """Two near-identical helpers: tiny alternating load nudges must
    never flip the placement back and forth."""
    phone = make_device("pixel_6_cpu", 0, site="home")
    j0 = make_device("jetson_agx_orin", 0, site="home")
    j1 = make_device("jetson_agx_orin", 1, site="home")
    placer = _placer(phone, j0, j1, hysteresis=0.15)
    placer.update_member(phone.device_id, ctx=LOADED)
    first = placer.place(phone.device_id)
    assert first.offloaded
    chosen = first.hosts[1]
    other = j1.device_id if chosen == j0.device_id else j0.device_id
    hosts_seen = {first.hosts}
    for i in range(6):
        # nudge the *chosen* helper slightly busier than the other —
        # a sub-hysteresis difference that would flip a greedy placer
        placer.update_member(chosen, own_load=0.04 if i % 2 == 0 else 0.0)
        placer.update_member(other, own_load=0.0 if i % 2 == 0 else 0.04)
        dec = placer.place(phone.device_id)
        hosts_seen.add(dec.hosts)
    assert hosts_seen == {first.hosts}, \
        f"placement ping-ponged: {hosts_seen}"


def test_large_load_shift_does_replace():
    """Hysteresis must not freeze the placement forever: a big genuine
    slowdown of the chosen helper moves the work."""
    phone = make_device("pixel_6_cpu", 0, site="home")
    j0 = make_device("jetson_agx_orin", 0, site="home")
    j1 = make_device("jetson_agx_orin", 1, site="home")
    placer = _placer(phone, j0, j1)
    placer.update_member(phone.device_id, ctx=LOADED)
    first = placer.place(phone.device_id)
    chosen = first.hosts[1]
    placer.update_member(chosen, own_load=0.9)
    dec = placer.place(phone.device_id)
    assert dec.hosts != first.hosts
    assert chosen not in dec.hosts


# -------------------------------------------- controller re-placement event --
@pytest.fixture(scope="module")
def placed_run():
    phone = make_device("pixel_6_cpu", 0, site="home")
    j0 = make_device("jetson_agx_orin", 0, site="home")
    j1 = make_device("jetson_agx_orin", 1, site="home")

    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone.device_id
            else ResourceContext(), n)

    ctl = FleetController([phone, j0, j1], CFG, SHAPE, trace_ticks=400,
                          trace_factory=tf, placement=True,
                          allow_offload=False, warmup_ticks=4,
                          recalibrate_every=2)
    ctl.set_sla(phone.device_id, 0.5)
    ctl.run_for(8.0)
    return ctl, phone, j0, j1


def test_loaded_phone_offloads_and_latency_collapses(placed_run):
    ctl, phone, _, _ = placed_run
    dec = ctl.placement_of(phone.device_id)
    assert dec.offloaded and len(dec.hosts) == 2
    recs = [r for r in ctl.records if r.device_id == phone.device_id]
    assert recs[-1].decision.action.offload.enabled
    assert recs[-1].decision.action.offload.peers == dec.hosts
    # end-to-end observed latency collapses vs the first (local) wake
    assert recs[-1].observed_s < 0.05 * recs[0].observed_s


def test_replacement_is_a_clock_event_with_bounded_reaction(placed_run):
    """After a simulated helper slowdown the controller must re-place
    within a bounded number of clock events (device wakes)."""
    ctl, phone, j0, j1 = placed_run
    before = ctl.placement_of(phone.device_id)
    chosen = before.hosts[1]
    w0 = ctl.wakes
    ctl.inject_load(chosen, 0.9)            # helper's owner starts a game
    ctl.run_for(4.0)
    after = ctl.placement_of(phone.device_id)
    assert after.hosts != before.hosts
    assert chosen not in after.hosts
    moves = [(ts, w) for ts, w, d in ctl.placement_log
             if d.requester == phone.device_id and w >= w0]
    assert moves, "no re-placement logged after the slowdown"
    reaction_events = moves[0][1] - w0
    # bounded: the pulled-forward placement wake fires before the fleet
    # completes two full rounds of device wakes
    assert reaction_events <= 2 * len(ctl.devices)


def test_placement_report_surfaces_decisions(placed_run):
    from repro.fleet import fleet_report
    ctl, phone, _, _ = placed_run
    rep = fleet_report(ctl)
    assert rep.placement_events > 0
    assert phone.device_id in rep.placements
    assert "->" in rep.placements[phone.device_id]
    assert phone.device_id in rep.render()


def test_lockstep_mode_places_on_recalibration_cadence():
    phone, jetson, far = _trio()

    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone.device_id
            else ResourceContext(), n)

    ctl = FleetController([phone, jetson, far], CFG, SHAPE,
                          trace_ticks=16, trace_factory=tf,
                          placement=True, allow_offload=False,
                          warmup_ticks=4, recalibrate_every=2,
                          step_mode="lockstep")
    ctl.set_sla(phone.device_id, 0.5)
    ctl.run(12)
    assert ctl.placement_events > 0
    assert ctl.placement_of(phone.device_id).offloaded


# ------------------------------------------------- accuracy channel ---------
def test_store_accuracy_channel_backs_out_modeled_drift():
    store = TelemetryStore()
    truth = 0.70                     # drift-free crowd accuracy
    for i in range(24):
        drift = 0.5 * (i % 3) / 2.0
        store.record_accuracy(AccuracyRecord(
            device_id="d0", tier=LIGHT, tick=i, variant="v",
            predicted_accuracy=0.76,
            observed_accuracy=truth - DRIFT_ACCURACY_COST * drift,
            drift=drift, timestamp_s=float(i)))
    est = store.measured_accuracy_for_tier(LIGHT)
    assert est["v"] == pytest.approx(truth, abs=1e-6)
    # MAE with the crowd estimate beats the raw proxy's
    before = store.accuracy_mae(tier=LIGHT)
    after = store.accuracy_mae(tier=LIGHT, measured=est)
    assert after < 0.01 < before


def test_crowd_measured_accuracy_reduces_drift_regression():
    """The drift regression test of the ROADMAP item: predictions made
    with the crowd-fed ``measured`` dict track observed accuracy far
    better than the raw proxy did, and the evaluator actually consumes
    the feedback."""
    fleet = build_fleet(6, seed=0)
    drifty = ResourceContext(data_drift=0.6, battery_frac=0.9)
    ctl = FleetController(
        fleet, CFG, SHAPE, trace_ticks=16, warmup_ticks=4,
        recalibrate_every=2,
        trace_factory=lambda spec, n: constant_trace(drifty, n))
    ctl.run(16)
    assert ctl.telemetry.accuracy_records
    ev = ctl.loop_for(fleet[0].device_id).evaluator
    assert ev.measured, "accuracy feedback never reached the evaluator"
    # the crowd estimate sits below the optimistic proxy (latent bias)
    assert ev.measured[FULL_SPEC] < ev.proxy_accuracy(FULL_SPEC)
    for tier in {d.tier for d in fleet}:
        est = ctl.telemetry.measured_accuracy_for_tier(tier)
        before = ctl.telemetry.accuracy_mae(tier=tier)
        after = ctl.telemetry.accuracy_mae(tier=tier, measured=est)
        assert after < before
