"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (act_dequant, act_quant, flash_attention, fused_ffn,
                           ssd_scan)
from repro.kernels import ref


@pytest.mark.parametrize("m,n", [(128, 256), (256, 512), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_act_quant_matches_ref(m, n, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(m + n), (m, n)) * 3).astype(dtype)
    q, s = act_quant(x, interpret=True, block_m=64, block_n=128)
    qr, sr = ref.act_quant_ref(x)
    # identical up to +-1 level on round-half ties (f32 association order)
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1
    assert (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # roundtrip error bounded by scale/2 per element
    xd = act_dequant(q, s, out_dtype=jnp.float32, interpret=True,
                     block_m=64, block_n=128)
    err = jnp.abs(xd - x.astype(jnp.float32))
    bound = jnp.repeat(s, 128, axis=-1) * 0.51 + 1e-6
    assert bool(jnp.all(err <= bound))


@pytest.mark.parametrize("m,d,f", [(128, 64, 256), (256, 128, 512),
                                   (64, 96, 128)])
@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_fused_ffn_matches_ref(m, d, f, activation):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (m, d), jnp.float32) * 0.5
    wg = jax.random.normal(ks[1], (d, f)) * 0.1
    wu = jax.random.normal(ks[2], (d, f)) * 0.1
    wd = jax.random.normal(ks[3], (f, d)) * 0.1
    y = fused_ffn(x, wg, wu, wd, activation=activation, interpret=True,
                  block_m=64, block_f=128)
    yr = ref.fused_ffn_ref(x, wg, wu, wd, activation)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=1e-4)


def test_fused_ffn_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = (jax.random.normal(ks[0], (128, 64)) * 0.5).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[1], (64, 256)) * 0.1).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[2], (64, 256)) * 0.1).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[3], (256, 64)) * 0.1).astype(jnp.bfloat16)
    y = fused_ffn(x, wg, wu, wd, interpret=True, block_m=64, block_f=128)
    yr = ref.fused_ffn_ref(x, wg, wu, wd, "silu")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=3e-2)


@pytest.mark.parametrize("s,hd", [(256, 64), (512, 128), (128, 32)])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_matches_ref(s, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    bh = 4
    q = jax.random.normal(ks[0], (bh, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, hd), jnp.float32)
    o = flash_attention(q, k, v, causal=True, window=window,
                        block_q=128, block_k=128, interpret=True)
    orf = ref.flash_attn_ref(q[None].reshape(1, bh, s, hd),
                             k.reshape(1, bh, s, hd),
                             v.reshape(1, bh, s, hd),
                             causal=True, window=window)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 64)) for kk in ks)
    o = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                        interpret=True)
    orf = ref.flash_attn_ref(q.reshape(1, 2, 128, 64),
                             k.reshape(1, 2, 128, 64),
                             v.reshape(1, 2, 128, 64), causal=False)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-5)


@pytest.mark.parametrize("s,p,n,chunk", [(64, 16, 8, 16), (128, 32, 16, 32),
                                         (96, 8, 4, 32)])
def test_ssd_scan_matches_ref(s, p, n, chunk):
    bh = 3
    ks = jax.random.split(jax.random.PRNGKey(s + p), 5)
    x = jax.random.normal(ks[0], (bh, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.2)
    b = jax.random.normal(ks[3], (bh, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bh, s, n)) * 0.5
    y, st = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd_scan_kernel_ref(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=1e-4, rtol=1e-3)


def test_ssd_scan_chunk_invariance():
    """The kernel result must not depend on the chunk size."""
    bh, s, p, n = 2, 128, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (bh, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.2)
    b = jax.random.normal(ks[3], (bh, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bh, s, n)) * 0.5
    y16, st16 = ssd_scan(x, dt, a, b, c, chunk=16, interpret=True)
    y64, st64 = ssd_scan(x, dt, a, b, c, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st16), np.asarray(st64),
                               atol=1e-4, rtol=1e-3)


def test_ops_dispatch_cpu_fallback():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    q1, s1 = ops.quantize_activations(x, use_pallas=False)
    q2, s2 = ops.quantize_activations(x, use_pallas=True, interpret=True)
    assert int(jnp.abs(q1.astype(jnp.int32) - q2.astype(jnp.int32)).max()) <= 1


@pytest.mark.parametrize("m,n", [(64, 256), (128, 512)])
def test_act_quant4_matches_engine_codec(m, n):
    from repro.engine import quantize_int4
    from repro.kernels import act_quant4
    x = jax.random.normal(jax.random.PRNGKey(m * n), (m, n)) * 2
    packed, s = act_quant4(x, interpret=True, block_m=64, block_n=128)
    ref_packed, ref_s = quantize_int4(x)
    # engine codec blocks over the flattened last dim identically
    diff = np.asarray(packed, np.int32) - np.asarray(ref_packed, np.int32)
    # allow rare +-1-level tie differences in EITHER nibble
    lo = np.abs((diff & 0xF).astype(np.int8))
    assert (np.minimum(lo, 16 - lo) <= 1).all()
    np.testing.assert_allclose(np.asarray(s),
                               np.asarray(ref_s.reshape(s.shape)), rtol=1e-5)


# ------------------------------------------------ fully-masked-row guard --
def test_flash_kv_len_zero_outputs_exactly_zero():
    """Regression: a fully-masked query row used to finalize to the
    uniform average of its (masked) keys — ``m_new == NEG_INF`` makes
    ``exp(s - m_new) == exp(0) == 1`` for every key.  With the guard the
    row is exactly zero, in kernel and oracle alike."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (2, 64, 32)) for kk in ks)
    o = flash_attention(q, k, v, kv_len=0, block_q=32, block_k=32,
                        interpret=True)
    assert bool(jnp.all(o == 0.0))
    orf = ref.flash_attn_ref(q[:, None], k[:, None], v[:, None], kv_len=0)
    assert bool(jnp.all(orf == 0.0))


def test_flash_window_beyond_kv_len_rows_are_zero():
    """window=1 + kv_len: row i's only candidate key is column i, which
    is masked for i >= kv_len — those rows must be exactly zero while
    earlier rows still attend themselves (softmax over one key == v)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = (jax.random.normal(kk, (2, 64, 32)) for kk in ks)
    kv_len = 24
    o = flash_attention(q, k, v, window=1, kv_len=kv_len,
                        block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o[:, :kv_len]),
                               np.asarray(v[:, :kv_len]), atol=2e-6)
    assert bool(jnp.all(o[:, kv_len:] == 0.0))
    orf = ref.flash_attn_ref(q[:, None], k[:, None], v[:, None],
                             window=1, kv_len=kv_len)[:, 0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-6)


def test_flash_kv_len_matches_truncated_cache():
    """kv_len masking must equal physically truncating the KV to
    kv_len for every row that still has valid keys."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (2, 64, 32)) for kk in ks)
    kv_len = 32
    o = flash_attention(q, k, v, kv_len=kv_len, block_q=32, block_k=32,
                        interpret=True)
    # rows < kv_len see the identical causal prefix
    o_trunc = flash_attention(q[:, :kv_len], k[:, :kv_len], v[:, :kv_len],
                              block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o[:, :kv_len]),
                               np.asarray(o_trunc), atol=2e-5, rtol=1e-4)


# ---------------------------------------------------- sliding-window edges --
def test_window_one_attends_self_only():
    """window=1, causal: the valid set (i-1, i] is exactly {i}, so every
    output row is its own value row (softmax over one key)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (jax.random.normal(kk, (2, 64, 32)) for kk in ks)
    o = flash_attention(q, k, v, window=1, block_q=32, block_k=32,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(v), atol=2e-6)


def test_window_geq_seq_equals_plain_causal():
    """A window that covers the whole sequence is a no-op."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (2, 64, 32)) for kk in ks)
    o_w = flash_attention(q, k, v, window=64, block_q=32, block_k=32,
                          interpret=True)
    o_c = flash_attention(q, k, v, window=0, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_c), atol=1e-6)
    o_big = flash_attention(q, k, v, window=1000, block_q=32, block_k=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(o_big), np.asarray(o_c), atol=1e-6)


def test_noncausal_window_semantics():
    """causal=False + window=w keeps only the *lower* bound: row i
    attends every key in (i-w, S) — lookback is clipped, lookahead is
    unlimited.  Pinned against an explicit dense computation."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    s, hd, w = 64, 32, 8
    q, k, v = (jax.random.normal(kk, (2, s, hd)) for kk in ks)
    o = flash_attention(q, k, v, causal=False, window=w,
                        block_q=32, block_k=32, interpret=True)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(hd)
    mask = jnp.arange(s)[None, :] > jnp.arange(s)[:, None] - w
    dense = jnp.einsum(
        "bqk,bkd->bqd",
        jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1), v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(dense),
                               atol=2e-5, rtol=1e-4)
    orf = ref.flash_attn_ref(q[:, None], k[:, None], v[:, None],
                             causal=False, window=w)[:, 0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------- int4 codec --
@pytest.mark.parametrize("m,n", [(64, 256), (128, 512)])
def test_act_dequant4_matches_ref(m, n):
    from repro.kernels import act_dequant4, act_quant4
    x = jax.random.normal(jax.random.PRNGKey(m + n), (m, n)) * 2
    packed, s = act_quant4(x, interpret=True, block_m=64, block_n=128)
    d_kernel = act_dequant4(packed, s, out_dtype=jnp.float32,
                            interpret=True, block_m=64, block_n=128)
    d_ref = ref.act_dequant4_ref(packed, s, dtype=jnp.float32)
    # same packed bytes + same scales -> dequant is exact, not approx
    np.testing.assert_array_equal(np.asarray(d_kernel), np.asarray(d_ref))


def test_act_quant4_roundtrip_is_exact_on_codes():
    """pack -> unpack -> repack is the identity on the packed bytes: the
    dequantized tensor re-quantizes to the same codes AND the same
    scales (scale = amax/7 survives because the per-block amax is itself
    a code-7 point, exactly representable)."""
    x = jax.random.normal(jax.random.PRNGKey(11), (64, 256)) * 3
    p1, s1 = ref.act_quant4_ref(x)
    d1 = ref.act_dequant4_ref(p1, s1, dtype=jnp.float32)
    p2, s2 = ref.act_quant4_ref(d1)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_act_quant4_range_is_symmetric():
    """The code space is the symmetric [-7, 7]: biased nibbles live in
    [1, 15] and nibble 0 (code -8) never occurs, so negating the input
    negates the codes exactly."""
    x = jax.random.normal(jax.random.PRNGKey(12), (32, 256)) * 4
    packed, _ = ref.act_quant4_ref(x)
    lo = np.asarray(packed & 0xF, np.int32)
    hi = np.asarray(packed >> 4, np.int32)
    assert lo.min() >= 1 and hi.min() >= 1          # -8 deliberately unused
    neg_packed, _ = ref.act_quant4_ref(-x)
    nlo = np.asarray(neg_packed & 0xF, np.int32) - 8
    nhi = np.asarray(neg_packed >> 4, np.int32) - 8
    np.testing.assert_array_equal(nlo, -(lo - 8))
    np.testing.assert_array_equal(nhi, -(hi - 8))


# ------------------------------------------------------ paged decode attn --
def _paged_case(seed, slots, H, kvh, hd, bs, mb, kv_dtype, pos_spec):
    """Build one paged-decode problem; pos_spec picks the ragged lengths."""
    from repro.kernels.act_quant import kv_quant_rows
    rng = np.random.default_rng(seed)
    nb = mb * slots + 2
    q = jnp.asarray(rng.standard_normal((slots, H, hd)), jnp.float32)
    kb = jnp.asarray(rng.standard_normal((nb, bs, kvh, hd)), jnp.float32)
    vb = jnp.asarray(rng.standard_normal((nb, bs, kvh, hd)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, nb, (slots, mb)), jnp.int32)
    kn = jnp.asarray(rng.standard_normal((slots, kvh, hd)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((slots, kvh, hd)), jnp.float32)
    if pos_spec == "ragged":
        pos = jnp.asarray(rng.integers(0, mb * bs + 1, (slots,)), jnp.int32)
    elif pos_spec == "zero":
        pos = jnp.zeros((slots,), jnp.int32)
    elif pos_spec == "full_tail":           # every tail block just filled
        pos = jnp.full((slots,), mb * bs, jnp.int32)
    kwargs = {}
    if kv_dtype == "int8":
        kb, ks = kv_quant_rows(kb)
        vb, vs = kv_quant_rows(vb)
        kwargs = dict(k_scale=ks, v_scale=vs)
    elif kv_dtype == "bfloat16":
        kb, vb = kb.astype(jnp.bfloat16), vb.astype(jnp.bfloat16)
    return (q, kb, vb, tables, pos, kn, vn), kwargs


@pytest.mark.parametrize("bs,mb", [(4, 5), (8, 3), (16, 2)])
@pytest.mark.parametrize("kv_dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("pos_spec", ["ragged", "zero", "full_tail"])
def test_paged_decode_matches_ref(bs, mb, kv_dtype, pos_spec):
    from repro.kernels import paged_decode_attention
    args, kw = _paged_case(bs * mb, slots=3, H=4, kvh=2, hd=16,
                           bs=bs, mb=mb, kv_dtype=kv_dtype,
                           pos_spec=pos_spec)
    o_k = paged_decode_attention(*args, interpret=True, **kw)
    o_r = ref.paged_decode_attn_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [1, 3, 100])
def test_paged_decode_window_matches_ref(window):
    from repro.kernels import paged_decode_attention
    args, _ = _paged_case(17, slots=4, H=8, kvh=4, hd=16, bs=4, mb=4,
                          kv_dtype="float32", pos_spec="ragged")
    o_k = paged_decode_attention(*args, window=window, interpret=True)
    o_r = ref.paged_decode_attn_ref(*args, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=1e-4)


def test_paged_decode_pos_zero_is_new_token_only():
    """A brand-new slot's pool sweep is fully masked; the only valid key
    is the just-computed token, so out == v_new per kv head (regression
    for the masked-row guard in the decode kernel)."""
    from repro.kernels import paged_decode_attention
    args, _ = _paged_case(23, slots=2, H=4, kvh=2, hd=16, bs=4, mb=3,
                          kv_dtype="float32", pos_spec="zero")
    q, kb, vb, tables, pos, kn, vn = args
    o = paged_decode_attention(*args, interpret=True)
    expect = jnp.repeat(vn, 2, axis=1)          # group=2 heads per kv head
    np.testing.assert_allclose(np.asarray(o), np.asarray(expect), atol=2e-6)


def test_paged_decode_matches_dense_decode():
    """The block-table kernel against the dense one-token attention it
    replaces: lay the same KV out densely (new token scattered at pos)
    and paged (new token folded in), outputs must agree."""
    from repro.kernels import paged_decode_attention
    from repro.models.attention import decode_attention
    rng = np.random.default_rng(41)
    slots, H, kvh, hd, bs, mb = 3, 4, 2, 16, 4, 4
    s_len = mb * bs
    nb = slots * mb + 1
    q = jnp.asarray(rng.standard_normal((slots, H, hd)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((slots, s_len, kvh, hd)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((slots, s_len, kvh, hd)), jnp.float32)
    pos = jnp.asarray([0, 7, 15], jnp.int32)
    # paged layout: slot s owns blocks [1 + s*mb, 1 + (s+1)*mb)
    tables = jnp.asarray(
        [[1 + s * mb + j for j in range(mb)] for s in range(slots)],
        jnp.int32)
    kb = jnp.zeros((nb, bs, kvh, hd), jnp.float32)
    vb = jnp.zeros((nb, bs, kvh, hd), jnp.float32)
    kb = kb.at[tables.reshape(-1)].set(
        kd.reshape(slots * mb, bs, kvh, hd))
    vb = vb.at[tables.reshape(-1)].set(
        vd.reshape(slots * mb, bs, kvh, hd))
    # the dense path sees the new token *scattered at pos*; the kernel
    # folds the same rows in as k_new/v_new
    kn = jnp.stack([kd[s, pos[s]] for s in range(slots)])
    vn = jnp.stack([vd[s, pos[s]] for s in range(slots)])
    for w in (0, 3):
        o_p = paged_decode_attention(q, kb, vb, tables, pos, kn, vn,
                                     window=w, interpret=True)
        o_d = jnp.stack([
            decode_attention(q[s:s + 1], kd[s:s + 1], vd[s:s + 1],
                             pos[s], window=w)[0]
            for s in range(slots)])
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d),
                                   atol=2e-5, rtol=1e-4)


def test_paged_decode_int8_error_bound():
    """int8 KV attention stays within the quantization error envelope of
    the f32 pool (per-row scales: relative error ~1/254 per element)."""
    from repro.kernels import paged_decode_attention
    from repro.kernels.act_quant import kv_quant_rows
    args, _ = _paged_case(29, slots=4, H=8, kvh=2, hd=32, bs=8, mb=3,
                          kv_dtype="float32", pos_spec="ragged")
    q, kb, vb, tables, pos, kn, vn = args
    o_f32 = paged_decode_attention(*args, interpret=True)
    kq, ks = kv_quant_rows(kb)
    vq, vs = kv_quant_rows(vb)
    o_i8 = paged_decode_attention(q, kq, vq, tables, pos, kn, vn,
                                  k_scale=ks, v_scale=vs, interpret=True)
    assert float(jnp.max(jnp.abs(o_i8 - o_f32))) < 0.05


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**16), kvh=st.sampled_from([1, 2, 4]),
           group=st.sampled_from([1, 2, 3]), bs=st.sampled_from([4, 8, 16]),
           mb=st.integers(1, 4), kv_dtype=st.sampled_from(
               ["float32", "bfloat16", "int8"]),
           pos_spec=st.sampled_from(["ragged", "zero", "full_tail"]),
           window=st.sampled_from([0, 1, 5]))
    def test_paged_decode_matches_ref_fuzzed(seed, kvh, group, bs, mb,
                                             kv_dtype, pos_spec, window):
        from repro.kernels import paged_decode_attention
        args, kw = _paged_case(seed, slots=2, H=kvh * group, kvh=kvh,
                               hd=16, bs=bs, mb=mb, kv_dtype=kv_dtype,
                               pos_spec=pos_spec)
        o_k = paged_decode_attention(*args, window=window, interpret=True,
                                     **kw)
        o_r = ref.paged_decode_attn_ref(*args, window=window, **kw)
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r, np.float32),
                                   atol=3e-5, rtol=2e-4)
