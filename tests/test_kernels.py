"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (act_dequant, act_quant, flash_attention, fused_ffn,
                           ssd_scan)
from repro.kernels import ref


@pytest.mark.parametrize("m,n", [(128, 256), (256, 512), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_act_quant_matches_ref(m, n, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(m + n), (m, n)) * 3).astype(dtype)
    q, s = act_quant(x, interpret=True, block_m=64, block_n=128)
    qr, sr = ref.act_quant_ref(x)
    # identical up to +-1 level on round-half ties (f32 association order)
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1
    assert (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # roundtrip error bounded by scale/2 per element
    xd = act_dequant(q, s, out_dtype=jnp.float32, interpret=True,
                     block_m=64, block_n=128)
    err = jnp.abs(xd - x.astype(jnp.float32))
    bound = jnp.repeat(s, 128, axis=-1) * 0.51 + 1e-6
    assert bool(jnp.all(err <= bound))


@pytest.mark.parametrize("m,d,f", [(128, 64, 256), (256, 128, 512),
                                   (64, 96, 128)])
@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_fused_ffn_matches_ref(m, d, f, activation):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (m, d), jnp.float32) * 0.5
    wg = jax.random.normal(ks[1], (d, f)) * 0.1
    wu = jax.random.normal(ks[2], (d, f)) * 0.1
    wd = jax.random.normal(ks[3], (f, d)) * 0.1
    y = fused_ffn(x, wg, wu, wd, activation=activation, interpret=True,
                  block_m=64, block_f=128)
    yr = ref.fused_ffn_ref(x, wg, wu, wd, activation)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=1e-4)


def test_fused_ffn_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = (jax.random.normal(ks[0], (128, 64)) * 0.5).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[1], (64, 256)) * 0.1).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[2], (64, 256)) * 0.1).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[3], (256, 64)) * 0.1).astype(jnp.bfloat16)
    y = fused_ffn(x, wg, wu, wd, interpret=True, block_m=64, block_f=128)
    yr = ref.fused_ffn_ref(x, wg, wu, wd, "silu")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=3e-2)


@pytest.mark.parametrize("s,hd", [(256, 64), (512, 128), (128, 32)])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_matches_ref(s, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    bh = 4
    q = jax.random.normal(ks[0], (bh, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, hd), jnp.float32)
    o = flash_attention(q, k, v, causal=True, window=window,
                        block_q=128, block_k=128, interpret=True)
    orf = ref.flash_attn_ref(q[None].reshape(1, bh, s, hd),
                             k.reshape(1, bh, s, hd),
                             v.reshape(1, bh, s, hd),
                             causal=True, window=window)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 64)) for kk in ks)
    o = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                        interpret=True)
    orf = ref.flash_attn_ref(q.reshape(1, 2, 128, 64),
                             k.reshape(1, 2, 128, 64),
                             v.reshape(1, 2, 128, 64), causal=False)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-5)


@pytest.mark.parametrize("s,p,n,chunk", [(64, 16, 8, 16), (128, 32, 16, 32),
                                         (96, 8, 4, 32)])
def test_ssd_scan_matches_ref(s, p, n, chunk):
    bh = 3
    ks = jax.random.split(jax.random.PRNGKey(s + p), 5)
    x = jax.random.normal(ks[0], (bh, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.2)
    b = jax.random.normal(ks[3], (bh, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bh, s, n)) * 0.5
    y, st = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd_scan_kernel_ref(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=1e-4, rtol=1e-3)


def test_ssd_scan_chunk_invariance():
    """The kernel result must not depend on the chunk size."""
    bh, s, p, n = 2, 128, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (bh, s, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.2)
    b = jax.random.normal(ks[3], (bh, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bh, s, n)) * 0.5
    y16, st16 = ssd_scan(x, dt, a, b, c, chunk=16, interpret=True)
    y64, st64 = ssd_scan(x, dt, a, b, c, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st16), np.asarray(st64),
                               atol=1e-4, rtol=1e-3)


def test_ops_dispatch_cpu_fallback():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    q1, s1 = ops.quantize_activations(x, use_pallas=False)
    q2, s2 = ops.quantize_activations(x, use_pallas=True, interpret=True)
    assert int(jnp.abs(q1.astype(jnp.int32) - q2.astype(jnp.int32)).max()) <= 1


@pytest.mark.parametrize("m,n", [(64, 256), (128, 512)])
def test_act_quant4_matches_engine_codec(m, n):
    from repro.engine import quantize_int4
    from repro.kernels import act_quant4
    x = jax.random.normal(jax.random.PRNGKey(m * n), (m, n)) * 2
    packed, s = act_quant4(x, interpret=True, block_m=64, block_n=128)
    ref_packed, ref_s = quantize_int4(x)
    # engine codec blocks over the flattened last dim identically
    diff = np.asarray(packed, np.int32) - np.asarray(ref_packed, np.int32)
    # allow rare +-1-level tie differences in EITHER nibble
    lo = np.abs((diff & 0xF).astype(np.int8))
    assert (np.minimum(lo, 16 - lo) <= 1).all()
    np.testing.assert_allclose(np.asarray(s),
                               np.asarray(ref_s.reshape(s.shape)), rtol=1e-5)
