"""Offload component: pre-partition invariants, placement optimality,
transformation semantic equivalence."""
import itertools

import numpy as np
import pytest

from repro.configs import get_config
from repro.offload import (DEVICE_POOLS, DeviceProfile, Graph, OpNode,
                           build_model_graph, convert, execute,
                           independent_flows, local_only, place_cas,
                           place_dads, place_dp, pre_partition)

CFG = get_config("paper-backbone")
G = build_model_graph(CFG, batch=1, seq=128)
PP = pre_partition(G)


def test_prepartition_covers_graph():
    for level in range(4):
        units = PP.units(level)
        covered = [n for u in units for n in u.node_names]
        assert sorted(covered) == sorted(n.output for n in G.nodes), level
        assert len(covered) == len(set(covered))


def test_prepartition_hierarchy_coarsens():
    sizes = [len(PP.units(l)) for l in range(4)]
    assert sizes[0] > sizes[1] > sizes[2] >= sizes[3]


def test_prepartition_flops_conserved():
    total = G.total_flops()
    for level in range(4):
        assert abs(sum(u.flops for u in PP.units(level)) - total) < 1e-6


def test_dp_beats_heuristics():
    devs = DEVICE_POOLS["edge_pair"]
    dp = place_dp(PP, devs)
    cas = place_cas(PP, devs)
    loc = local_only(PP, devs)
    assert dp.latency_s <= cas.latency_s + 1e-9
    assert dp.latency_s <= loc.latency_s + 1e-9


def test_dp_optimal_vs_bruteforce():
    """On a small chain with 2 devices, DP must equal exhaustive search."""
    devs = DEVICE_POOLS["edge_pair"]
    units = PP.units(3)       # 4 coarse stages
    n = len(units)
    dp = place_dp(PP, devs, level=3)
    best = float("inf")
    for cut in range(-1, n - 1):   # -1 = all on device 0... all splits
        lat = 0.0
        feas = True
        mem0 = sum(u.param_bytes + u.peak_act_bytes for u in units[:cut + 1])
        mem1 = sum(u.param_bytes + u.peak_act_bytes for u in units[cut + 1:])
        if cut >= 0:
            if mem0 > devs[0].mem_bytes or mem1 > devs[1].mem_bytes:
                continue
            lat += sum(devs[0].compute_seconds(u) for u in units[:cut + 1])
            lat += units[cut].boundary_bytes / devs[0].link_bw
            lat += sum(devs[1].compute_seconds(u) for u in units[cut + 1:])
        else:
            if sum(u.param_bytes + u.peak_act_bytes for u in units) \
                    > devs[0].mem_bytes:
                continue
            lat = sum(devs[0].compute_seconds(u) for u in units)
        best = min(best, lat)
    assert dp.latency_s <= best + 1e-9


def test_placement_respects_memory():
    tight = (
        DeviceProfile("small0", 50e9, G.total_param_bytes() * 0.6, 10e9, 1e9),
        DeviceProfile("small1", 50e9, G.total_param_bytes() * 0.6, 10e9, 0),
    )
    pl = place_dp(PP, tight)
    for m, d in zip(pl.per_device_mem, tight):
        assert m <= d.mem_bytes + 1e-6


def test_placement_infeasible_raises():
    tiny = (DeviceProfile("t0", 1e9, 1024, 1e9, 1e9),
            DeviceProfile("t1", 1e9, 1024, 1e9, 0))
    with pytest.raises(ValueError):
        place_dp(PP, tiny)


def test_independent_flows_topological():
    flows = independent_flows(G)
    node_of = G.node_map()
    seen = set(G.inputs)
    for level in flows:
        for t in level:
            assert all(i in seen for i in node_of[t].inputs)
        seen.update(level)


# ------------------------------------------------ transformation passes ----
def _rand_graph(seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    nodes = [OpNode("w0", "const", (), "w0",
                    attrs={"value": rng.standard_normal((8, 8)).astype(np.float32)}),
             OpNode("w1", "const", (), "w1",
                    attrs={"value": rng.standard_normal((8, 8)).astype(np.float32)})]
    prev = "x"
    for i in range(int(rng.integers(2, 6))):
        kind = rng.choice(["matmul", "act", "add"])
        if kind == "matmul":
            nodes.append(OpNode(f"n{i}", "matmul",
                                (prev, rng.choice(["w0", "w1"])), f"n{i}"))
        elif kind == "act":
            nodes.append(OpNode(f"n{i}", "act", (prev,), f"n{i}",
                                attrs={"fn": str(rng.choice(["relu", "gelu",
                                                             "silu"]))}))
        else:
            nodes.append(OpNode(f"n{i}", "add", (prev, "w0_row"), f"n{i}"))
            if "w0_row" not in [n.output for n in nodes]:
                nodes.insert(2, OpNode("w0_row", "const", (), "w0_row",
                                       attrs={"value": rng.standard_normal(
                                           (8,)).astype(np.float32)}))
        prev = f"n{i}"
    return Graph(nodes=nodes, inputs=("x",), outputs=(prev,))


@pytest.mark.parametrize("seed", range(12))
def test_convert_preserves_semantics(seed):
    g = _rand_graph(seed)
    x = np.random.default_rng(seed).standard_normal((4, 8)).astype(np.float32)
    ref = execute(g, {"x": x})[g.outputs[0]]
    g2 = convert(_rand_graph(seed))
    out = execute(g2, {"x": x})[g2.outputs[0]]
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
    assert len(g2.nodes) <= len(g.nodes)


def test_convert_removes_duplicates_and_constants():
    nodes = [
        OpNode("w", "const", (), "w",
               attrs={"value": np.eye(4, dtype=np.float32)}),
        OpNode("w_dup", "const", (), "w_dup",
               attrs={"value": np.eye(4, dtype=np.float32)}),
        OpNode("m1", "matmul", ("x", "w"), "m1"),
        OpNode("m2", "matmul", ("x", "w_dup"), "m2"),
        OpNode("c1", "matmul", ("w", "w_dup"), "c1"),
        OpNode("cr", "reduce", ("c1",), "cr", attrs={"fn": "mean", "axis": 0}),
        OpNode("s", "add", ("m1", "m2"), "s"),
        OpNode("o", "add", ("s", "cr"), "o"),
    ]
    g = Graph(nodes=nodes, inputs=("x",), outputs=("o",))
    g2 = convert(g)
    kinds = [n.kind for n in g2.nodes]
    assert kinds.count("matmul") + kinds.count("fused") <= 2
    x = np.random.randn(3, 4).astype(np.float32)
    np.testing.assert_allclose(execute(g2, {"x": x})["o"],
                               execute(g, {"x": x})["o"], atol=1e-5)
