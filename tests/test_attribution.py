"""Critical-path attribution, flight recorder, and perf-gate suite.

* **Bit-equal decomposition** — for every attributed request,
  ``sum(components_ns) == end_to_end_ns`` exactly (integer-ns
  arithmetic, no float summation), across dense/per-slot/paged decode,
  swap-driven freeze/thaw, and synthetic timelines with awkward float
  timestamps.
* **Component semantics** — queue waits split at ``engine.oom`` into
  wait vs. retry backoff; same-engine freeze→thaw is ``migration``;
  cross-engine freeze→thaw is ``offload_link``.
* **Fleet rollup** — :func:`attribute_fleet` totals are integer sums of
  the per-request values, so they match exactly; tier grouping and
  dominant-component ranking are consistent with the per-device rows.
* **Lenient pairing** — ``pair_spans`` degrades to a counted
  :class:`PairingReport` when the recorder dropped events, and
  ``spans()`` auto-selects lenient mode from ``rec.dropped``.
* **Histogram snapshots** — P² marker state round-trips through
  ``snapshot()/from_snapshot()`` and the restored estimator continues
  bit-identically; p99.9 ships in the default quantile set.
* **Flight recorder** — the bounded ring keeps recording past
  saturation, trigger instants arm dumps that bracket the anomaly, and
  every written dump validates through ``tools/check_trace.py``.
* **Perf gate** — ``tools/check_perf.py`` ops (eq/ge/le/approx) pass
  and fail as specified, and trajectory rows upsert by label.
"""
import importlib.util
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.obs import (COMPONENTS, FlightRecorder, Histogram, TraceRecorder,
                       attribute_fleet, attribute_requests, pair_spans,
                       spans)
from repro.serving import CompileCache, Request, ServingEngine

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=300)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
CC = CompileCache()

TOOLS = Path(__file__).resolve().parents[1] / "tools"


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(name, TOOLS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_trace = _load_tool("check_trace")
check_perf = _load_tool("check_perf")


def _prompt(length, rid):
    rng = np.random.default_rng(7 * length + rid)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _run(mode, mix, swap=False, recorder=None, **eng_kw):
    rec = recorder if recorder is not None else TraceRecorder()
    eng = ServingEngine(CFG, PARAMS, slots=2, max_seq=64, decode_mode=mode,
                        compile_cache=CC, recorder=rec, pid="dev0",
                        **eng_kw)
    reqs = [Request(rid=i, prompt=_prompt(n, i), max_new_tokens=b)
            for i, (n, b) in enumerate(mix)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    if swap:
        eng.swap_model(CFG, PARAMS, eng.opts)
    eng.drain()
    return rec, eng, reqs


def _assert_invariant(attrs):
    for a in attrs.values():
        assert sum(a.components_ns.values()) == a.end_to_end_ns
        assert all(v >= 0 for v in a.components_ns.values())


# --------------------------------------------------- engine attribution ----
@pytest.mark.parametrize("mode", ["batched", "per_slot", "paged"])
def test_attribution_invariant_across_decode_modes(mode):
    mix = [(8, 4), (20, 3), (5, 6), (12, 2)]     # more rids than slots
    rec, eng, reqs = _run(mode, mix)
    attrs = attribute_requests(rec)
    assert sorted(attrs) == [r.rid for r in reqs]
    _assert_invariant(attrs)
    for r in reqs:
        a = attrs[r.rid]
        assert a.complete and a.pid == "dev0"
        # every completed request spent time somewhere
        assert a.end_to_end_ns > 0
        # no freeze/thaw happened: migration components stay zero
        assert a.components_ns["migration"] == 0
        assert a.components_ns["offload_link"] == 0
        # the decomposition is consistent with the request's own stamps
        assert a.end_to_end_s == pytest.approx(
            a.component_s("queue_wait") + a.component_s("retry_backoff")
            + a.component_s("prefill") + a.component_s("decode"))


def test_swap_freeze_thaw_counts_as_migration_same_engine():
    # budget outlives the first step → the swap freezes and re-queues;
    # thaw happens on the SAME engine, so the interval is migration,
    # never offload_link
    rec, eng, reqs = _run("batched", [(8, 6)], swap=True)
    attrs = attribute_requests(rec)
    _assert_invariant(attrs)
    assert eng.stats.thaws == 1
    assert attrs[0].components_ns["migration"] > 0
    assert attrs[0].components_ns["offload_link"] == 0


def test_incomplete_requests_attribute_to_last_milestone():
    rec = TraceRecorder()
    eng = ServingEngine(CFG, PARAMS, slots=1, max_seq=64,
                        compile_cache=CC, recorder=rec, pid="dev0")
    reqs = [Request(rid=i, prompt=_prompt(6, i), max_new_tokens=10)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()                          # rid 0 decoding, rid 1 queued
    attrs = attribute_requests(rec)
    _assert_invariant(attrs)
    assert not attrs[0].complete
    # rid 1 never admitted: only its queued milestone exists, so its
    # window is empty but still well-formed
    assert not attrs[1].complete
    assert attrs[1].end_to_end_ns == 0


# ------------------------------------------------- synthetic timelines ----
class _E:
    def __init__(self, name, ph, pid, wall_s, **args):
        self.name, self.ph, self.pid = name, ph, pid
        self.wall_s, self.sim_s = wall_s, None
        self.tid, self.cat = "t", "request"
        self.args = args


def test_synthetic_oom_splits_queue_wait_into_retry_backoff():
    # awkward floats on purpose: 0.1 + 0.2 != 0.3 in float arithmetic,
    # but the int-ns decomposition still telescopes exactly
    evts = [
        _E("req.queued", "i", "e0", 0.1, rid=1),
        _E("engine.oom", "i", "e0", 0.2),
        _E("engine.prefill", "B", "e0", 0.30000000000000004, rids=[1]),
        _E("req.first_token", "i", "e0", 0.4, rid=1),
        _E("req.decode", "i", "e0", 0.55, rid=1),
        _E("req.slot", "E", "e0", 0.55, rid=1, reason="finished"),
    ]
    attrs = attribute_requests(evts)
    a = attrs[1]
    _assert_invariant(attrs)
    assert a.complete
    assert a.components_ns["queue_wait"] == 100_000_000
    assert a.components_ns["retry_backoff"] == 100_000_000
    assert a.components_ns["prefill"] == 100_000_000
    assert a.components_ns["decode"] == 150_000_000
    assert a.end_to_end_ns == 450_000_000
    assert a.dominant() == "decode"


def test_synthetic_cross_engine_thaw_is_offload_link():
    evts = [
        _E("req.queued", "i", "e0", 1.0, rid=3),
        _E("engine.prefill", "B", "e0", 1.1, rids=[3]),
        _E("req.first_token", "i", "e0", 1.2, rid=3),
        _E("req.freeze", "i", "e0", 1.5, rid=3, reason="migrate"),
        _E("req.thaw", "i", "e1", 2.5, rid=3),      # different engine
        _E("req.decode", "i", "e1", 2.6, rid=3),
        _E("req.slot", "E", "e1", 2.6, rid=3, reason="finished"),
    ]
    a = attribute_requests(evts)[3]
    _assert_invariant({3: a})
    assert a.components_ns["offload_link"] == 1_000_000_000
    assert a.components_ns["migration"] == 0
    assert a.dominant() == "offload_link"
    assert a.pid == "e0"                # origin engine, not destination


def test_fleet_rollup_totals_equal_per_request_sums():
    mix = [(8, 4), (20, 3), (5, 6)]
    rec, eng, reqs = _run("batched", mix)
    attrs = attribute_requests(rec)
    fa = attribute_fleet(rec, tiers={"dev0": "light"})
    assert fa.fleet.requests == len(reqs)
    for c in COMPONENTS:
        want = sum(a.components_ns[c] for a in attrs.values())
        assert fa.fleet.components_ns[c] == want
        assert fa.per_device["dev0"].components_ns[c] == want
        assert fa.per_tier["light"].components_ns[c] == want
    assert fa.fleet.end_to_end_ns == \
        sum(a.end_to_end_ns for a in attrs.values())
    # ranking is the fleet components sorted by total, descending
    ranked = [c for c, _ in fa.ranking()]
    assert sorted(ranked) == sorted(COMPONENTS)
    totals = [fa.fleet.components_ns[c] for c in ranked]
    assert totals == sorted(totals, reverse=True)
    # tail stats: p95 row is one of the observed end-to-ends and the
    # tail dominant maps to a real layer
    e2es = {a.end_to_end_ns for a in attrs.values()}
    assert fa.fleet.tail_p95_ns in e2es
    assert fa.fleet.tail_dominant_layer in ("request", "engine", "fleet",
                                            "placement")
    d = fa.to_dict()
    assert d["fleet"]["requests"] == len(reqs)


# ------------------------------------------------------ lenient pairing ----
def test_pair_spans_strict_raises_lenient_counts():
    rec = TraceRecorder()
    rec.end("ghost", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.begin("open", pid="p", tid="t", cat="engine", wall_s=2.0)
    # a pristine recorder (dropped == 0) keeps the hard contract
    with pytest.raises(ValueError):
        spans(rec)
    # explicit lenient mode counts instead of raising
    rep = pair_spans(rec.events, dropped=0, strict=False)
    assert rep.orphaned_ends == 1
    assert rep.unclosed_begins == 1
    assert not rep.truncated
    assert rep.spans == []
    # a saturated recorder flips spans() to lenient automatically
    rec.dropped = 3
    assert spans(rec) == []
    rep2 = pair_spans(rec.events, dropped=rec.dropped)
    assert rep2.truncated and rep2.orphaned_ends == 1


def test_pair_spans_lenient_name_mismatch_never_pops_unrelated_frame():
    rec = TraceRecorder()
    rec.begin("outer", pid="p", tid="t", cat="engine", wall_s=1.0)
    rec.end("other", pid="p", tid="t", cat="engine", wall_s=2.0)
    rec.end("outer", pid="p", tid="t", cat="engine", wall_s=3.0)
    rep = pair_spans(rec.events, strict=False)
    # the mismatched end is an orphan; "outer" still pairs with its own
    assert rep.orphaned_ends == 1
    assert [s.name for s in rep.spans] == ["outer"]
    assert rep.unclosed_begins == 0


# -------------------------------------------------- histogram snapshots ----
def test_histogram_snapshot_roundtrip_continues_bit_identically():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(mean=-5.0, sigma=1.0, size=500)
    h1 = Histogram("h")
    for x in xs[:300]:
        h1.observe(float(x))
    snap = h1.snapshot()
    assert snap["count"] == 300 and "p99.9" in snap and "p2" in snap
    h2 = Histogram.from_snapshot(snap)
    assert h2.count == h1.count and h2.sum == h1.sum
    assert h2.min == h1.min and h2.max == h1.max
    for x in xs[300:]:
        h1.observe(float(x))
        h2.observe(float(x))
    for q in Histogram.DEFAULT_QUANTILES:
        assert h1.quantile(q) == h2.quantile(q)     # exact, not approx
    # stateless summaries (what bench artifacts embed) don't round-trip
    with pytest.raises(ValueError):
        Histogram.from_snapshot(h1.snapshot(state=False))


def test_default_quantiles_include_p999():
    assert 0.999 in Histogram.DEFAULT_QUANTILES
    h = Histogram("h")
    for i in range(2000):
        h.observe(float(i))
    assert h.quantile(0.999) > h.quantile(0.95)
    assert h.snapshot(state=False)["p99.9"] is not None


# ------------------------------------------------------ flight recorder ----
def test_flight_ring_keeps_recording_and_dumps_validate(tmp_path):
    rec = FlightRecorder(capacity=64, window_s=60.0, post_roll_s=0.0,
                         triggers=("engine.oom",))
    # saturate the ring: far more events than capacity
    for i in range(200):
        rec.instant("tick", pid="p", tid="t", cat="engine",
                    wall_s=float(i), args={"i": i})
    assert len(rec.events) == 64
    assert rec.dropped == 200 - 64
    # the trigger arms a dump; the next event finalizes it (post-roll 0)
    rec.instant("engine.oom", pid="p", tid="t", cat="engine", wall_s=200.0,
                args={"queued": 3})
    rec.instant("tick", pid="p", tid="t", cat="engine", wall_s=201.0)
    dumps = rec.flush()
    assert len(dumps) == 1
    d = dumps[0]
    assert d["anomaly"] == "engine.oom" and d["events"] > 0
    # truncation is honest: ring evictions + window-clipped events
    assert d["trace"]["otherData"]["dropped_events"] >= rec.dropped
    paths = rec.write_dumps(str(tmp_path))
    assert len(paths) == 1 and "engine_oom" in paths[0]
    # the dump validates under the CI trace checker (truncation only
    # FLAGs, never fails)
    assert check_trace.check(Path(paths[0])) == 0


def test_flight_recorder_with_real_engine_spans(tmp_path):
    rec = FlightRecorder(capacity=16, window_s=60.0, post_roll_s=0.0)
    _run("batched", [(8, 4), (16, 3), (5, 5)], recorder=rec)
    assert rec.dropped > 0              # the tiny ring saturated
    # span queries degrade to lenient pairing instead of raising
    spans(rec)
    dump = rec.snapshot(anomaly="manual.end_of_run")
    assert dump["events"] == len(rec.events)
    paths = rec.write_dumps(str(tmp_path))
    assert all(check_trace.check(Path(p)) == 0 for p in paths)


def test_flight_max_dumps_bounds_capture():
    rec = FlightRecorder(capacity=32, post_roll_s=0.0, max_dumps=2,
                         triggers=("boom",))
    for i in range(6):
        rec.instant("boom", pid="p", tid="t", cat="fleet", wall_s=float(i))
    rec.instant("tick", pid="p", tid="t", cat="fleet", wall_s=10.0)
    assert len(rec.flush()) == 2


# ------------------------------------------------------------ perf gate ----
def test_check_perf_ops_and_missing_paths(tmp_path):
    art = {"a": {"speed": 2.0, "ok": True, "count": 0}}
    (tmp_path / "BENCH_x.json").write_text(json.dumps(art))
    base = tmp_path / "baselines.json"

    def gate(checks):
        base.write_text(json.dumps({"checks": checks}))
        return check_perf.run_checks(tmp_path, base)

    passed, failed = gate([
        {"file": "BENCH_x.json", "path": "a.ok", "op": "eq", "expect": True},
        {"file": "BENCH_x.json", "path": "a.count", "op": "eq", "expect": 0},
        {"file": "BENCH_x.json", "path": "a.speed", "op": "ge", "expect": 1.5},
        {"file": "BENCH_x.json", "path": "a.speed", "op": "le", "expect": 2.5},
        {"file": "BENCH_x.json", "path": "a.speed", "op": "approx",
         "expect": 2.1, "tol": 0.1},
    ])
    assert len(passed) == 5 and not failed
    _, failed = gate([
        {"file": "BENCH_x.json", "path": "a.speed", "op": "ge", "expect": 3.0},
        {"file": "BENCH_x.json", "path": "a.speed", "op": "approx",
         "expect": 4.0, "tol": 0.05},
        {"file": "BENCH_x.json", "path": "a.nope", "op": "eq", "expect": 1},
        {"file": "BENCH_missing.json", "path": "a", "op": "eq", "expect": 1},
    ])
    assert len(failed) == 4
    assert any("path missing" in m for m in failed)
    assert any("artifact missing" in m for m in failed)


def test_repo_baselines_pass_against_committed_artifacts():
    root = Path(__file__).resolve().parents[1]
    passed, failed = check_perf.run_checks(
        root, root / "benchmarks" / "baselines.json")
    assert not failed, failed
    assert passed


def test_trajectory_upserts_by_label(tmp_path):
    art = {"slots": {"4": {"batched": {"tokens_per_s": 100.0},
                           "speedup": 2.0}},
           "bit_identical": True,
           "obs_overhead": {"overhead_factor": 1.01}}
    (tmp_path / "BENCH_serving.json").write_text(json.dumps(art))
    traj = tmp_path / "BENCH_trajectory.json"
    e1 = check_perf.trajectory_entry(tmp_path, "pr1")
    assert e1["serving"]["tokens_per_s_slots4"] == 100.0
    assert e1["serving"]["bit_identical"] is True
    assert e1["paging"]["bit_identical"] is None    # artifact absent: sparse
    check_perf.append_trajectory(traj, e1)
    check_perf.append_trajectory(traj, check_perf.trajectory_entry(
        tmp_path, "pr2"))
    # re-running a label replaces its row instead of duplicating it
    check_perf.append_trajectory(traj, check_perf.trajectory_entry(
        tmp_path, "pr1"))
    doc = json.loads(traj.read_text())
    assert [e["label"] for e in doc["entries"]] == ["pr2", "pr1"]
