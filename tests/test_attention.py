"""Attention path equivalences + causality properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (banded_attention, chunked_attention,
                                    decode_attention, full_attention,
                                    update_kv_cache)


def _qkv(key, b=2, s=256, h=4, k=2, hd=32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    kk = jax.random.normal(ks[1], (b, s, k, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, k, hd), jnp.float32)
    return q, kk, v


@pytest.mark.parametrize("qc,kc", [(64, 64), (128, 256), (256, 128)])
def test_chunked_equals_full(qc, kc):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    ref = full_attention(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_banded_equals_full_windowed(window):
    q, k, v = _qkv(jax.random.PRNGKey(1))
    ref = full_attention(q, k, v, causal=True, window=window)
    out = banded_attention(q, k, v, window=window, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_window_mask():
    q, k, v = _qkv(jax.random.PRNGKey(2))
    ref = full_attention(q, k, v, causal=True, window=64)
    out = chunked_attention(q, k, v, causal=True, window=64,
                            q_chunk=128, k_chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_causality_property():
    """Perturbing a future token must not change earlier outputs."""
    q, k, v = _qkv(jax.random.PRNGKey(3), s=64)
    out1 = full_attention(q, k, v, causal=True)
    k2 = k.at[:, 50].add(100.0)
    v2 = v.at[:, 50].add(100.0)
    out2 = full_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :50]),
                               np.asarray(out2[:, :50]), atol=1e-6)
    assert not np.allclose(np.asarray(out1[:, 50:]), np.asarray(out2[:, 50:]))


def test_decode_matches_full_row():
    b, s, h, kh, hd = 2, 32, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(4), b=b, s=s, h=h, k=kh, hd=hd)
    ref = full_attention(q, k, v, causal=True)
    for pos in (0, 7, 31):
        kc = jnp.zeros((b, 64, kh, hd))
        vc = jnp.zeros((b, 64, kh, hd))
        kc = kc.at[:, : pos + 1].set(k[:, : pos + 1])
        vc = vc.at[:, : pos + 1].set(v[:, : pos + 1])
        out = decode_attention(q[:, pos], kc, vc, jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, pos]),
                                   atol=2e-5)


def test_decode_window_limits_context():
    """With a window, tokens older than `window` must have no influence."""
    b, s, kh, hd = 1, 64, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(5), (b, 4, hd))
    q = q.reshape(b, 4, hd)
    k = jax.random.normal(jax.random.PRNGKey(6), (b, 128, kh, hd))
    v = jax.random.normal(jax.random.PRNGKey(7), (b, 128, kh, hd))
    pos = jnp.int32(63)
    out1 = decode_attention(q, k, v, pos, window=16)
    # perturb entries older than the window
    k2 = k.at[:, :40].add(50.0)
    v2 = v.at[:, :40].add(50.0)
    out2 = decode_attention(q, k2, v2, pos, window=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_update_kv_cache_inserts_at_pos():
    b, kh, hd = 2, 2, 8
    kc = jnp.zeros((b, 16, kh, hd))
    vc = jnp.ones((b, 16, kh, hd))
    knew = jnp.full((b, kh, hd), 3.0)
    vnew = jnp.full((b, kh, hd), 4.0)
    kc2, vc2 = update_kv_cache(kc, vc, knew, vnew, jnp.int32(5))
    assert float(kc2[0, 5, 0, 0]) == 3.0
    assert float(vc2[0, 5, 0, 0]) == 4.0
    assert float(kc2[0, 4, 0, 0]) == 0.0
