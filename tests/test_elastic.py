"""Elastic-inference component: η operators, supernet, early exit, TTA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.elastic import (FULL_SPEC, NAMED_COMBOS, ElasticSupernet,
                           VariantSpec, attach_exits, derive_variant,
                           early_exit_predict, ensemble_loss, sliced_forward,
                           tta_step, variant_cost)
from repro.models import forward, init_params

CFG = get_config("paper-backbone")
KEY = jax.random.PRNGKey(0)
PARAMS = init_params(CFG, KEY)
TOKENS = jax.random.randint(KEY, (2, 32), 0, CFG.vocab_size)


@pytest.mark.parametrize("name", sorted(NAMED_COMBOS))
def test_variant_runs_and_shrinks(name):
    spec = NAMED_COMBOS[name]
    vcfg, vparams = derive_variant(CFG, PARAMS, spec)
    logits, _ = forward(vparams, vcfg, TOKENS)
    assert logits.shape == (2, 32, CFG.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    cost = variant_cost(CFG, spec)
    full = variant_cost(CFG, FULL_SPEC)
    assert cost["flops_per_token"] < full["flops_per_token"]


def test_variant_output_close_to_backbone():
    """Weight recycling: a mild variant must stay close to the backbone
    (retraining-free switching keeps function approximately intact)."""
    base, _ = forward(PARAMS, CFG, TOKENS)
    vcfg, vparams = derive_variant(CFG, PARAMS, VariantSpec(rank_ratio=0.9))
    lg, _ = forward(vparams, vcfg, TOKENS)
    base = jax.nn.softmax(base.astype(jnp.float32), -1)
    lg = jax.nn.softmax(lg.astype(jnp.float32), -1)
    tv = float(0.5 * jnp.abs(base - lg).sum(-1).mean())
    assert tv < 0.30, f"rank-0.9 variant drifted too far (TV={tv})"


def test_eta5_depth_slices_layers():
    vcfg, vparams = derive_variant(CFG, PARAMS, VariantSpec(depth_ratio=0.5))
    assert vcfg.num_layers == CFG.num_layers // 2
    leaf = jax.tree_util.tree_leaves(vparams["layers"])[0]
    assert leaf.shape[0] == vcfg.num_layers


def test_eta6_importance_ordering():
    """Channel slicing keeps the highest-importance channels."""
    from repro.elastic.operators import _ffn_channel_importance
    layer0 = {k: np.asarray(v)[0] for k, v in PARAMS["layers"]["ffn"].items()}
    imp = _ffn_channel_importance(layer0)
    vcfg, vparams = derive_variant(CFG, PARAMS, VariantSpec(width_ratio=0.5))
    kept = vcfg.d_ff
    # mean importance of kept channels must exceed the dropped ones'
    thresh = np.sort(imp)[::-1][kept - 1]
    assert np.mean(np.sort(imp)[::-1][:kept]) >= np.mean(imp)


def test_eta2_kv_merge_halves_heads():
    vcfg, vparams = derive_variant(CFG, PARAMS, VariantSpec(kv_merge=2))
    assert vcfg.num_kv_heads == CFG.num_kv_heads // 2
    wk = vparams["layers"]["attn"]["wk"]
    assert wk.shape[-1] == vcfg.num_kv_heads * vcfg.resolved_head_dim


def test_supernet_caching_and_action_space():
    sn = ElasticSupernet(CFG, PARAMS, max_cached=2)
    space = sn.action_space()
    assert FULL_SPEC in space and len(space) >= 6
    a = sn.variant(space[1])
    b = sn.variant(space[1])
    assert a is b  # cached
    sn.variant(space[2])
    sn.variant(space[3])  # evicts
    assert len(sn._cache) <= 2


def test_ssm_action_space_is_depth_only():
    ssm_cfg = get_config("mamba2-370m").reduced()
    p = init_params(ssm_cfg, KEY)
    sn = ElasticSupernet(ssm_cfg, p)
    assert sn.applicable_operators() == ("eta5",)
    for spec in sn.action_space():
        assert spec.width_ratio == 1.0 and spec.rank_ratio == 1.0


def test_early_exit_monotone_threshold():
    p2 = attach_exits(CFG, PARAMS, KEY, positions=(2, 5))
    _, depth_strict = early_exit_predict(p2, CFG, TOKENS, threshold=0.99)
    _, depth_loose = early_exit_predict(p2, CFG, TOKENS, threshold=0.0)
    # threshold 0 exits everything at the first branch
    assert int(depth_loose.max()) == 0
    assert float(depth_strict.mean()) >= float(depth_loose.mean())


def test_tta_reduces_entropy_and_touches_only_norms():
    # sharpen the random-init logits so the entropy objective has signal
    sharp = dict(PARAMS)
    sharp["embed"] = PARAMS["embed"] * 8.0
    p1, e1 = tta_step(sharp, CFG, TOKENS, lr=5e-2)
    p2, e2 = tta_step(p1, CFG, TOKENS, lr=5e-2)
    assert float(e2) < float(e1)
    PARAMS_ = sharp
    for kp, (a, b) in zip(
            jax.tree_util.tree_leaves_with_path(sharp),
            zip(jax.tree_util.tree_leaves(sharp),
                jax.tree_util.tree_leaves(p1))):
        names = [str(getattr(k, "key", "")) for k in kp[0]]
        changed = not bool(jnp.array_equal(a, b))
        is_norm = any(n in ("ln1", "ln2", "final_norm", "ln", "norm_scale")
                      for n in names)
        if changed:
            assert is_norm, f"non-norm leaf changed: {names}"


def test_ensemble_loss_trains_slices():
    labels = jnp.roll(TOKENS, -1, 1)
    spec = VariantSpec(depth_ratio=0.5, width_ratio=0.5)
    loss, grads = jax.value_and_grad(
        lambda p: ensemble_loss(p, CFG, TOKENS, labels, KEY, (spec,)))(PARAMS)
    assert jnp.isfinite(loss)
    # gradient must reach the FULL ffn tensor (recycled weights)
    g = grads["layers"]["ffn"]["w_up"]
    assert float(jnp.abs(g[:, :, : CFG.d_ff // 2]).sum()) > 0
    # prefix-slice training: sliced channels get gradient from 2 paths
    assert bool(jnp.isfinite(g).all())


def test_sliced_forward_prefix_semantics():
    lg = sliced_forward(PARAMS, CFG, TOKENS,
                        VariantSpec(depth_ratio=0.5, width_ratio=0.5))
    assert lg.shape == (2, 32, CFG.padded_vocab)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())
