"""SSD correctness: chunked scan vs naive recurrence; step consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import segsum, ssd_scan_ref, ssd_step


def naive_ssd(x, dt, a, b, c):
    """O(S·N·P) literal recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t x_t b_t."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = np.repeat(np.asarray(b, np.float64), rep, axis=2)
    ch = np.repeat(np.asarray(c, np.float64), rep, axis=2)
    xd = np.asarray(x, np.float64) * np.asarray(dt, np.float64)[..., None]
    da = np.asarray(dt, np.float64) * np.asarray(a, np.float64)
    state = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, s, h, p))
    for t in range(s):
        state = state * np.exp(da[:, t])[:, :, None, None] \
            + xd[:, t][..., None] * bh[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, ch[:, t])
    return ys, state


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (40, 16)])
def test_ssd_matches_naive(s, chunk):
    bsz, h, p, g, n = 2, 4, 8, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(s), 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, g, n)) * 0.5
    y, st = ssd_scan_ref(x, dt, a, b, c, chunk=chunk)
    yn, stn = naive_ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), yn, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), stn, atol=1e-4, rtol=1e-3)


def test_ssd_step_matches_scan():
    """Running decode steps one-by-one equals the full scan."""
    bsz, s, h, p, g, n = 1, 16, 2, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, g, n)) * 0.5
    y_scan, st_scan = ssd_scan_ref(x, dt, a, b, c, chunk=8)
    state = jnp.zeros((bsz, h, p, n))
    for t in range(s):
        y_t, state = ssd_step(state, x[:, t], dt[:, t], a, b[:, t], c[:, t])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_scan[:, t]),
                                   atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_scan),
                               atol=1e-4, rtol=1e-3)


def test_ssd_initial_state_composition():
    """scan(x1;x2) == scan(x2, initial_state=scan(x1).state) — the property
    the inter-chunk recurrence (and multi-pod sequence sharding) relies on."""
    bsz, s, h, p, g, n = 1, 32, 2, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, g, n)) * 0.5
    y_full, st_full = ssd_scan_ref(x, dt, a, b, c, chunk=8)
    half = s // 2
    y1, st1 = ssd_scan_ref(x[:, :half], dt[:, :half], a, b[:, :half],
                           c[:, :half], chunk=8)
    y2, st2 = ssd_scan_ref(x[:, half:], dt[:, half:], a, b[:, half:],
                           c[:, half:], chunk=8, initial_state=st1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-3)


def test_segsum_definition():
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ss = segsum(x)
    assert float(ss[2, 0]) == 5.0      # x1 + x2
    assert float(ss[3, 1]) == 7.0      # x2 + x3
    assert float(ss[1, 1]) == 0.0
    assert np.isneginf(float(ss[0, 1]))
