"""§Perf lever correctness: the beyond-paper optimizations must preserve
model semantics (dense-dispatch MoE decode, fp8 KV, windowed decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (RuntimeOptions, decode_step, forward, init_cache,
                          init_params, prefill)
from repro.models import moe as moe_mod


def test_dense_dispatch_matches_gather_dispatch():
    """The §Perf-B2 rewrite: dense-dispatch decode must equal a literal
    per-token gathered-expert computation."""
    cfg = get_config("olmoe-1b-7b").reduced()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model)) * 0.3
    y = moe_mod.moe_apply_decode(params, x, cfg)

    # literal reference: gather each token's experts explicitly
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topk_p, topk_i = jax.lax.top_k(probs, cfg.experts_per_token)
    topk_p = topk_p / topk_p.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(x))
    for t in range(x.shape[0]):
        for j in range(cfg.experts_per_token):
            e = int(topk_i[t, j])
            h = np.asarray(x[t]) @ np.asarray(params["w_gate"][e])
            u = np.asarray(x[t]) @ np.asarray(params["w_up"][e])
            h = h / (1 + np.exp(-np.clip(h, -30, 30))) * u
            ref[t] += float(topk_p[t, j]) * (h @ np.asarray(
                params["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["yi-34b", "gemma3-12b", "zamba2-1.2b"])
def test_fp8_kv_cache_decode_close(arch):
    """§Perf-B3/C5: fp8 KV decode within tolerance of bf16."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    outs = {}
    for name in ("bfloat16", "fp8"):
        opts = RuntimeOptions(kv_cache_dtype=name)
        cache = init_cache(cfg, 2, 24, opts)
        _, cache = prefill(params, cfg, tokens[:, :11], cache, opts)
        lg, _ = decode_step(params, cfg, cache, tokens[:, 11], opts)
        outs[name] = lg.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(outs["fp8"] - outs["bfloat16"]))) / (
        float(jnp.max(jnp.abs(outs["bfloat16"]))) + 1e-9)
    assert rel < 0.15, f"{arch}: fp8 KV decode drifted {rel}"


def test_windowed_decode_matches_windowed_forward():
    """§Perf-C2: decode_window semantics == a sliding-window model."""
    cfg = get_config("paper-backbone").with_updates(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256)
    wcfg = cfg.with_updates(local_global_ratio=100, sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, 256)
    # forward with all-local window-8 layers
    ref, _ = forward(params, wcfg, tokens, RuntimeOptions(attn_impl="full"))
    # decode with decode_window on the plain config
    opts = RuntimeOptions(decode_window=8, kv_cache_dtype="float32")
    cache = init_cache(cfg, 1, 48, opts)
    _, cache = prefill(params, wcfg, tokens[:, :23], cache,
                       RuntimeOptions(attn_impl="full",
                                      kv_cache_dtype="float32"))
    lg, _ = decode_step(params, cfg, cache, tokens[:, 23], opts)
    rel = float(jnp.max(jnp.abs(ref[:, -1].astype(jnp.float32)
                                - lg.astype(jnp.float32)))) / (
        float(jnp.max(jnp.abs(ref[:, -1]))) + 1e-9)
    assert rel < 0.06


def test_seq_shard_noop_without_mesh_axis():
    """seq_shard_axis must be a pure no-op numerically."""
    cfg = get_config("paper-backbone").with_updates(num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    lg1, _ = forward(params, cfg, tokens, RuntimeOptions())
    mesh = jax.make_mesh((1,), ("model",), devices=jax.devices()[:1])
    with mesh:
        lg2, _ = forward(params, cfg, tokens,
                         RuntimeOptions(seq_shard_axis="model"))
    np.testing.assert_allclose(np.asarray(lg1, np.float32),
                               np.asarray(lg2, np.float32), atol=1e-3)
