"""Paper Fig. 11: scalable offloading vs CAS and DADS — placement latency,
per-device memory, transfer overhead across device pools and granularities."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.offload import (DEVICE_POOLS, build_model_graph, local_only,
                           place_cas, place_dads, place_dp, pre_partition)

from .common import emit, header


def run() -> None:
    header("scalable offloading vs CAS/DADS (Fig 11)")
    cfg = get_config("paper-backbone")
    g = build_model_graph(cfg, batch=1, seq=256)
    pp = pre_partition(g)
    for pool in ("edge_pair", "edge_trio"):
        devs = DEVICE_POOLS[pool]
        base = local_only(pp, devs)
        for name, fn in (("crowdhmtware_dp", place_dp), ("cas", place_cas),
                         ("dads", place_dads)):
            t0 = time.perf_counter()
            pl = fn(pp, devs)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"offload.{pool}.{name}", us,
                 f"latency={pl.latency_s*1e3:.2f}ms;"
                 f"vs_local={base.latency_s/pl.latency_s:.2f}x;"
                 f"xfer={pl.transfer_s*1e3:.2f}ms;"
                 f"dev0_mem={pl.per_device_mem[0]/1e6:.1f}MB;"
                 f"cuts={len(pl.cuts)}")

    header("pre-partition granularity sweep")
    devs = DEVICE_POOLS["edge_pair"]
    for level in range(4):
        t0 = time.perf_counter()
        pl = place_dp(pp, devs, level=level)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"offload.granularity.L{level}", us,
             f"units={len(pp.units(level))};latency={pl.latency_s*1e3:.2f}ms")

    header("pod-pipeline placement (TPU mesh-slice adaptation)")
    devs = DEVICE_POOLS["pod_pipeline"]
    pl = place_dp(pp, devs, level=3)
    emit("offload.pod_pipeline", pl.latency_s * 1e6,
         f"cuts={len(pl.cuts)};xfer={pl.transfer_s*1e6:.2f}us")


if __name__ == "__main__":
    run()
