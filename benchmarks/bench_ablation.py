"""Paper Table V: component ablation — compression / partitioning / engine
in pairs vs the full cross-level middleware, under one resource context."""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core import (ActionEvaluator, Budgets, ResourceContext,
                        nondominated_front, select_online)
from repro.core.actions import Action, OffloadChoice, default_action_space
from repro.elastic import VariantSpec
from repro.engine.schedule import EngineConfig
from repro.models.configs import InputShape

from .common import emit, header

VARIANTS = (VariantSpec(), VariantSpec(depth_ratio=0.75),
            VariantSpec(width_ratio=0.5),
            VariantSpec(rank_ratio=0.5, width_ratio=0.5))


def _select(ev, ctx, budgets, *, compression: bool, offload: bool,
            engine: bool):
    variants = VARIANTS if compression else (VariantSpec(),)
    actions = list(default_action_space(variants, allow_offload=offload))
    if not engine:
        actions = [dataclasses.replace(a, engine=EngineConfig(
            fuse=False, parallel_streams=1, remat_policy="none"))
            for a in actions]
        actions = list(dict.fromkeys(actions))
    evals = [ev.evaluate(a, ctx) for a in actions]
    front = nondominated_front(evals)
    return select_online(front, ctx, budgets)


def run() -> None:
    header("component ablation (Table V)")
    cfg = get_config("paper-backbone")
    shape = InputShape("bench", 512, 8, "prefill")
    ev = ActionEvaluator(cfg, shape)
    ctx = ResourceContext(battery_frac=0.5, mem_free_frac=0.4,
                          chips_available=1)
    budgets = Budgets(memory_bytes=1.5e9)
    combos = {
        "compression+partition": dict(compression=True, offload=True,
                                      engine=False),
        "compression+engine": dict(compression=True, offload=False,
                                   engine=True),
        "partition+engine": dict(compression=False, offload=True,
                                 engine=True),
        "full_crowdhmtware": dict(compression=True, offload=True,
                                  engine=True),
    }
    results = {}
    for name, kw in combos.items():
        e = _select(ev, ctx, budgets, **kw)
        results[name] = e
        emit(f"ablation.{name}", e.latency_s * 1e6,
             f"A={e.accuracy:.3f};M={e.memory_bytes/1e6:.1f}MB;"
             f"E={e.energy_j:.2e}J")
    full = results["full_crowdhmtware"]
    best_pair = min((e for k, e in results.items()
                     if k != "full_crowdhmtware"),
                    key=lambda e: e.latency_s)
    emit("ablation.crosslevel_gain", full.latency_s * 1e6,
         f"latency_vs_best_pair={best_pair.latency_s/max(full.latency_s,1e-12):.2f}x;"
         f"mem_vs_best_pair={best_pair.memory_bytes/max(full.memory_bytes,1):.2f}x")


if __name__ == "__main__":
    run()
