"""Paper §III-A2: runtime parameter adaptation under data drift.

A synthetic distribution shift degrades next-token loss; TENT-style
norm-scale adaptation (unsupervised, on live tokens) recovers part of it.
Measured with REAL training/eval on the paper-backbone model: train on the
base distribution briefly, drift the stream, adapt, compare losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.elastic import tta_step
from repro.launch.train import train_loop
from repro.models import forward, lm_loss
from repro.models.configs import InputShape

from .common import emit, header


def _eval_loss(params, cfg, data, n=4):
    tot = 0.0
    for i in range(n):
        b = data.batch(100 + i)
        logits, _ = forward(params, cfg, jnp.asarray(b["tokens"]))
        tot += float(lm_loss(logits, jnp.asarray(b["labels"])))
    return tot / n


def run() -> None:
    header("test-time adaptation under drift (paper §III-A2)")
    cfg = get_config("paper-backbone").with_updates(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512)
    shape = InputShape("tta", 64, 8, "train")
    out = train_loop(cfg, shape, steps=40, log_every=40)
    params = out["params"]

    clean = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                   batch_size=8))
    drifted = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                     batch_size=8, drift=0.8))
    base = _eval_loss(params, cfg, clean)
    degraded = _eval_loss(params, cfg, drifted)
    emit("tta.baseline", 0.0, f"clean_loss={base:.3f};"
         f"drifted_loss={degraded:.3f};gap={degraded-base:+.3f}")

    # unsupervised adaptation on live drifted tokens (no labels used;
    # objective="self": live tokens are their own next-token supervision)
    adapted = params
    for i in range(12):
        live = jnp.asarray(drifted.batch(i)["tokens"])
        adapted, ent = tta_step(adapted, cfg, live, lr=5e-2,
                                objective="self")
    recovered = _eval_loss(adapted, cfg, drifted)
    rec_frac = (degraded - recovered) / max(degraded - base, 1e-9)
    emit("tta.adapted", 0.0,
         f"drifted_loss={recovered:.3f};recovered_frac={rec_frac:.2f};"
         f"final_entropy={float(ent):.3f}")
    # adaptation must not catastrophically forget the clean distribution
    clean_after = _eval_loss(adapted, cfg, clean)
    emit("tta.forgetting", 0.0,
         f"clean_after={clean_after:.3f};delta={clean_after-base:+.3f}")


if __name__ == "__main__":
    run()
