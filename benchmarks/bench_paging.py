"""Paged KV cache: capacity, shared-prefix admission, live migration.

Four sections, mirroring the ISSUE-8 claims:

* **differential** — paged decode must be bit-identical to the dense
  batched path at equal throughput order; a second paged engine on the
  warm compile cache must compile nothing (block tables are runtime
  data, so occupancy/table contents never enter a jit key).
* **residency** — at a *fixed* KV memory budget (a fixed block pool),
  prefix sharing lets the paged engine keep many more same-system-prompt
  requests resident than the dense layout, which must allocate
  ``max_seq`` rows per slot up front.
* **prefix_admission** — time-to-first-token for admitting a prompt the
  prefix cache already holds (blocks increfed, first token sampled from
  the cached logits row, ``prefill_calls += 0``) vs a cold admission of
  the same bucket.
* **migration** — freeze → thaw onto a compatible engine vs the requeue
  fallback onto an incompatible one: tokens recovered without
  re-prefill, destination prefill calls on each path, and a request/
  engine-layer trace of the hand-off for ``tools/check_trace.py``.

Results go to stdout (the ``name,us_per_call,derived`` CSV contract)
and ``BENCH_paging.json`` for trend tracking.

  PYTHONPATH=src python -m benchmarks.bench_paging [--quick] [--json PATH]
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.faults import plan_migration
from repro.models.model import init_params
from repro.obs import NULL_RECORDER, TraceRecorder, write_trace
from repro.serving import CompileCache, Request, ServingEngine

from .common import emit, header

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512)
MAX_SEQ = 128
BLOCK_SIZE = 16


def _prompt(length: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, size=length).astype(np.int32)


def _requests(n: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=_prompt(int(rng.integers(4, 60)), seed * 97 + i),
                    max_new_tokens=max_new)
            for i in range(n)]


def _engine(params, cc, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("max_seq", MAX_SEQ)
    return ServingEngine(CFG, params, compile_cache=cc, **kw)


# ------------------------------------------------------------ differential --
def _differential(params, cc, steps: int):
    out = {}
    streams = {}
    for mode in ("batched", "paged"):
        eng = _engine(params, cc, decode_mode=mode)
        reqs = _requests(4, max_new=steps + 8, seed=1)
        for r in reqs:
            eng.submit(r)
        eng.step()
        eng.step()
        t0 = time.perf_counter()
        emitted = 0
        for _ in range(steps):
            emitted += eng.step()
        wall = time.perf_counter() - t0
        eng.drain()
        streams[mode] = [tuple(r.generated) for r in reqs]
        out[mode] = {"tokens_per_s": emitted / wall,
                     "recompiles": eng.stats.recompiles}
    # a second paged engine on the warm cache: block tables are runtime
    # data, so it must find every program already compiled
    eng2 = _engine(params, cc, decode_mode="paged")
    reqs = _requests(4, max_new=4, seed=1)
    for r in reqs:
        eng2.submit(r)
    eng2.drain()
    out["bit_identical"] = streams["batched"] == streams["paged"]
    out["second_paged_engine_recompiles"] = eng2.stats.recompiles
    out["paged_over_dense_throughput"] = (
        out["paged"]["tokens_per_s"]
        / max(out["batched"]["tokens_per_s"], 1e-12))
    return out


# --------------------------------------------------------------- residency --
def _residency(params, cc, attempts: int = 12):
    """Fixed memory: a pool worth two dense slots.  Identical prompts
    share their prompt blocks, so far more requests stay resident."""
    bps = MAX_SEQ // BLOCK_SIZE
    pool_blocks = 2 * bps + 2               # trash + two dense slots' rows
    dense_resident = (pool_blocks - 1) // bps
    prompt = _prompt(50, seed=11)           # bucket 64 → 4 prompt blocks
    eng = _engine(params, cc, decode_mode="paged", slots=attempts,
                  block_size=BLOCK_SIZE, pool_blocks=pool_blocks)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new_tokens=4)
            for i in range(attempts)]
    for r in reqs:
        eng.submit(r)
    eng.step()                              # burst + prefix-hit admissions
    pool = eng.block_pool
    resident = sum(1 for r in reqs if r.generated and not r.done)
    peak = {"used_blocks": pool.used_blocks,
            "shared_blocks": pool.shared_blocks}
    eng.drain()
    return {
        "pool_blocks": pool_blocks, "block_size": BLOCK_SIZE,
        "kv_rows_budget": (pool_blocks - 1) * BLOCK_SIZE,
        "dense_resident": dense_resident,
        "paged_resident": resident,
        "residency_gain": resident / max(dense_resident, 1),
        **peak,
        "prefix_sharing_merged": pool.shared_blocks > 0 or resident <= 1,
    }


# -------------------------------------------------------- prefix admission --
def _prefix_admission(params, cc, rounds: int = 5):
    """Cold admission (real prefill jit call) vs prefix-cache hit
    (incref + cached logits row) on the same bucket, warm programs."""
    # a roomy pool: cached prefixes must survive later admissions
    # instead of being LRU-evicted for tail blocks
    eng = _engine(params, cc, decode_mode="paged", slots=1,
                  pool_blocks=8 * (rounds + 3),
                  prefix_entries=rounds + 2)
    warm = Request(rid=1000, prompt=_prompt(40, seed=999), max_new_tokens=1)
    eng.submit(warm)
    eng.drain()                             # warm the bucket's programs

    prompts = [_prompt(40, seed=500 + i) for i in range(rounds)]
    cold_ttft, hit_ttft = [], []
    for phase, sink in (("cold", cold_ttft), ("hit", hit_ttft)):
        calls0 = eng.stats.prefill_calls
        for i, p in enumerate(prompts):
            r = Request(rid=2000 * (phase == "hit") + i, prompt=p.copy(),
                        max_new_tokens=1)
            eng.submit(r)
            eng.drain()
            sink.append(r.first_token_s - r.arrived_s)
        if phase == "cold":
            cold_calls = eng.stats.prefill_calls - calls0
        else:
            hit_calls = eng.stats.prefill_calls - calls0
    cold_ttft.sort()
    hit_ttft.sort()
    return {
        "rounds": rounds,
        "cold_p50_ttft_ms": cold_ttft[len(cold_ttft) // 2] * 1e3,
        "hit_p50_ttft_ms": hit_ttft[len(hit_ttft) // 2] * 1e3,
        "ttft_speedup": (cold_ttft[len(cold_ttft) // 2]
                         / max(hit_ttft[len(hit_ttft) // 2], 1e-9)),
        "cold_prefill_calls": cold_calls,
        "hit_prefill_calls": hit_calls,     # the prefill-skip claim: 0
    }


# --------------------------------------------------------------- migration --
def _migration(params, cc, trace_path: str = ""):
    """Freeze mid-decode and move to a peer: thaw (same weights binding)
    vs the requeue fallback (fingerprint mismatch)."""
    def run_src(rec=NULL_RECORDER):
        src = _engine(params, cc, decode_mode="paged", slots=2,
                      recorder=rec, pid="src_engine")
        reqs = _requests(4, max_new=24, seed=5)
        for r in reqs:
            src.submit(r)
        for _ in range(4):
            src.step()
        return reqs, src.freeze_all("migrate"), src.drain_waiting()

    # baseline: the same mix, uninterrupted
    base_eng = _engine(params, cc, decode_mode="paged", slots=2)
    base = _requests(4, max_new=24, seed=5)
    for r in base:
        base_eng.submit(r)
    base_eng.drain()
    want = [tuple(r.generated) for r in base]

    rec = TraceRecorder() if trace_path else NULL_RECORDER
    reqs, frozen, waiting = run_src(rec)
    frozen_tokens = sum(len(r.generated) for r in frozen)
    dst = _engine(params, cc, decode_mode="paged", slots=2,
                  recorder=rec, pid="dst_engine")
    plan = plan_migration(frozen, dst.can_thaw)
    for r in frozen:
        dst.thaw(r)
    for r in waiting:
        dst.submit(r)
    dst.drain()
    migrate = {
        "migrated": len(plan.migrated), "fallback": len(plan.fallback),
        "recovered_tokens": plan.recovered_tokens,
        "dst_prefill_calls": dst.stats.prefill_calls,
        "dst_thaws": dst.stats.thaws,
        "bit_identical": [tuple(r.generated) for r in reqs] == want,
    }
    if trace_path:
        write_trace(rec, trace_path)
        migrate["trace"] = trace_path

    # the requeue-only alternative: same scenario, incompatible peer
    reqs2, frozen2, waiting2 = run_src()
    dst2 = _engine(params, cc, decode_mode="paged", slots=2,
                   params_version="other-weights")
    for r in frozen2:
        dst2.thaw(r)
    for r in waiting2:
        dst2.submit(r)
    dst2.drain()
    requeue = {
        "dst_prefill_calls": dst2.stats.prefill_calls,
        "dst_thaws": dst2.stats.thaws,
        "reprefilled_tokens": frozen_tokens,    # re-earned through prefill
        "no_token_loss": all(len(r.generated) == 24 for r in reqs2),
    }
    return {"thaw": migrate, "requeue_fallback": requeue,
            "frozen_tokens_at_handoff": frozen_tokens}


def run(quick: bool = False, json_path: str = "BENCH_paging.json",
        trace_path: str = "BENCH_paging_trace.json") -> None:
    header("paging: paged KV cache, prefix sharing, freeze/thaw migration")
    steps = 12 if quick else 48
    params = init_params(CFG, jax.random.PRNGKey(0))
    cc = CompileCache()
    results = {"config": {"quick": quick, "steps": steps, "arch": CFG.name,
                          "max_seq": MAX_SEQ, "block_size": BLOCK_SIZE,
                          "backend": jax.default_backend()}}

    diff = _differential(params, cc, steps)
    results["differential"] = diff
    emit("paging.decode.paged", 0.0,
         f"tok_per_s={diff['paged']['tokens_per_s']:.0f}")
    emit("paging.decode.dense", 0.0,
         f"tok_per_s={diff['batched']['tokens_per_s']:.0f}")
    emit("paging.bit_identical", 0.0, str(int(diff["bit_identical"])))
    emit("paging.second_engine_recompiles", 0.0,
         str(diff["second_paged_engine_recompiles"]))

    res = _residency(params, cc, attempts=8 if quick else 12)
    results["residency"] = res
    emit("paging.residency", 0.0,
         f"dense={res['dense_resident']};paged={res['paged_resident']};"
         f"gain=x{res['residency_gain']:.1f};"
         f"shared_blocks={res['shared_blocks']}")

    adm = _prefix_admission(params, cc, rounds=3 if quick else 5)
    results["prefix_admission"] = adm
    emit("paging.admit.cold", adm["cold_p50_ttft_ms"] * 1e3,
         f"prefill_calls={adm['cold_prefill_calls']}")
    emit("paging.admit.prefix_hit", adm["hit_p50_ttft_ms"] * 1e3,
         f"prefill_calls={adm['hit_prefill_calls']};"
         f"speedup=x{adm['ttft_speedup']:.2f}")

    mig = _migration(params, cc, trace_path=trace_path)
    results["migration"] = mig
    emit("paging.migrate.thaw", 0.0,
         f"migrated={mig['thaw']['migrated']};"
         f"recovered_tokens={mig['thaw']['recovered_tokens']};"
         f"dst_prefill_calls={mig['thaw']['dst_prefill_calls']}")
    emit("paging.migrate.requeue", 0.0,
         f"dst_prefill_calls={mig['requeue_fallback']['dst_prefill_calls']};"
         f"reprefilled_tokens={mig['requeue_fallback']['reprefilled_tokens']}")

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {json_path}")

    if quick:
        # CI smoke: structural claims only (throughput magnitudes are
        # machine-dependent)
        assert diff["bit_identical"], "paged decode diverged from dense"
        assert diff["second_paged_engine_recompiles"] == 0, \
            "block-table shapes leaked into a jit key"
        assert res["paged_resident"] > res["dense_resident"], \
            "prefix sharing bought no residency at fixed memory"
        assert res["shared_blocks"] > 0, "no blocks were actually shared"
        assert adm["hit_prefill_calls"] == 0, \
            "prefix-hit admission still called prefill"
        assert mig["thaw"]["fallback"] == 0, \
            "compatible thaw fell back to re-prefill"
        assert mig["thaw"]["bit_identical"], \
            "migrated streams diverged from the uninterrupted run"
        assert mig["thaw"]["recovered_tokens"] > 0
        # only never-admitted requests may prefill on the destination
        assert mig["thaw"]["dst_prefill_calls"] <= \
            4 - mig["thaw"]["dst_thaws"], \
            "a thawed request re-prefilled on the destination"
        assert mig["requeue_fallback"]["no_token_loss"]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_paging.json")
    ap.add_argument("--trace", default="BENCH_paging_trace.json",
                    help="where the migration scenario exports its Chrome "
                         "trace (validated by tools/check_trace.py)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick, json_path=args.json, trace_path=args.trace)
