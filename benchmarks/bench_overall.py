"""Paper Fig. 8/9 + Table I: overall accuracy/latency/memory trade-off of
the middleware vs AdaDeep across model sizes and device profiles.

Models: three elastic backbones standing in for ResNet18/34/VGG16; devices:
profiler hardware profiles standing in for RPi-4B / Jetson-class / v5e.
Measured CPU wall-time on the smallest model anchors the estimated ranks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import adadeep_select
from repro.configs import get_config
from repro.core import (ActionEvaluator, Budgets, ResourceContext,
                        MOBILE_CPU, TPU_V5E)
from repro.core.actions import Action
from repro.core.loop import AdaptationLoop
from repro.elastic import ElasticSupernet, derive_variant
from repro.models import forward, init_params
from repro.models.configs import InputShape

from .common import emit, header, time_fn

SIZES = {
    "backbone-S(resnet18)": dict(num_layers=6, d_model=192, d_ff=768,
                                 num_heads=6, num_kv_heads=6, head_dim=32),
    "backbone-M(resnet34)": dict(num_layers=12, d_model=256, d_ff=1024,
                                 num_heads=8, num_kv_heads=8, head_dim=32),
    "backbone-L(vgg16)": dict(num_layers=16, d_model=384, d_ff=1536,
                              num_heads=8, num_kv_heads=8, head_dim=48),
}
DEVICES = {"rpi4b": MOBILE_CPU, "v5e": TPU_V5E}


def run() -> None:
    header("overall: middleware vs AdaDeep (Fig 8/9, Table I)")
    shape = InputShape("bench", 256, 4, "prefill")
    base_cfg = get_config("paper-backbone")
    for name, kw in SIZES.items():
        cfg = base_cfg.with_updates(name=name, **kw)
        # evaluate against the mobile-CPU profile (the paper's testbed
        # class) so the latency budget actually binds
        ev = ActionEvaluator(cfg, shape, hw=MOBILE_CPU)
        ctx = ResourceContext()
        lat_budget = ev.evaluate(Action(), ctx).latency_s * 0.6

        ada_spec = adadeep_select(cfg, shape, lat_budget, ev)
        ada = ev.evaluate(Action(variant=ada_spec), ctx)

        loop = AdaptationLoop(cfg=cfg, shape=shape, allow_offload=True,
                              hw=MOBILE_CPU,
                              budgets=Budgets(latency_s=lat_budget))
        loop.build_pareto(evolve=False)
        ours = loop.tick(ctx).eval

        # real CPU wall-time for the chosen variants (smallest model only
        # gets full measurement; ranks must agree with estimates)
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                    cfg.vocab_size)
        vcfg_a, vp_a = derive_variant(cfg, params, ada_spec)
        vcfg_o, vp_o = derive_variant(cfg, params, ours.action.variant)
        f_a = jax.jit(lambda p, t: forward(p, vcfg_a, t)[0])
        f_o = jax.jit(lambda p, t: forward(p, vcfg_o, t)[0])
        us_a = time_fn(f_a, vp_a, tokens)
        us_o = time_fn(f_o, vp_o, tokens)

        emit(f"overall.{name}.adadeep", us_a,
             f"estT={ada.latency_s*1e3:.2f}ms;A={ada.accuracy:.3f};"
             f"M={ada.memory_bytes/1e6:.0f}MB")
        emit(f"overall.{name}.crowdhmtware", us_o,
             f"estT={ours.latency_s*1e3:.2f}ms;A={ours.accuracy:.3f};"
             f"M={ours.memory_bytes/1e6:.0f}MB;"
             f"accx={ours.accuracy-ada.accuracy:+.3f};"
             f"memx={ada.memory_bytes/max(ours.memory_bytes,1):.2f}")

    # Table I flavor: normalized gains across device profiles
    for dev_name, hw in DEVICES.items():
        cfg = base_cfg
        ev = ActionEvaluator(cfg, shape, hw=hw)
        ctx = ResourceContext()
        full = ev.evaluate(Action(), ctx)
        loop = AdaptationLoop(cfg=cfg, shape=shape, hw=hw,
                              allow_offload=False)
        loop.build_pareto(evolve=False)
        d = loop.tick(ctx).eval
        emit(f"overall.device.{dev_name}", d.latency_s * 1e6,
             f"latencyx={full.latency_s/max(d.latency_s,1e-12):.2f};"
             f"energyx={full.energy_j/max(d.energy_j,1e-12):.2f};"
             f"accdelta={d.accuracy-full.accuracy:+.3f}")


if __name__ == "__main__":
    run()
