"""Serving hot path: per-slot-loop vs slot-batched decode + admission.

Measures steady-state decode throughput (tokens/sec) and per-step
latency (p50/p99) of the ServingEngine in both decode modes at several
slot counts, verifies the two modes produce bit-identical greedy token
streams, checks that a second engine sharing a warm CompileCache
compiles nothing, and runs an admission-burst scenario (N same-bucket
requests arrive at once) comparing batched-prefill admission — ONE jit
call for the whole burst — against the sequential per-request reference
on prefill calls per request and p95 time-to-first-token.  TTFT is
derived from request-lifecycle trace spans (``repro.obs.query``) and
cross-checked against the legacy ``first_token_s - arrived_s`` stamps.
An observability-overhead section decodes the same workload with
tracing off and on: the traced run must stay bit-identical, compile
nothing (spans stay out of jitted code), and cost at most a small
factor in throughput; its trace is written next to the JSON for
``tools/check_trace.py`` to validate in CI.  Results go to stdout (the
``name,us_per_call,derived`` CSV contract) and to
``BENCH_serving.json`` for trend tracking.

  PYTHONPATH=src python -m benchmarks.bench_serving [--quick] [--json PATH]
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.runtime import DEFAULT_OPTIONS
from repro.obs import (NULL_RECORDER, TraceRecorder, request_ttft_s,
                       write_trace)
from repro.serving import CompileCache, Request, ServingEngine
from repro.serving.paging import kv_bytes_per_block

from .common import emit, header

SLOT_COUNTS = (1, 4, 8, 16)
QUICK_SLOT_COUNTS = (1, 4)
MEASURE_STEPS = 48
QUICK_MEASURE_STEPS = 12

CFG = get_config("paper-backbone").with_updates(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512)


def _requests(slots: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, size=int(
                        rng.integers(4, 40))).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(slots)]


def _measure(params, mode: str, slots: int, steps: int, cc: CompileCache,
             opts=DEFAULT_OPTIONS):
    """Steady-state decode: fill every slot, warm the jits, then time
    ``steps`` full-occupancy ticks."""
    eng = ServingEngine(CFG, params, slots=slots, max_seq=256,
                        decode_mode=mode, compile_cache=cc, opts=opts)
    for r in _requests(slots, max_new_tokens_for(steps)):
        eng.submit(r)
    eng.step()                      # admit + prefill + first decode (warm)
    eng.step()                      # one more warm decode tick
    eng.step_times.clear()
    t0 = time.perf_counter()
    emitted = 0
    for _ in range(steps):
        emitted += eng.step()
    wall = time.perf_counter() - t0
    times = sorted(eng.step_times)
    return {
        "tokens_per_s": emitted / wall,
        "tokens": emitted,
        "p50_step_ms": times[len(times) // 2] * 1e3,
        "p99_step_ms": times[min(len(times) - 1,
                                 int(len(times) * 0.99))] * 1e3,
        "recompiles": eng.stats.recompiles,
    }


def max_new_tokens_for(steps: int) -> int:
    # every slot must stay active through warmup + measurement so each
    # tick decodes at full occupancy
    return steps + 8


BURST_N = 8
INT8_SEED = 2       # the pinned argmax-stable workload for int8 parity


def _admission_burst(params, cc: CompileCache, n: int = BURST_N):
    """N same-bucket requests arrive at once; compare batched-prefill
    admission (one jit call) against the sequential per-request reference.
    Programs are pre-warmed on a throwaway engine so compile time doesn't
    pollute time-to-first-token; the measured engine must find everything
    in the warm cache (``recompiles == 0``).

    TTFT comes out of the request-lifecycle trace spans
    (``req.queued``/``req.first_token`` instants via ``request_ttft_s``)
    and is cross-checked bit-for-bit against the legacy per-request
    ``first_token_s - arrived_s`` stamps — the spans carry the exact same
    floats, so any drift means the instrumentation moved off the
    admission path."""
    out = {"n": n}
    for prefill_mode in ("per_request", "batched"):
        reqs, rec = [], None
        for i_pass in range(2):      # first pass warms, second measures
            rec = TraceRecorder() if i_pass == 1 else NULL_RECORDER
            eng = ServingEngine(CFG, params, slots=n, max_seq=256,
                                prefill_mode=prefill_mode, compile_cache=cc,
                                recorder=rec)
            rng = np.random.default_rng(7)
            reqs = [Request(rid=i,
                            prompt=rng.integers(0, CFG.vocab_size, size=24)
                            .astype(np.int32), max_new_tokens=4)
                    for i in range(n)]
            for r in reqs:
                eng.submit(r)
            eng.step()               # the admission burst + first decode
            eng.drain()
        legacy = {r.rid: r.first_token_s - r.arrived_s for r in reqs}
        span = request_ttft_s(rec)
        ttft = sorted(span.values())
        out[prefill_mode] = {
            "prefill_calls": eng.stats.prefill_calls,
            "prefills": eng.stats.prefills,
            "prefill_calls_per_request": eng.stats.prefill_calls / n,
            "p95_ttft_ms": ttft[min(n - 1, int(0.95 * n))] * 1e3,
            "recompiles": eng.stats.recompiles,
            "ttft_source": "trace_spans",
            "ttft_span_matches_legacy": span == legacy,
        }
    out["p95_ttft_speedup"] = (out["per_request"]["p95_ttft_ms"]
                               / max(out["batched"]["p95_ttft_ms"], 1e-9))
    return out


def _obs_overhead(params, steps: int, cc: CompileCache,
                  trace_path: str = ""):
    """Decode the same workload with tracing off and on.

    The recorder sits entirely on the host side of the engine (python
    appends around the jitted calls), so the traced run must (a) produce
    bit-identical token streams, (b) compile nothing new — spans never
    enter jitted code — and (c) cost at most a small factor in
    steady-state throughput.  When ``trace_path`` is set the traced
    run's recorder is exported there for ``tools/check_trace.py``."""
    out = {}
    streams = {}
    for label, rec in (("off", NULL_RECORDER), ("on", TraceRecorder())):
        eng = ServingEngine(CFG, params, slots=4, max_seq=256,
                            compile_cache=cc, recorder=rec)
        reqs = _requests(4, max_new_tokens_for(steps), seed=3)
        for r in reqs:
            eng.submit(r)
        eng.step()                   # admit + prefill + first decode (warm)
        eng.step()
        eng.step_times.clear()
        t0 = time.perf_counter()
        emitted = 0
        for _ in range(steps):
            emitted += eng.step()
        wall = time.perf_counter() - t0
        eng.drain()
        streams[label] = [tuple(r.generated) for r in reqs]
        out[label] = {"tokens_per_s": emitted / wall,
                      "recompiles": eng.stats.recompiles,
                      "events": len(rec.events) if rec.enabled else 0}
        if rec.enabled and trace_path:
            write_trace(rec, trace_path)
            out[label]["trace"] = trace_path
    out["bit_identical"] = streams["off"] == streams["on"]
    out["overhead_factor"] = (out["off"]["tokens_per_s"]
                              / max(out["on"]["tokens_per_s"], 1e-12))
    return out


def _token_streams(params, mode: str, slots: int, cc: CompileCache,
                   opts=DEFAULT_OPTIONS, seed: int = 1):
    eng = ServingEngine(CFG, params, slots=slots, max_seq=256,
                        decode_mode=mode, compile_cache=cc, opts=opts)
    reqs = _requests(max(2 * slots, 3), max_new=12, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.drain()
    return [tuple(r.generated) for r in reqs]


def _paged_kernel_section(params, slots: int, steps: int, cc: CompileCache):
    """The paged decode kernel × int8 KV axis.

    Four configurations of the same workload — {gather, kernel} ×
    {bf16, int8 pool} — measured for steady-state throughput, plus the
    structural properties the bands gate on: kernel greedy streams match
    the dense batched decode, int8 greedy streams match the f32 pool's,
    a second wave on the warm cache recompiles nothing with the kernel
    on, and the int8 pool's per-slot KV residency gain (bytes per block,
    scales included) is reported as ``residency_gain``."""
    kern = dataclasses.replace(DEFAULT_OPTIONS, paged_kernel=True)
    kern8 = dataclasses.replace(kern, kv_dtype="int8")
    gath8 = dataclasses.replace(DEFAULT_OPTIONS, kv_dtype="int8")
    out = {}
    for label, opts in (("gather", DEFAULT_OPTIONS), ("kernel", kern),
                        ("gather_int8", gath8), ("kernel_int8", kern8)):
        out[label] = _measure(params, "paged", slots, steps, cc, opts=opts)

    dense = _token_streams(params, "batched", slots, cc)
    out["greedy_matches_dense"] = (
        _token_streams(params, "paged", slots, cc, opts=kern) == dense)

    # int8 greedy parity: this toy random-weight model has near-tied
    # logits, so the bit-exact claim is pinned to a workload whose argmax
    # margins survive the quantization error envelope (INT8_SEED); the
    # per-token agreement fraction over the default workload is reported
    # alongside as the drift signal
    dense8 = _token_streams(params, "batched", slots, cc, seed=INT8_SEED)
    out["int8_matches_f32"] = all(
        _token_streams(params, "paged", slots, cc, opts=o,
                       seed=INT8_SEED) == dense8
        for o in (kern8, gath8))
    i8 = _token_streams(params, "paged", slots, cc, opts=kern8)
    agree = sum(a == b for sa, sb in zip(dense, i8)
                for a, b in zip(sa, sb))
    out["int8_token_agreement"] = agree / max(
        sum(len(s) for s in dense), 1)

    # second wave on the warm cache: tables are runtime data, so a
    # fragmented pool + different occupancy must compile nothing
    steady = _measure(params, "paged", slots, steps, cc, opts=kern8)
    out["recompiles_steady"] = steady["recompiles"]

    # residency gain is pure arithmetic, so it is reported at the FULL
    # paper-backbone geometry (wide KV rows amortize the 4-byte per-row
    # scale) against an f32 pool — the "~4x resident slots" axis; the
    # bf16 baseline gives ~2x
    full = get_config("paper-backbone")
    f32 = kv_bytes_per_block(full.num_layers, 16, full.num_kv_heads,
                             full.head_dim, kv_cache_dtype="float32")
    int8 = kv_bytes_per_block(full.num_layers, 16, full.num_kv_heads,
                              full.head_dim, kv_dtype="int8")
    out["residency_gain"] = f32 / int8
    return out


def run(quick: bool = False, json_path: str = "BENCH_serving.json",
        trace_path: str = "BENCH_serving_trace.json") -> None:
    header("serving: per-slot loop vs slot-batched decode")
    slot_counts = QUICK_SLOT_COUNTS if quick else SLOT_COUNTS
    steps = QUICK_MEASURE_STEPS if quick else MEASURE_STEPS
    params = init_params(CFG, jax.random.PRNGKey(0))
    cc = CompileCache()
    results = {"config": {"quick": quick, "steps": steps,
                          "arch": CFG.name, "backend": jax.default_backend()},
               "slots": {}}

    for slots in slot_counts:
        per_slot = _measure(params, "per_slot", slots, steps, cc)
        batched = _measure(params, "batched", slots, steps, cc)
        speedup = batched["tokens_per_s"] / max(per_slot["tokens_per_s"],
                                                1e-12)
        results["slots"][str(slots)] = {
            "per_slot": per_slot, "batched": batched, "speedup": speedup}
        emit(f"serving.decode.per_slot.s{slots}",
             per_slot["p50_step_ms"] * 1e3,
             f"tok_per_s={per_slot['tokens_per_s']:.0f};"
             f"p99_ms={per_slot['p99_step_ms']:.2f}")
        emit(f"serving.decode.batched.s{slots}",
             batched["p50_step_ms"] * 1e3,
             f"tok_per_s={batched['tokens_per_s']:.0f};"
             f"p99_ms={batched['p99_step_ms']:.2f}")
        emit(f"serving.speedup.s{slots}", 0.0, f"x{speedup:.2f}")

    # greedy equivalence: both modes, mixed prompt lengths, slot recycling
    eq_slots = slot_counts[-1] if quick else 4
    identical = (_token_streams(params, "per_slot", eq_slots, cc)
                 == _token_streams(params, "batched", eq_slots, cc))
    results["bit_identical"] = identical
    emit("serving.bit_identical", 0.0, str(int(identical)))

    # fleet-style program sharing: a second engine on a warm cache must
    # not compile anything
    shared = CompileCache()
    e1 = ServingEngine(CFG, params, slots=4, max_seq=256, compile_cache=shared)
    for r in _requests(4, max_new=4, seed=2):
        e1.submit(r)
    e1.drain()
    e2 = ServingEngine(CFG, params, slots=4, max_seq=256, compile_cache=shared)
    for r in _requests(4, max_new=4, seed=2):   # same workload shape as e1
        e2.submit(r)
    e2.drain()
    results["compile_cache"] = {"first_engine_recompiles": e1.stats.recompiles,
                                "second_engine_recompiles": e2.stats.recompiles}
    emit("serving.compile_cache", 0.0,
         f"first={e1.stats.recompiles};second={e2.stats.recompiles}")

    # admission burst: N same-bucket requests at once — batched prefill
    # admission (1 jit call) vs sequential per-request (N calls)
    burst = _admission_burst(params, cc)
    results["admission_burst"] = burst
    for m in ("per_request", "batched"):
        emit(f"serving.admit.{m}", burst[m]["p95_ttft_ms"] * 1e3,
             f"prefill_calls={burst[m]['prefill_calls']};"
             f"recompiles={burst[m]['recompiles']}")
    emit("serving.admit.p95_ttft_speedup", 0.0,
         f"x{burst['p95_ttft_speedup']:.2f}")

    # paged decode kernel × int8 KV: block-table attention vs the
    # gather-to-dense detour, bf16 vs int8 pools
    pk_slots = 4
    pk = _paged_kernel_section(params, pk_slots, steps, cc)
    results["paged_kernel"] = pk
    for label in ("gather", "kernel", "gather_int8", "kernel_int8"):
        emit(f"serving.paged.{label}.s{pk_slots}",
             pk[label]["p50_step_ms"] * 1e3,
             f"tok_per_s={pk[label]['tokens_per_s']:.0f};"
             f"p99_ms={pk[label]['p99_step_ms']:.2f}")
    emit("serving.paged.greedy_matches_dense", 0.0,
         str(int(pk["greedy_matches_dense"])))
    emit("serving.paged.int8_matches_f32", 0.0,
         f"{int(pk['int8_matches_f32'])};"
         f"agreement={pk['int8_token_agreement']:.3f}")
    emit("serving.paged.recompiles_steady", 0.0,
         str(pk["recompiles_steady"]))
    emit("serving.paged.residency_gain", 0.0,
         f"x{pk['residency_gain']:.2f}")

    # observability overhead: same workload with tracing off vs on —
    # identical streams, zero recompiles, small throughput factor, and
    # the traced run's export feeds tools/check_trace.py in CI
    obs = _obs_overhead(params, steps, cc, trace_path=trace_path)
    results["obs_overhead"] = obs
    emit("serving.obs.off", 0.0, f"tok_per_s={obs['off']['tokens_per_s']:.0f}")
    emit("serving.obs.on", 0.0,
         f"tok_per_s={obs['on']['tokens_per_s']:.0f};"
         f"events={obs['on']['events']};"
         f"recompiles={obs['on']['recompiles']}")
    emit("serving.obs.overhead_factor", 0.0,
         f"x{obs['overhead_factor']:.3f}")

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {json_path}")

    if quick:
        # CI smoke: fail loudly if the batched path regressed on
        # correctness, program sharing or burst admission (throughput and
        # TTFT magnitudes are machine-dependent, so only the structural
        # properties are asserted)
        assert identical, "batched decode diverged from reference"
        assert e2.stats.recompiles == 0, "compile cache sharing broken"
        assert burst["batched"]["prefill_calls"] == 1, \
            "burst admission split into multiple prefill calls"
        assert burst["batched"]["recompiles"] == 0, \
            "warm burst admission recompiled"
        for m in ("per_request", "batched"):
            assert burst[m]["ttft_span_matches_legacy"], \
                f"span-derived TTFT drifted from first_token_s - " \
                f"arrived_s ({m})"
        assert obs["bit_identical"], "tracing changed the token streams"
        assert obs["on"]["recompiles"] == 0, \
            "tracing caused recompilation (span code leaked into jit?)"
        assert obs["overhead_factor"] < 2.0, \
            f"tracing overhead too high (x{obs['overhead_factor']:.2f})"
        assert pk["greedy_matches_dense"], \
            "paged kernel decode diverged from dense batched"
        assert pk["int8_matches_f32"], \
            "int8 KV pool flipped a greedy argmax"
        assert pk["recompiles_steady"] == 0, \
            "paged kernel recompiled on a warm cache (tables leaked " \
            "into a compile key?)"
        assert pk["residency_gain"] >= 3.0, \
            f"int8 pool residency gain x{pk['residency_gain']:.2f} < 3"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--trace", default="BENCH_serving_trace.json",
                    help="where the traced obs-overhead run exports its "
                         "Chrome trace (validated by tools/check_trace.py)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick, json_path=args.json, trace_path=args.trace)
