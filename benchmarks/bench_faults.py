"""Self-healing under injected faults: MTTD/MTTR, goodput, token loss.

Three measurements over the placement acceptance fleet (loaded phone +
two same-site jetson helpers + a WAN server):

1. **fault-free overhead** — the heartbeat detector enabled on a
   healthy fleet must be *free*: per-wake records and placement logs
   bit-identical to a detector-off run, and an engine sharing the warm
   compile cache reports zero recompiles.
2. **detection/recovery latency** — a deterministic schedule (helper
   crash + helper freeze) drives the suspect→dead state machine; the
   exported trace yields MTTD (fault → first ``detector.suspect``) and
   MTTR (fault → first re-placement after ``fleet.evict``) via
   :func:`repro.faults.summarize_faults`.
3. **goodput under chaos** — an engine-backed phone streams requests
   while the schedule crashes its placed helper, drops helper
   telemetry and OOMs admissions; tokens generated in the same horizon
   are compared against a fault-free twin (ratio must clear
   ``GOODPUT_FLOOR``) and every request must finish with its full
   budget — ``tokens_lost`` and ``tokens_duplicated`` must both be 0.

Writes ``BENCH_faults.json`` (committed) and, when ``--trace`` is
given, a Chrome trace of the chaos run for ``tools/check_trace.py``.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.monitor import ResourceContext, constant_trace
from repro.faults import (CRASH, FREEZE, OOM, TELEMETRY_LOSS, FaultInjector,
                          FaultSpec, schedule_to_json, summarize_faults)
from repro.fleet import FleetController, make_device
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.obs import TraceRecorder, write_trace
from repro.serving import Request

from .common import emit, header

JSON_PATH = "BENCH_faults.json"
HORIZON_S, QUICK_HORIZON_S = 30.0, 12.0
GOODPUT_FLOOR = 0.5            # chaos goodput ≥ this × fault-free
N_REQS, TOKENS_PER_REQ = 8, 16

LOADED = ResourceContext(cpu_temp_derate=0.45, competing_procs=4,
                         battery_frac=0.8, mem_free_frac=0.7)
PHONE_SLA_S = 0.5

# reduced model: real jitted decode steps, cheap enough for a benchmark
TINY_UPDATES = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=300)


def _fleet():
    return (make_device("pixel_6_cpu", 0, site="home"),
            make_device("jetson_agx_orin", 0, site="home"),
            make_device("jetson_agx_orin", 1, site="home"),
            make_device("edge_server_a100", 0, site="dc"))


def _trace_factory(phone_id):
    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone_id else ResourceContext(), n)
    return tf


def _controller(fleet, cfg, shape, *, detection=True, recorder=None,
                compile_cache=None):
    kw = {}
    if recorder is not None:
        kw["recorder"] = recorder
    if compile_cache is not None:
        kw["compile_cache"] = compile_cache
    ctl = FleetController(
        list(fleet), cfg, shape, trace_ticks=8000,
        trace_factory=_trace_factory(fleet[0].device_id),
        placement=True, allow_offload=False, detection=detection,
        warmup_ticks=4, recalibrate_every=2, **kw)
    ctl.set_sla(fleet[0].device_id, PHONE_SLA_S)
    return ctl


def _record_key(r):
    return (r.device_id, r.tick, r.observed_s, r.predicted_s, r.violated)


def _fault_free(cfg, shape, horizon):
    """Detector-on vs detector-off on a healthy fleet: bit-identical."""
    runs = {}
    for detection in (True, False):
        ctl = _controller(_fleet(), cfg, shape, detection=detection)
        ctl.run_for(horizon)
        runs[detection] = ctl
    a, b = runs[True], runs[False]
    identical = ([_record_key(r) for r in a.records]
                 == [_record_key(r) for r in b.records]
                 and [(t, d.hosts) for t, _, d in a.placement_log]
                 == [(t, d.hosts) for t, _, d in b.placement_log])
    return {"records": len(a.records),
            "placements": len(a.placement_log),
            "bit_identical": bool(identical),
            "detector_suspects":
                int(a.metrics.counter("fleet.detector_suspects").value),
            "evictions": int(a.metrics.counter("fleet.evictions").value)}


def _detection(cfg, shape, horizon):
    """Crash + freeze a helper each; measure MTTD/MTTR from the trace."""
    fleet = _fleet()
    phone = fleet[0].device_id
    rec = TraceRecorder()
    ctl = _controller(fleet, cfg, shape, recorder=rec)
    schedule = [
        FaultSpec(CRASH, fleet[1].device_id, at_s=0.40 * horizon),
        FaultSpec(FREEZE, fleet[2].device_id, at_s=0.60 * horizon,
                  duration_s=0.30 * horizon),
        FaultSpec(TELEMETRY_LOSS, fleet[3].device_id,
                  at_s=0.30 * horizon, duration_s=0.20 * horizon,
                  magnitude=0.7),
    ]
    inj = FaultInjector(ctl, schedule).arm()
    ctl.run_for(horizon)
    summ = summarize_faults(rec.events)
    out = dict(summ)                 # outcomes already serialized
    out["schedule"] = schedule_to_json(schedule)
    out["applied"] = len(inj.applied)
    out["skipped"] = len(inj.skipped)
    out["phone_wakes"] = int(ctl.tick_counts[phone])
    out["evictions"] = int(ctl.metrics.counter("fleet.evictions").value)
    out["readmissions"] = \
        int(ctl.metrics.counter("fleet.readmissions").value)
    out["degraded_fallbacks"] = \
        int(ctl.metrics.counter("fleet.degraded_fallbacks").value)
    return out, rec


def _goodput_run(cfg, shape, tiny, params, horizon, *, faulted,
                 compile_cache=None, recorder=None):
    fleet = _fleet()
    phone = fleet[0].device_id
    ctl = _controller(fleet, cfg, shape, compile_cache=compile_cache,
                      recorder=recorder)
    eng = ctl.build_engine(phone, params, cfg=tiny, slots=2, max_seq=96,
                           steps_per_tick=2)
    reqs = []
    for i in range(N_REQS):
        rng = np.random.default_rng(17 * i + 3)
        r = Request(rid=i,
                    prompt=rng.integers(0, tiny.vocab_size,
                                        size=6 + i % 4).astype(np.int32),
                    max_new_tokens=TOKENS_PER_REQ)
        reqs.append(r)
        eng.submit(r)
    if faulted:
        helper = fleet[1].device_id
        FaultInjector(ctl, [
            FaultSpec(CRASH, helper, at_s=0.35 * horizon),
            FaultSpec(TELEMETRY_LOSS, fleet[2].device_id,
                      at_s=0.30 * horizon, duration_s=0.25 * horizon,
                      magnitude=0.8),
            FaultSpec(OOM, phone, at_s=0.25 * horizon, magnitude=2),
        ]).arm()
    ctl.run_for(horizon)
    tokens_at_horizon = int(eng.stats.tokens_out)
    # drain to settle token-loss accounting: requeued continuations live
    # in the engine queue (the swap-requeue contract replaces Requests)
    final = {r.rid: r for r in reqs}
    final.update({r.rid: r for r in eng._queue})
    eng.drain()
    final.update({r.rid: r for r in eng._queue})
    lost = sum(max(r.max_new_tokens - len(r.generated), 0)
               for r in final.values())
    dup = sum(max(len(r.generated) - r.max_new_tokens, 0)
              for r in final.values())
    return {"tokens_at_horizon": tokens_at_horizon,
            "tokens_total": int(eng.stats.tokens_out),
            "tokens_lost": int(lost),
            "tokens_duplicated": int(dup),
            "all_done": bool(all(r.done for r in final.values())),
            "oom_events": int(eng.stats.oom_events),
            "requeues": int(eng.stats.requeues),
            "recompiles": int(eng.stats.recompiles)}, ctl


def run(quick: bool = False, json_path: str = JSON_PATH,
        trace_path: str = "") -> None:
    header("fault injection + self-healing")
    cfg = get_config("paper-backbone")
    shape = InputShape("faults", 256, 4, "prefill")
    tiny = cfg.with_updates(**TINY_UPDATES)
    params = init_params(tiny, jax.random.PRNGKey(0))
    horizon = QUICK_HORIZON_S if quick else HORIZON_S
    fleet = _fleet()
    results = {"config": {"quick": quick, "arch": cfg.name,
                          "devices": [d.device_id for d in fleet],
                          "horizon_s": horizon,
                          "goodput_floor": GOODPUT_FLOOR,
                          "n_requests": N_REQS,
                          "tokens_per_request": TOKENS_PER_REQ}}

    # ---- 1. fault-free overhead: the detector must be free -------------
    ff = _fault_free(cfg, shape, horizon)
    results["fault_free"] = ff
    emit("faults.fault_free", 0.0,
         f"bit_identical={int(ff['bit_identical'])};"
         f"records={ff['records']};suspects={ff['detector_suspects']}")

    # ---- 2. MTTD / MTTR from the trace timeline ------------------------
    det, _ = _detection(cfg, shape, horizon)
    results["detection"] = det
    mttd = det["mean_mttd_s"] or 0.0
    mttr = det["mean_mttr_s"] or 0.0
    emit("faults.mttd", mttd * 1e6,
         f"max_us={(det['max_mttd_s'] or 0)*1e6:.0f};"
         f"detected={det['detected']}/{det['silent_faults']}")
    emit("faults.mttr", mttr * 1e6,
         f"max_us={(det['max_mttr_s'] or 0)*1e6:.0f};"
         f"evictions={det['evictions']};"
         f"readmissions={det['readmissions']}")

    # ---- 3. goodput under chaos vs fault-free twin ---------------------
    base, base_ctl = _goodput_run(cfg, shape, tiny, params, horizon,
                                  faulted=False)
    # the chaos twin reuses the warm compile cache: healing costs no jit
    chaos_rec = TraceRecorder() if trace_path else None
    chaos, _ = _goodput_run(cfg, shape, tiny, params, horizon,
                            faulted=True,
                            compile_cache=base_ctl.compile_cache,
                            recorder=chaos_rec)
    if trace_path:
        # the chaos run is the four-layer showcase: request + engine +
        # fleet + placement events, with faults/detector/recovery on top
        write_trace(chaos_rec, trace_path)
    ratio = (chaos["tokens_at_horizon"]
             / max(base["tokens_at_horizon"], 1))
    results["goodput"] = {
        "baseline": base, "chaos": chaos,
        "ratio": ratio,
        "meets_floor": bool(ratio >= GOODPUT_FLOOR),
    }
    emit("faults.goodput", 0.0,
         f"ratio={ratio:.2f};floor={GOODPUT_FLOOR};"
         f"base_tokens={base['tokens_at_horizon']};"
         f"chaos_tokens={chaos['tokens_at_horizon']};"
         f"lost={chaos['tokens_lost']};dup={chaos['tokens_duplicated']};"
         f"oom={chaos['oom_events']};recompiles={chaos['recompiles']}")

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=JSON_PATH)
    ap.add_argument("--trace", default="",
                    help="also export the chaos run's Chrome trace here")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json, trace_path=args.trace)
