"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  PYTHONPATH=src python -m benchmarks.run [--only overall,engine,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("overall", "dynamic_budgets", "elastic", "offload", "engine",
          "ablation", "case_study", "tta", "roofline", "fleet", "serving",
          "placement", "faults", "paging")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES
    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"bench_{name},0.0,ERROR")
            traceback.print_exc()
        print(f"bench_{name}.wall,{(time.time()-t0)*1e6:.0f},", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
