"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  PYTHONPATH=src python -m benchmarks.run [--only overall,engine,...]

``--summary`` additionally folds the resulting ``BENCH_*.json``
artifacts into one labelled row of ``BENCH_trajectory.json`` after the
suites run (``--summary-only`` skips the suites and just re-folds the
artifacts already on disk); the extraction and upsert live in
``tools/check_perf.py`` so the trajectory row and the regression gate
read the artifacts identically.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import sys
import time
import traceback
from pathlib import Path

SUITES = ("overall", "dynamic_budgets", "elastic", "offload", "engine",
          "ablation", "case_study", "tta", "roofline", "fleet", "serving",
          "placement", "faults", "paging")

ROOT = Path(__file__).resolve().parents[1]


def _check_perf():
    """Load tools/check_perf.py (tools/ is not a package)."""
    spec = importlib.util.spec_from_file_location(
        "check_perf", ROOT / "tools" / "check_perf.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def write_summary(label: str, root: Path = ROOT) -> None:
    cp = _check_perf()
    entry = cp.trajectory_entry(root, label)
    cp.append_trajectory(root / cp.TRAJECTORY, entry)
    print(f"trajectory,{label},"
          f"{json.dumps(entry, sort_keys=True, default=str)}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--summary", action="store_true",
                    help="append a BENCH_trajectory.json row after the "
                         "suites run")
    ap.add_argument("--summary-only", action="store_true",
                    help="skip the suites; fold the BENCH_*.json already "
                         "on disk into BENCH_trajectory.json")
    ap.add_argument("--label", default="head",
                    help="trajectory row label (rows are upserted by "
                         "label, e.g. pr9)")
    args = ap.parse_args()
    if args.summary_only:
        write_summary(args.label)
        sys.exit(0)
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES
    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"bench_{name},0.0,ERROR")
            traceback.print_exc()
        print(f"bench_{name}.wall,{(time.time()-t0)*1e6:.0f},", flush=True)
    if args.summary and not failures:
        write_summary(args.label)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
