"""Fleet-aware cross-device placement: live peers vs static pools.

Three measurements on the ISSUE's acceptance scenario — a loaded
phone-tier member sharing a site with idle helpers:

1. **Predicted latency**: the FleetPlacer chain vs local-only execution
   vs the static ``edge_pair`` pool (the best the old placer could do).
2. **End-to-end p95**: the same fleet run through ``FleetController``
   with placement off vs on — the phone's observed per-wake latency
   distribution with and without same-site helpers.
3. **Re-placement reaction**: after a simulated helper slowdown
   (``inject_load``), how many clock events (device wakes) and how much
   simulated time pass before the controller moves the work.

The fleet run's placer keeps a per-decision audit trail
(:class:`~repro.fleet.placement.PlacementAudit`): every sweep records
the chains it considered with their scored latencies, how many were
DP-infeasible, which chain won and why, and whether hysteresis held the
incumbent.  That decision log lands in the JSON so a placement change
in a trend diff can be traced to the exact sweep that made it.

Results go to stdout (``name,us_per_call,derived`` CSV) and to
``BENCH_placement.json`` for trend tracking.

  PYTHONPATH=src python -m benchmarks.bench_placement [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.core.monitor import ResourceContext, constant_trace
from repro.fleet import FleetController, FleetPlacer, make_device
from repro.models.configs import InputShape
from repro.offload import DEVICE_POOLS, place_dp

from .common import emit, header

JSON_PATH = "BENCH_placement.json"
HORIZON_S, QUICK_HORIZON_S = 24.0, 8.0
REACT_S, QUICK_REACT_S = 8.0, 4.0

# the phone under load: throttled, contended, memory-pressured
LOADED = ResourceContext(cpu_temp_derate=0.45, competing_procs=4,
                         battery_frac=0.8, mem_free_frac=0.7)
PHONE_SLA_S = 0.5


def _fleet():
    """Loaded phone + two idle same-site jetson helpers + a WAN server."""
    return (make_device("pixel_6_cpu", 0, site="home"),
            make_device("jetson_agx_orin", 0, site="home"),
            make_device("jetson_agx_orin", 1, site="home"),
            make_device("edge_server_a100", 0, site="dc"))


def _trace_factory(phone_id):
    def tf(spec, n):
        return constant_trace(
            LOADED if spec.device_id == phone_id else ResourceContext(), n)
    return tf


def _controller(fleet, cfg, shape, placement: bool) -> FleetController:
    ctl = FleetController(
        list(fleet), cfg, shape, trace_ticks=4000,
        trace_factory=_trace_factory(fleet[0].device_id),
        placement=placement, allow_offload=False,
        warmup_ticks=4, recalibrate_every=2)
    ctl.set_sla(fleet[0].device_id, PHONE_SLA_S)
    return ctl


# the audit trail grows with the horizon (~190 sweeps × ~8 chains each
# ballooned the artifact to ~10k lines); keep the interesting edges —
# the first sweeps (cold placement) and the last (steady state) — and
# record how many middle entries were dropped
DECISION_LOG_KEEP = 12


def _decision_log(placer, keep: int = DECISION_LOG_KEEP) -> dict:
    """Summarize the placer's audit trail for the JSON artifact: the
    first/last ``keep`` decisions with the chains each scored, plus
    rollup counts over the FULL trail (how often hysteresis held the
    incumbent, how many candidates were DP-infeasible)."""
    audits = list(placer.audits)
    truncated = max(len(audits) - 2 * keep, 0)
    kept = audits if not truncated else audits[:keep] + audits[-keep:]
    decisions = []
    for a in kept:
        decisions.append({
            "requester": a.requester,
            "t_s": a.timestamp_s,
            "considered": len(a.considered),
            "infeasible": a.infeasible,
            "chosen": ">".join(a.chosen),
            "chosen_latency_s": a.chosen_latency_s,
            "reason": a.reason,
            "held_by_hysteresis": a.held_by_hysteresis,
            "chains": [{"hosts": ">".join(c), "latency_s": lat}
                       for c, lat in zip(a.considered, a.latencies)],
        })
    return {
        "decisions": decisions,
        "total": len(audits),
        "kept": len(kept),
        "truncated": truncated,
        "held_by_hysteresis": sum(
            1 for a in audits if a.held_by_hysteresis),
        "infeasible_total": sum(a.infeasible for a in audits),
    }


def run(quick: bool = False, json_path: str = JSON_PATH) -> None:
    header("fleet-aware cross-device placement")
    cfg = get_config("paper-backbone")
    shape = InputShape("fleet", 256, 4, "prefill")
    horizon = QUICK_HORIZON_S if quick else HORIZON_S
    react_horizon = QUICK_REACT_S if quick else REACT_S
    fleet = _fleet()
    phone = fleet[0]
    results = {"config": {"quick": quick, "arch": cfg.name,
                          "devices": [d.device_id for d in fleet],
                          "sites": {d.device_id: d.site for d in fleet},
                          "phone_sla_s": PHONE_SLA_S,
                          "horizon_s": horizon}}

    # ---- 1. predicted: fleet chain vs local vs static pool -------------
    placer = FleetPlacer(cfg)
    for d in fleet:
        placer.register(d)
    placer.update_member(phone.device_id, ctx=LOADED)
    dec = placer.place(phone.device_id)
    local_s = placer.local_decision(phone.device_id).latency_s
    static_s = place_dp(placer.pp, DEVICE_POOLS["edge_pair"]).latency_s
    results["predicted"] = {
        "local_only_s": local_s,
        "edge_pair_s": static_s,
        "fleet_s": dec.latency_s,
        "hosts": list(dec.hosts),
        "migration_s": dec.migration_s,
        "speedup_vs_local": local_s / dec.latency_s,
        "speedup_vs_edge_pair": static_s / dec.latency_s,
    }
    emit("placement.predicted", dec.latency_s * 1e6,
         f"local_us={local_s*1e6:.0f};edge_pair_us={static_s*1e6:.0f};"
         f"x_local={local_s/dec.latency_s:.1f};"
         f"x_edge_pair={static_s/dec.latency_s:.1f};"
         f"hosts={'>'.join(dec.hosts)}")

    # ---- 2. end-to-end p95 with vs without same-site helpers -----------
    p95 = {}
    for label, placement in (("local_only", False), ("fleet", True)):
        ctl = _controller(fleet, cfg, shape, placement)
        ctl.run_for(horizon)
        obs = np.array([r.observed_s for r in ctl.records
                        if r.device_id == phone.device_id])
        # skip the calibration/placement warmup half for a steady-state
        # distribution (identical window for both modes)
        steady = obs[len(obs) // 2:]
        p95[label] = {
            "p95_s": float(np.percentile(steady, 95)),
            "mean_s": float(steady.mean()),
            "wakes": int(len(obs)),
            "violations": ctl.violations(),
        }
        if placement:
            results["placement_events"] = ctl.placement_events
            results["decision_log"] = _decision_log(ctl.placer)
    speedup = p95["local_only"]["p95_s"] / max(p95["fleet"]["p95_s"], 1e-12)
    results["phone_p95"] = {**{f"{k}_{f}": v for k, d in p95.items()
                               for f, v in d.items()},
                           "p95_speedup": speedup}
    emit("placement.p95", p95["fleet"]["p95_s"] * 1e6,
         f"local_only_us={p95['local_only']['p95_s']*1e6:.0f};"
         f"speedup={speedup:.1f};"
         f"viol_local={p95['local_only']['violations']};"
         f"viol_fleet={p95['fleet']['violations']}")
    dlog = results["decision_log"]
    emit("placement.decisions", 0.0,
         f"total={dlog['total']};held={dlog['held_by_hysteresis']};"
         f"infeasible={dlog['infeasible_total']}")

    # ---- 3. reaction to a helper slowdown ------------------------------
    ctl = _controller(fleet, cfg, shape, True)
    ctl.run_for(horizon / 2)
    before = ctl.placement_of(phone.device_id)
    chosen = before.hosts[1] if before.offloaded else None
    reaction = {"placed_before": before.describe()}
    if chosen is not None:
        t0, w0 = ctl.now_s, ctl.wakes
        ctl.inject_load(chosen, 0.9)
        ctl.run_for(react_horizon)
        moves = [(ts, w, d) for ts, w, d in ctl.placement_log
                 if d.requester == phone.device_id and w >= w0]
        after = ctl.placement_of(phone.device_id)
        reaction.update({
            "slowed_helper": chosen,
            "reacted": bool(moves) and after.hosts != before.hosts,
            "reaction_events": moves[0][1] - w0 if moves else -1,
            "reaction_s": moves[0][0] - t0 if moves else -1.0,
            "placed_after": after.describe(),
        })
        emit("placement.reaction",
             (moves[0][0] - t0) * 1e6 if moves else 0.0,
             f"events={reaction['reaction_events']};"
             f"reacted={int(reaction['reacted'])};"
             f"from={chosen};to={'>'.join(after.hosts)}")
    results["reaction"] = reaction

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
