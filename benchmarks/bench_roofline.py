"""§Roofline report: aggregate the dry-run JSONs into the per-(arch ×
shape × mesh) roofline table (compute / memory / collective terms, dominant
bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit, header

DRYRUN_DIR = Path("experiments/dryrun")


def run() -> None:
    header("roofline table from dry-run artifacts (§Roofline)")
    if not DRYRUN_DIR.exists():
        emit("roofline.missing", 0.0,
             "run: python -m repro.launch.dryrun --arch all --shape all "
             "--both-meshes --out experiments/dryrun")
        return
    rows = []
    for fn in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(fn.read_text())
        if r.get("status") != "ok":
            emit(f"roofline.{fn.stem}", 0.0, "status=FAIL")
            continue
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append((r["arch"], r["shape"], r["mesh"], rf))
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
             bound * 1e6,
             f"dom={rf['dominant']};"
             f"C={rf['compute_s']:.3e};M={rf['memory_s']:.3e};"
             f"X={rf['collective_s']:.3e};"
             f"useful={rf['useful_compute_ratio']:.2f}")
    # summary: dominant-term histogram
    from collections import Counter
    doms = Counter(rf["dominant"] for _, _, _, rf in rows)
    emit("roofline.summary", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(doms.items()))
         + f";total={len(rows)}")


if __name__ == "__main__":
    run()
