"""Heterogeneous fleet with crowd-shared telemetry calibration.

Runs a ≥10-device fleet (all three hardware tiers) over the day-long
case-study trace, reporting per-tier latency/violation/energy, the
before/after profiler prediction error (MAPE) that tier-pooled
calibration buys, and the cross-tier divergence of adaptation decisions
under one identical context.
"""
from __future__ import annotations

import time
from collections import Counter

from repro.configs import get_config
from repro.core import ResourceContext
from repro.fleet import (FleetController, TIERS, build_fleet, fleet_report)
from repro.models.configs import InputShape

from .common import emit, header

FLEET_SIZE = 12
TICKS = 24


def run() -> None:
    header("heterogeneous fleet + crowd telemetry")
    cfg = get_config("paper-backbone")
    shape = InputShape("fleet", 256, 4, "prefill")
    fleet = build_fleet(FLEET_SIZE, seed=0)
    ctl = FleetController(fleet, cfg, shape, trace_ticks=TICKS)
    t0 = time.perf_counter()
    ctl.run(TICKS)
    wall = (time.perf_counter() - t0) * 1e6
    rep = fleet_report(ctl)
    emit("fleet.run", wall / max(rep.total_ticks, 1),
         f"devices={FLEET_SIZE};ticks={rep.total_ticks}")

    for t in rep.tiers:
        emit(f"fleet.tier.{t.tier}", t.mean_latency_s * 1e6,
             f"p95_us={t.p95_latency_s*1e6:.1f};viol={t.violations};"
             f"rate={t.violation_rate:.2f};energy_J={t.energy_j:.3g}")
        emit(f"fleet.mape.{t.tier}", 0.0,
             f"before={t.mape_before:.3f};after={t.mape_after:.3f};"
             f"reduced={int(t.mape_after < t.mape_before)}")
    emit("fleet.violations", 0.0,
         f"first_half={rep.violations_first_half};"
         f"second_half={rep.violations_second_half};"
         f"decreased={int(rep.violations_second_half < rep.violations_first_half)}")
    print(rep.render())

    # decision divergence: fresh loops (no hysteresis history), one per
    # tier, carrying only that tier's crowd-learned calibration, all fed
    # the SAME context — what each tier would decide for a new device
    probe = ResourceContext(battery_frac=0.95, mem_free_frac=0.7)
    chosen = {}
    for spec in ctl.devices:
        if spec.tier in chosen:
            continue
        d = ctl.probe_loop(spec).tick(probe)
        v = d.action.variant
        chosen[spec.tier] = (f"w={v.width_ratio};d={v.depth_ratio};"
                             f"r={v.rank_ratio};"
                             f"remat={d.action.engine.remat_policy}")
    for tier in TIERS:
        emit(f"fleet.decision.{tier}", 0.0, chosen[tier][:90])
    distinct = len(set(chosen.values()))
    emit("fleet.decision.divergence", 0.0,
         f"tiers={len(chosen)};distinct={distinct};"
         f"diverged={int(distinct > 1)}")

    # per-tier action histogram over the whole shared scenario
    for tier in TIERS:
        hist = Counter()
        for r in ctl.records:
            if r.tier == tier:
                v = r.decision.action.variant
                hist[f"w{v.width_ratio}/d{v.depth_ratio}/"
                     f"{r.decision.action.engine.remat_policy}"] += 1
        top = ";".join(f"{k}:{n}" for k, n in hist.most_common(3))
        emit(f"fleet.actions.{tier}", 0.0, top)


if __name__ == "__main__":
    run()
