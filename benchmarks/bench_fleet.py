"""Heterogeneous fleet with crowd-shared telemetry calibration.

Runs a ≥10-device fleet (all three hardware tiers) over the day-long
case-study trace under the event-driven scheduler, reporting per-tier
latency/violation/energy, the before/after profiler prediction error
(MAPE) that tier-pooled calibration buys, the cross-tier divergence of
adaptation decisions under one identical context, and the scheduler's
asynchrony itself — per-device tick spread, clock skew, and an
event-vs-lockstep wall-time comparison.  Results go to stdout (the
``name,us_per_call,derived`` CSV contract) and to ``BENCH_fleet.json``
for trend tracking.
"""
from __future__ import annotations

import json
import time
from collections import Counter

from repro.configs import get_config
from repro.core import ResourceContext
from repro.fleet import (FleetController, TIERS, build_fleet, fleet_report)
from repro.models.configs import InputShape

from .common import emit, header

FLEET_SIZE = 12
TICKS = 24
# event-mode traces must outlast the run(TICKS) horizon (TICKS × the
# slowest member's period), or fast devices exhaust their contexts and
# idle — hiding exactly the differential tick rates being measured.
# Heavy tier wakes 4× as often as light, so 4×TICKS contexts suffice.
EVENT_TRACE_TICKS = 4 * TICKS
JSON_PATH = "BENCH_fleet.json"


def run() -> None:
    header("heterogeneous fleet + crowd telemetry (event-driven)")
    cfg = get_config("paper-backbone")
    shape = InputShape("fleet", 256, 4, "prefill")
    fleet = build_fleet(FLEET_SIZE, seed=0)
    ctl = FleetController(fleet, cfg, shape, trace_ticks=EVENT_TRACE_TICKS)
    t0 = time.perf_counter()
    ctl.run(TICKS)
    wall = (time.perf_counter() - t0) * 1e6
    rep = fleet_report(ctl)
    emit("fleet.run", wall / max(rep.total_ticks, 1),
         f"devices={FLEET_SIZE};ticks={rep.total_ticks}")

    results = {
        "config": {"devices": FLEET_SIZE, "ticks": TICKS,
                   "trace_ticks": EVENT_TRACE_TICKS,
                   "step_mode": "event", "arch": cfg.name},
        "tiers": {},
        "violations": {"first_half": rep.violations_first_half,
                       "second_half": rep.violations_second_half},
        "event": {"device_ticks": rep.device_ticks,
                  "clock_skew_s": rep.clock_skew_s},
    }
    for t in rep.tiers:
        results["tiers"][t.tier] = {
            "devices": t.devices, "ticks": t.ticks,
            "ticks_per_device": [t.min_device_ticks, t.max_device_ticks],
            "mean_latency_s": t.mean_latency_s,
            "p95_latency_s": t.p95_latency_s,
            "violations": t.violations, "violation_rate": t.violation_rate,
            "energy_j": t.energy_j,
            "mape_before": t.mape_before, "mape_after": t.mape_after,
        }
        emit(f"fleet.tier.{t.tier}", t.mean_latency_s * 1e6,
             f"p95_us={t.p95_latency_s*1e6:.1f};viol={t.violations};"
             f"rate={t.violation_rate:.2f};energy_J={t.energy_j:.3g}")
        emit(f"fleet.mape.{t.tier}", 0.0,
             f"before={t.mape_before:.3f};after={t.mape_after:.3f};"
             f"reduced={int(t.mape_after < t.mape_before)}")
    emit("fleet.violations", 0.0,
         f"first_half={rep.violations_first_half};"
         f"second_half={rep.violations_second_half};"
         f"decreased={int(rep.violations_second_half < rep.violations_first_half)}")
    ticks = rep.device_ticks.values()
    emit("fleet.async.ticks", 0.0,
         f"min={min(ticks)};max={max(ticks)};"
         f"skew_s={rep.clock_skew_s:.3f}")
    print(rep.render())

    # event vs lockstep: same fleet/scenario, synchronized stepping —
    # wall-time per record and the (absence of) tick-count spread
    lk = FleetController(build_fleet(FLEET_SIZE, seed=0), cfg, shape,
                         trace_ticks=TICKS, step_mode="lockstep")
    t0 = time.perf_counter()
    lk.run(TICKS)
    lk_wall = (time.perf_counter() - t0) * 1e6
    lk_rep = fleet_report(lk)
    emit("fleet.lockstep.run", lk_wall / max(lk_rep.total_ticks, 1),
         f"ticks={lk_rep.total_ticks};skew_s={lk_rep.clock_skew_s:.3f}")
    results["lockstep"] = {
        "total_ticks": lk_rep.total_ticks,
        "clock_skew_s": lk_rep.clock_skew_s,
        "us_per_record": lk_wall / max(lk_rep.total_ticks, 1),
    }
    results["event"]["us_per_record"] = wall / max(rep.total_ticks, 1)

    # decision divergence: fresh loops (no hysteresis history), one per
    # tier, carrying only that tier's crowd-learned calibration, all fed
    # the SAME context — what each tier would decide for a new device
    probe = ResourceContext(battery_frac=0.95, mem_free_frac=0.7)
    chosen = {}
    for spec in ctl.devices:
        if spec.tier in chosen:
            continue
        d = ctl.probe_loop(spec).tick(probe)
        v = d.action.variant
        chosen[spec.tier] = (f"w={v.width_ratio};d={v.depth_ratio};"
                             f"r={v.rank_ratio};"
                             f"remat={d.action.engine.remat_policy}")
    for tier in TIERS:
        emit(f"fleet.decision.{tier}", 0.0, chosen[tier][:90])
    distinct = len(set(chosen.values()))
    emit("fleet.decision.divergence", 0.0,
         f"tiers={len(chosen)};distinct={distinct};"
         f"diverged={int(distinct > 1)}")
    results["decisions"] = {"per_tier": chosen, "distinct": distinct}

    # per-tier action histogram over the whole shared scenario
    for tier in TIERS:
        hist = Counter()
        for r in ctl.records:
            if r.tier == tier:
                v = r.decision.action.variant
                hist[f"w{v.width_ratio}/d{v.depth_ratio}/"
                     f"{r.decision.action.engine.remat_policy}"] += 1
        top = ";".join(f"{k}:{n}" for k, n in hist.most_common(3))
        emit(f"fleet.actions.{tier}", 0.0, top)

    with open(JSON_PATH, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    run()
