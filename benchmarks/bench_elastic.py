"""Paper Fig. 10 + Table III: the elastic-inference component against the
compression baselines (Fire, SVD, OFA, AdaDeep), and the paper's named
operator combinations — measured CPU latency + params + MACs + energy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import HANDCRAFTED, adadeep_select, ofa_select
from repro.configs import get_config
from repro.core import ActionEvaluator, ResourceContext
from repro.core.actions import Action
from repro.elastic import (FULL_SPEC, NAMED_COMBOS, ElasticSupernet,
                           VariantSpec, derive_variant, variant_cost)
from repro.models import forward, init_params
from repro.models.configs import InputShape

from .common import emit, header, time_fn


def _count_params(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))


def run() -> None:
    header("elastic inference vs compression baselines (Fig 10, Table III)")
    cfg = get_config("paper-backbone")
    shape = InputShape("bench", 256, 4, "prefill")
    ev = ActionEvaluator(cfg, shape)
    ctx = ResourceContext()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)
    sn = ElasticSupernet(cfg, params)

    budget = ev.evaluate(Action(), ctx).latency_s * 0.6
    selections = dict(HANDCRAFTED)
    selections["adadeep"] = adadeep_select(cfg, shape, budget, ev)
    selections["ofa"] = ofa_select(cfg, shape, budget, sn.action_space(), ev)
    # CrowdHMTware: profiler+optimizer pick (context-aware)
    from repro.core.loop import AdaptationLoop
    loop = AdaptationLoop(cfg=cfg, shape=shape, supernet=sn,
                          allow_offload=False)
    loop.build_pareto(evolve=False)
    selections["crowdhmtware"] = loop.tick(ctx).action.variant

    full_cost = variant_cost(cfg, FULL_SPEC, shape.seq_len)
    for name, spec in selections.items():
        vcfg, vp = derive_variant(cfg, params, spec)
        f = jax.jit(lambda p, t: forward(p, vcfg, t)[0])
        us = time_fn(f, vp, tokens)
        cost = variant_cost(cfg, spec, shape.seq_len)
        e = ev.evaluate(Action(variant=spec), ctx)
        emit(f"elastic.{name}", us,
             f"macsx={full_cost['flops_per_token']/cost['flops_per_token']:.2f};"
             f"params={_count_params(vp)/1e6:.1f}M;"
             f"A={e.accuracy:.3f};E={e.energy_j:.2e}J")

    header("operator combinations (Table III)")
    for name, spec in NAMED_COMBOS.items():
        vcfg, vp = derive_variant(cfg, params, spec)
        f = jax.jit(lambda p, t: forward(p, vcfg, t)[0])
        us = time_fn(f, vp, tokens)
        cost = variant_cost(cfg, spec, shape.seq_len)
        emit(f"combo.{name}", us,
             f"macsx={full_cost['flops_per_token']/cost['flops_per_token']:.2f};"
             f"params={_count_params(vp)/1e6:.1f}M")


if __name__ == "__main__":
    run()
