"""Paper Fig. 13: real-world case study — a day-long time-varying context
trace (battery 90%→21%, memory dip, evening drift) driving the full
adaptation loop; logs every strategy switch like the paper's e1/e2/e3."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import AdaptationLoop, Budgets, case_study_trace
from repro.models.configs import InputShape

from .common import emit, header


def run() -> None:
    header("real-world case study (Fig 13)")
    cfg = get_config("paper-backbone")
    shape = InputShape("vehicle", 512, 4, "prefill")
    loop = AdaptationLoop(cfg=cfg, shape=shape, allow_offload=True,
                          budgets=Budgets(latency_s=0.05, memory_bytes=2e9),
                          hysteresis=0.02)
    loop.build_pareto(evolve=True)
    emit("case.pareto_front", 0.0, f"size={len(loop.front)}")

    switches = 0
    prev = None
    for ctx in case_study_trace(24):
        d = loop.tick(ctx)
        if prev is not None and d.action != prev:
            switches += 1
            emit(f"case.switch@{ctx.time_s/3600:.2f}h",
                 d.eval.latency_s * 1e6,
                 f"bat={ctx.battery_frac:.2f};mem={ctx.mem_free_frac:.2f};"
                 f"drift={ctx.data_drift:.2f};"
                 f"ops={'+'.join(d.action.variant.operators()) or 'full'};"
                 f"offload={int(d.action.offload.enabled)}")
        prev = d.action
    first, last = loop.decisions[0], loop.decisions[-1]
    emit("case.summary", 0.0,
         f"ticks=24;switches={switches};"
         f"E_first={first.eval.energy_j:.2e};E_last={last.eval.energy_j:.2e};"
         f"energy_drop={first.eval.energy_j/max(last.eval.energy_j,1e-12):.2f}x")


if __name__ == "__main__":
    run()
