"""Paper Table II: adaptation under stepped memory budgets
(100% / 75% / 50% / 25%) — memory tracks the budget, accuracy holds."""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core import AdaptationLoop, Budgets, ResourceContext
from repro.models.configs import InputShape

from .common import emit, header


def run() -> None:
    header("dynamic memory budgets (Table II)")
    cfg = get_config("paper-backbone")
    shape = InputShape("bench", 512, 8, "prefill")
    loop = AdaptationLoop(cfg=cfg, shape=shape, allow_offload=True,
                          hysteresis=0.0)
    loop.build_pareto(evolve=False)
    base_mem = None
    for frac in (1.0, 0.75, 0.5, 0.25):
        ctx = ResourceContext(mem_free_frac=frac, chips_available=1)
        # anchor the 100% budget at the unrestricted selection's memory
        if base_mem is not None:
            loop.budgets = Budgets(memory_bytes=base_mem * frac)
        d = loop.tick(ctx)
        if base_mem is None:
            base_mem = d.eval.memory_bytes
        emit(f"budget.{int(frac*100)}pct", d.eval.latency_s * 1e6,
             f"A={d.eval.accuracy:.3f};M={d.eval.memory_bytes/1e6:.1f}MB;"
             f"cap={base_mem*frac/1e6:.1f}MB;ok="
             f"{int(d.eval.memory_bytes <= base_mem*frac*1.001)};"
             f"action={'+'.join(d.action.variant.operators()) or 'full'}")


if __name__ == "__main__":
    run()
