"""Shared benchmark plumbing: timing + the ``name,us_per_call,derived``
CSV contract from the brief."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def header(title: str) -> None:
    print(f"\n# === {title} ===")
