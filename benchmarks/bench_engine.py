"""Paper Table IV: frontend / backend / cross-level optimization on one
model — measured CPU wall-time for each optimization stack plus the IR-level
memory/fusion accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.elastic import VariantSpec, derive_variant
from repro.engine import fuse_graph, plan_memory, plan_parallelism
from repro.models import RuntimeOptions, forward, init_params
from repro.offload import build_model_graph

from .common import emit, header, time_fn


def run() -> None:
    header("model-adaptive engine (Table IV)")
    cfg = get_config("paper-backbone")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 512), 0,
                                cfg.vocab_size)

    def bench(name, vcfg, vp, opts, extra=""):
        f = jax.jit(lambda p, t: forward(p, vcfg, t, opts)[0])
        us = time_fn(f, vp, tokens)
        if not hasattr(bench, "base"):
            bench.base = us
        emit(f"engine.{name}", us,
             f"speedup={bench.base/us:.3f}x;{extra}")
        return us

    base_opts = RuntimeOptions(attn_impl="full")
    bench("original", cfg, params, base_opts)

    # frontend-level compression (resource-friendly frontend compilation)
    lcfg, lp = derive_variant(cfg, params, VariantSpec(rank_ratio=0.5))
    bench("lowrank_decomp", lcfg, lp, base_opts)
    pcfg, ppar = derive_variant(cfg, params, VariantSpec(width_ratio=0.5))
    bench("pruning", pcfg, ppar, base_opts)

    # backend-level: operator impl selection (fusion analogue) — chunked
    # attention keeps score tiles cache-resident, XLA fuses the chain
    bench("operator_fusion(chunked)", cfg, params,
          RuntimeOptions(attn_impl="chunked", q_chunk=128, k_chunk=256))

    # cross-level: pruning + fused attention path
    bench("cross_level(prune+fuse)", pcfg, ppar,
          RuntimeOptions(attn_impl="chunked", q_chunk=128, k_chunk=256))

    header("engine IR accounting (fusion + memory allocator)")
    g = build_model_graph(cfg, 1, 512)
    g2, reports = fuse_graph(g)
    fused_ops = sum(r.ops_fused for r in reports)
    saved = sum(r.bytes_saved for r in reports)
    emit("engine.ir.fusion", 0.0,
         f"ops={len(g.nodes)}->{len(g2.nodes)};fused={fused_ops};"
         f"traffic_saved={saved/1e6:.1f}MB")
    plan = plan_memory(g)
    emit("engine.ir.memory_alloc", 0.0,
         f"naive={plan.naive_bytes/1e6:.1f}MB;peak={plan.peak_bytes/1e6:.1f}MB;"
         f"reuse={1/plan.reuse_ratio:.1f}x")
    pp2 = plan_parallelism(g, streams=2)
    emit("engine.ir.op_parallelism", 0.0,
         f"speedup={pp2.speedup:.2f}x;streams=2")


if __name__ == "__main__":
    run()
