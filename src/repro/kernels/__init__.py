from . import ops, ref
from .act_quant import act_dequant, act_quant, act_quant4
from .flash_attn import flash_attention
from .fused_ffn import fused_ffn
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "act_dequant", "act_quant", "act_quant4", "flash_attention",
           "fused_ffn", "ssd_scan"]
