from . import ops, ref
from .act_quant import (act_dequant, act_dequant4, act_quant, act_quant4,
                        kv_dequant_rows, kv_quant_rows)
from .flash_attn import flash_attention
from .fused_ffn import fused_ffn
from .paged_decode_attn import paged_decode_attention
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "act_dequant", "act_dequant4", "act_quant",
           "act_quant4", "kv_dequant_rows", "kv_quant_rows",
           "flash_attention", "fused_ffn", "paged_decode_attention",
           "ssd_scan"]
