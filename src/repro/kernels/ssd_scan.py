"""Pallas TPU kernel: Mamba2 SSD chunked scan (the SSM hot spot).

Tiling: grid (B*H, S/chunk) with the chunk axis innermost (sequential);
the carried SSM state (P, N) lives in VMEM scratch across chunks.  Each
program computes the within-chunk quadratic form (decay-masked attention
analogue, an (L, L) matmul that maps onto the MXU) plus the contribution
of the carried state, then updates the state — the state never round-trips
to HBM between chunks, which is the TPU adaptation of Mamba2's SRAM-
resident scan.

VMEM per program at L=128, P=64, N=128: x (L,P) + b,c (L,N) + decay (L,L)
+ state (P,N) in f32 ≈ 0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref,
                state_scr, *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L,)
    a = a_ref[0].astype(jnp.float32)          # scalar ()
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    c = c_ref[0].astype(jnp.float32)          # (L, N)

    xd = x * dt[:, None]
    da = dt * a                               # (L,)
    da_cs = jnp.cumsum(da)                    # (L,)
    # intra-chunk decay matrix: exp(sum_{j+1..i} da) masked lower-triangular
    seg = da_cs[:, None] - da_cs[None, :]     # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)

    # diagonal block:  Y_diag = ((C B^T) ∘ decay) @ Xd
    scores = (c @ b.T) * decay                # (L, L) on the MXU
    y = scores @ xd                           # (L, P)

    # carried-state contribution: Y_off = exp(da_cs) * (C @ state^T)
    state = state_scr[...]                    # (P, N)
    y = y + jnp.exp(da_cs)[:, None] * (c @ state.T)

    # state update: state' = exp(sum da) * state + sum_l exp(tail decay) xd_l b_l
    decay_states = jnp.exp(da_cs[-1] - da_cs) # (L,)
    new_state = jnp.exp(da_cs[-1]) * state + (xd * decay_states[:, None]).T @ b
    state_scr[...] = new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        st_out_ref[0] = new_state.astype(st_out_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD scan, one (batch·head) per grid row.

    x: (BH, S, P); dt: (BH, S) (softplus applied); a: (BH,) negative;
    b, c: (BH, S, N) (groups pre-broadcast).
    Returns (y (BH, S, P) f32, final_state (BH, P, N) f32)."""
    bh, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk,
                               num_chunks=s // chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk), lambda h, i: (h, i)),
            pl.BlockSpec((1,), lambda h, i: (h,)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, p, n), lambda h, i: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
