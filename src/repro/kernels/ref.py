"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each ``*_ref`` function is the semantic ground truth the kernels are
allclose-validated against in interpret mode, and the CPU execution path
when ``RuntimeOptions.use_pallas`` is off.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------- act_quant ------
def act_quant_ref(x: jax.Array, block: int = 128
                  ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization along the last dim.
    x: (M, N) with N % block == 0 -> (q int8 (M,N), scales f32 (M, N/block))."""
    m, n = x.shape
    xb = x.reshape(m, n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(m, n), scale[..., 0]


def act_dequant_ref(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    m, n = q.shape
    block = n // scale.shape[1]
    xb = q.reshape(m, n // block, block).astype(jnp.float32) * scale[..., None]
    return xb.reshape(m, n).astype(dtype)


# ----------------------------------------------------------- fused_ffn -----
def fused_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, activation: str = "silu") -> jax.Array:
    """GeGLU/SwiGLU FFN: (act(x@wg) * (x@wu)) @ wd, f32 accumulation."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    xf = x.astype(jnp.float32)
    h = act(xf @ w_gate.astype(jnp.float32)) * (xf @ w_up.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------- flash_attn -----
def flash_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0) -> jax.Array:
    """Single-head-batched attention oracle.
    q: (B, H, S, hd); k, v: (B, H, S, hd)  (kv heads pre-broadcast)."""
    b, h, s, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------- ssd_scan ----
def ssd_scan_kernel_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                        b: jax.Array, c: jax.Array, chunk: int
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-(batch·head) SSD oracle in the kernel's layout.

    x: (BH, S, P); dt: (BH, S); a: (BH,); b, c: (BH, S, N).
    Returns (y (BH,S,P), final_state (BH,P,N))."""
    from repro.models.ssm import ssd_scan_ref

    def one(xi, dti, ai, bi, ci):
        y, st = ssd_scan_ref(xi[None, :, None, :], dti[None, :, None],
                             ai[None], bi[None, :, None, :],
                             ci[None, :, None, :], chunk=chunk)
        return y[0, :, 0, :], st[0, 0]

    return jax.vmap(one)(x, dt, a, b, c)
