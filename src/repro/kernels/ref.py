"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each ``*_ref`` function is the semantic ground truth the kernels are
allclose-validated against in interpret mode, and the CPU execution path
when ``RuntimeOptions.use_pallas`` is off.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------- act_quant ------
def act_quant_ref(x: jax.Array, block: int = 128
                  ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization along the last dim.
    x: (M, N) with N % block == 0 -> (q int8 (M,N), scales f32 (M, N/block))."""
    m, n = x.shape
    xb = x.reshape(m, n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(m, n), scale[..., 0]


def act_dequant_ref(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    m, n = q.shape
    block = n // scale.shape[1]
    xb = q.reshape(m, n // block, block).astype(jnp.float32) * scale[..., None]
    return xb.reshape(m, n).astype(dtype)


def act_quant4_ref(x: jax.Array, block: int = 128
                   ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int4 quantization, two codes packed per byte.

    The code range is the symmetric [-7, 7] (the -8 point is deliberately
    unused so negation round-trips inside the code space and the scale is
    amax/7 on both sides); codes are stored biased by +8 into [1, 15] and
    packed little-nibble-first: byte j holds column 2j in its low nibble
    and column 2j+1 in its high nibble.

    x: (M, N) with N % block == 0 and N even
    -> (packed uint8 (M, N//2), scales f32 (M, N/block))."""
    m, n = x.shape
    xb = x.reshape(m, n // block, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -7, 7) + 8.0
    q = q.reshape(m, n).astype(jnp.uint8)
    lo, hi = q[:, 0::2], q[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8), scale[..., 0]


def act_dequant4_ref(packed: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of ``act_quant4_ref``: unpack nibbles (low nibble = even
    column), un-bias to [-7, 7], and rescale per block.
    packed: (M, N//2) uint8; scale: (M, N/block) -> (M, N)."""
    m, half = packed.shape
    n = half * 2
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(m, n)
    block = n // scale.shape[1]
    xb = q.reshape(m, n // block, block).astype(jnp.float32) * scale[..., None]
    return xb.reshape(m, n).astype(dtype)


# ----------------------------------------------------------- fused_ffn -----
def fused_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, activation: str = "silu") -> jax.Array:
    """GeGLU/SwiGLU FFN: (act(x@wg) * (x@wu)) @ wd, f32 accumulation."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    xf = x.astype(jnp.float32)
    h = act(xf @ w_gate.astype(jnp.float32)) * (xf @ w_up.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------- flash_attn -----
def flash_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   kv_len: int | None = None) -> jax.Array:
    """Single-head-batched attention oracle.
    q: (B, H, S, hd); k, v: (B, H, S, hd)  (kv heads pre-broadcast).
    ``kv_len`` masks keys at positions >= kv_len; a query row with zero
    valid keys outputs exactly zero (matching the kernel's masked-row
    guard) instead of softmax's uniform average over -1e30 scores."""
    b, h, s, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= cols > rows - window
    if kv_len is not None:
        mask &= cols < kv_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = p * jnp.any(mask, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ----------------------------------------------------- paged decode attn ----
def paged_decode_attn_ref(q: jax.Array, k_blocks: jax.Array,
                          v_blocks: jax.Array, tables: jax.Array,
                          pos: jax.Array, k_new: jax.Array,
                          v_new: jax.Array, *,
                          k_scale: jax.Array | None = None,
                          v_scale: jax.Array | None = None,
                          window: int = 0) -> jax.Array:
    """Single-query GQA attention over a paged KV pool (oracle).

    q: (slots, H, hd); k/v_blocks: (num_blocks, bs, kvh, hd) — ONE layer's
    pool slice; tables: (slots, mb) int32 block ids; pos: (slots,) — the
    number of tokens already in the pool (pool columns < pos are valid);
    k_new/v_new: (slots, kvh, hd) — the current token's KV, folded in as an
    always-valid extra key (it has NOT been scattered into the pool yet).
    Optional k/v_scale: (num_blocks, bs) f32 per-row int8 scales.
    ``window`` keeps pool columns > pos - window (the new token is position
    ``pos``, so with window w the valid set is (pos-w, pos]).
    Returns (slots, H, hd) in q.dtype."""
    slots, h, hd = q.shape
    nb, bs, kvh, _ = k_blocks.shape
    mb = tables.shape[1]
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)

    def one(qi, tbl, p, kn, vn):
        kf = k_blocks[tbl].astype(jnp.float32).reshape(mb * bs, kvh, hd)
        vf = v_blocks[tbl].astype(jnp.float32).reshape(mb * bs, kvh, hd)
        if k_scale is not None:
            kf = kf * k_scale[tbl].reshape(mb * bs, 1, 1)
            vf = vf * v_scale[tbl].reshape(mb * bs, 1, 1)
        cols = jnp.arange(mb * bs)
        valid = cols < p
        if window:
            valid &= cols > p - window
        kf = jnp.concatenate([kf, kn.astype(jnp.float32)[None]], axis=0)
        vf = jnp.concatenate([vf, vn.astype(jnp.float32)[None]], axis=0)
        valid = jnp.concatenate([valid, jnp.ones((1,), bool)])
        qg = qi.astype(jnp.float32).reshape(kvh, g, hd) * scale
        s = jnp.einsum("kgh,skh->kgs", qg, kf)
        s = jnp.where(valid[None, None, :], s, -1e30)
        p_attn = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("kgs,skh->kgh", p_attn, vf)
        return out.reshape(h, hd)

    return jax.vmap(one)(q, tables, pos, k_new, v_new).astype(q.dtype)


# ------------------------------------------------------------- ssd_scan ----
def ssd_scan_kernel_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                        b: jax.Array, c: jax.Array, chunk: int
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-(batch·head) SSD oracle in the kernel's layout.

    x: (BH, S, P); dt: (BH, S); a: (BH,); b, c: (BH, S, N).
    Returns (y (BH,S,P), final_state (BH,P,N))."""
    from repro.models.ssm import ssd_scan_ref

    def one(xi, dti, ai, bi, ci):
        y, st = ssd_scan_ref(xi[None, :, None, :], dti[None, :, None],
                             ai[None], bi[None, :, None, :],
                             ci[None, :, None, :], chunk=chunk)
        return y[0, :, 0, :], st[0, 0]

    return jax.vmap(one)(x, dt, a, b, c)
