"""Pallas TPU kernel: blockwise int8 activation quantization (engine ❼).

Tiling: grid (M/bm, N/bn); each program reads a (bm, bn) activation tile
into VMEM, computes per-128-lane-block absmax scales (bn is a multiple of
128 so scales stay register/VMEM-local), and writes the int8 tile plus the
f32 scales.  Quantizing on-chip right after the producing matmul keeps the
bf16 tile from ever round-tripping to HBM — the kernel-level realization
of the paper's "compress intermediate activations post-forward".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128


def _act_quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (bm, bn)
    bm, bn = x.shape
    xb = x.reshape(bm, bn // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127)
    q_ref[...] = q.reshape(bm, bn).astype(jnp.int8)
    s_ref[...] = scale[..., 0]


def _act_dequant_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    bm, bn = q.shape
    s = s_ref[...]
    xb = q.reshape(bm, bn // QBLOCK, QBLOCK) * s[..., None]
    o_ref[...] = xb.reshape(bm, bn).astype(out_dtype)


def act_quant(x: jax.Array, *, block_m: int = 256, block_n: int = 512,
              interpret: bool = False):
    """x: (M, N), N % 128 == 0 -> (q int8 (M,N), scales f32 (M, N/128))."""
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _act_quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n // QBLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def act_dequant(q: jax.Array, scales: jax.Array, *, out_dtype=jnp.bfloat16,
                block_m: int = 256, block_n: int = 512,
                interpret: bool = False) -> jax.Array:
    m, n = q.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_act_dequant_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(q, scales)


def _act_quant4_kernel(x_ref, q_ref, s_ref):
    """int4 variant: two 4-bit values packed per uint8 byte.

    The code range is the *symmetric* [-7, 7]: the -8 point is deliberately
    unused so negation round-trips inside the code space and one amax/7
    scale serves both signs (using -8 would need an asymmetric scale or
    clip +amax harder than -amax).  Codes are stored biased by +8 into
    [1, 15], little-nibble-first: byte j = col 2j | (col 2j+1 << 4).
    ``_act_dequant4_kernel`` pins this layout exactly."""
    x = x_ref[...].astype(jnp.float32)               # (bm, bn)
    bm, bn = x.shape
    xb = x.reshape(bm, bn // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -7, 7) + 8.0  # bias to unsigned
    q = q.reshape(bm, bn).astype(jnp.uint8)
    lo, hi = q[:, 0::2], q[:, 1::2]
    q_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    s_ref[...] = scale[..., 0]


def act_quant4(x: jax.Array, *, block_m: int = 256, block_n: int = 512,
               interpret: bool = False):
    """Packed int4 activation quantization (engine ❼: the paper's 4-bit
    storage path).  x: (M, N), N % 128 == 0 ->
    (packed uint8 (M, N/2), scales f32 (M, N/128))."""
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _act_quant4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n // 2), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // QBLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _act_dequant4_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    """Inverse of ``_act_quant4_kernel``: unpack the nibbles (low nibble =
    even column), un-bias to [-7, 7] and rescale per 128-lane block."""
    packed = q_ref[...]                              # (bm, bn // 2) uint8
    bm, half = packed.shape
    bn = half * 2
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(bm, bn).astype(jnp.float32)
    s = s_ref[...]
    xb = q.reshape(bm, bn // QBLOCK, QBLOCK) * s[..., None]
    o_ref[...] = xb.reshape(bm, bn).astype(out_dtype)


def act_dequant4(packed: jax.Array, scales: jax.Array, *,
                 out_dtype=jnp.bfloat16, block_m: int = 256,
                 block_n: int = 512, interpret: bool = False) -> jax.Array:
    """packed: (M, N/2) uint8 from ``act_quant4``; scales: (M, N/128)
    -> (M, N) in ``out_dtype``.  Pack→unpack round-trips the int4 codes
    exactly (the symmetric [-7, 7] range survives the +8 bias)."""
    m, half = packed.shape
    n = half * 2
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_act_dequant4_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(packed, scales)


# ----------------------------------------------------- paged-KV helpers ----
def kv_quant_rows(x: jax.Array):
    """Per-row symmetric int8 quantization for paged-KV storage.

    ``x``: (..., kvh, hd) — one KV row (one token, all kv heads) per
    leading index.  One f32 scale per row (amax over the trailing
    (kvh, hd)) keeps the pool's scale leaves tiny — bs floats per block —
    while the row is the natural append granularity of the decode step.
    Returns (q int8 same shape, scale f32 with the last two dims gone)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale[..., None, None]), -127, 127)
    return q.astype(jnp.int8), scale


def kv_dequant_rows(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16
                    ) -> jax.Array:
    """Inverse of ``kv_quant_rows``: q (..., kvh, hd) int8 with per-row
    scale (...) -> (..., kvh, hd) in ``dtype``."""
    return (q.astype(jnp.float32) * scale[..., None, None]).astype(dtype)
