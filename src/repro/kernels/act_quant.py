"""Pallas TPU kernel: blockwise int8 activation quantization (engine ❼).

Tiling: grid (M/bm, N/bn); each program reads a (bm, bn) activation tile
into VMEM, computes per-128-lane-block absmax scales (bn is a multiple of
128 so scales stay register/VMEM-local), and writes the int8 tile plus the
f32 scales.  Quantizing on-chip right after the producing matmul keeps the
bf16 tile from ever round-tripping to HBM — the kernel-level realization
of the paper's "compress intermediate activations post-forward".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128


def _act_quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (bm, bn)
    bm, bn = x.shape
    xb = x.reshape(bm, bn // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127)
    q_ref[...] = q.reshape(bm, bn).astype(jnp.int8)
    s_ref[...] = scale[..., 0]


def _act_dequant_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    bm, bn = q.shape
    s = s_ref[...]
    xb = q.reshape(bm, bn // QBLOCK, QBLOCK) * s[..., None]
    o_ref[...] = xb.reshape(bm, bn).astype(out_dtype)


def act_quant(x: jax.Array, *, block_m: int = 256, block_n: int = 512,
              interpret: bool = False):
    """x: (M, N), N % 128 == 0 -> (q int8 (M,N), scales f32 (M, N/128))."""
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _act_quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n // QBLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def act_dequant(q: jax.Array, scales: jax.Array, *, out_dtype=jnp.bfloat16,
                block_m: int = 256, block_n: int = 512,
                interpret: bool = False) -> jax.Array:
    m, n = q.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_act_dequant_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(q, scales)


def _act_quant4_kernel(x_ref, q_ref, s_ref):
    """int4 variant: two 4-bit values packed per uint8 byte."""
    x = x_ref[...].astype(jnp.float32)               # (bm, bn)
    bm, bn = x.shape
    xb = x.reshape(bm, bn // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -7, 7) + 8.0  # bias to unsigned
    q = q.reshape(bm, bn).astype(jnp.uint8)
    lo, hi = q[:, 0::2], q[:, 1::2]
    q_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    s_ref[...] = scale[..., 0]


def act_quant4(x: jax.Array, *, block_m: int = 256, block_n: int = 512,
               interpret: bool = False):
    """Packed int4 activation quantization (engine ❼: the paper's 4-bit
    storage path).  x: (M, N), N % 128 == 0 ->
    (packed uint8 (M, N/2), scales f32 (M, N/128))."""
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0 and bn % QBLOCK == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _act_quant4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // QBLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n // 2), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // QBLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)
