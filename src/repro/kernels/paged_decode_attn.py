"""Pallas TPU kernel: paged single-query decode attention (serving ❽).

The per-token hot path of ``decode_mode="paged"``: each slot attends its
one new query against KV stored in fixed-size ``BlockPool`` blocks,
reading blocks *directly through the block table* instead of gathering
the pool to a dense cache first.  The table and per-slot lengths ride in
as scalar-prefetch operands (``PrefetchScalarGridSpec``), so they are
runtime data: occupancy, fragmentation and CoW remaps never change the
program — ``CompileCache`` keys stay put and ``recompiles == 0`` holds
across any block-table shape the engine produces.

Tiling: grid ``(slots, max_blocks)`` with the KV-block axis innermost
(sequential).  The index map for the K/V operands dereferences the table
(``tbl[s, j]``), so each program pulls exactly one pool block into VMEM;
online-softmax running state ``(m, l, acc)`` lives in VMEM scratch across
the sweep.  Tail/empty blocks (table entries pointing at the trash block)
are masked by ``col < pos`` — combined with the masked-row guard
(``m == NEG_INF`` → zero contribution) they contribute exactly nothing.
The current token's KV (``k_new``/``v_new``) has *not* been scattered
into the pool yet; it is folded into the running softmax at finalization
as an always-valid extra key, which keeps the append-then-attend ordering
out of the kernel entirely.

int8 KV: when per-row scales are passed, blocks are stored int8 and
dequantized inside the block loop (one f32 multiply per row) — the pool
holds ~4x more resident slots for one extra VMEM operand of ``bs``
floats per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(*args, has_scales: bool, kvh: int, group: int,
                         block_size: int, num_blocks: int, window: int,
                         scale: float):
    if has_scales:
        (tbl_ref, pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
         ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr) = args
    else:
        (tbl_ref, pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
         o_ref, m_scr, l_scr, acc_scr) = args
        ks_ref = vs_ref = None
    s_id = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qg = (q_ref[0].astype(jnp.float32) * scale).reshape(kvh, group, -1)
    k = k_ref[0].astype(jnp.float32)                 # (bs, kvh, hd)
    v = v_ref[0].astype(jnp.float32)
    if has_scales:
        k = k * ks_ref[0][:, None, None]
        v = v * vs_ref[0][:, None, None]

    # scores (kvh, group, bs); pool col c is valid iff c < pos (and inside
    # the sliding window when one is set — the new token is position pos)
    s = jnp.einsum("kgh,ckh->kgc", qg, k,
                   preferred_element_type=jnp.float32)
    pos = pos_ref[s_id]
    cols = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, block_size), 2)
    valid = cols < pos
    if window:
        valid &= cols > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                              # (kvh, group)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # fully-masked block sweep so far: keep the contribution exactly zero
    # (exp(NEG_INF - NEG_INF) would be 1 for every masked key)
    p = jnp.where(m_new[..., None] == NEG_INF, 0.0,
                  jnp.exp(s - m_new[..., None]))
    corr = jnp.exp(m_prev - m_new)                   # 0 when m_prev==NEG_INF
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jnp.einsum("kgc,ckh->kgh", p, v,
                                 preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        # fold in the current token's KV — always valid, so l_fin >= 1
        # even for a brand-new slot (pos == 0) whose pool sweep was fully
        # masked
        kn = kn_ref[0].astype(jnp.float32)           # (kvh, hd)
        vn = vn_ref[0].astype(jnp.float32)
        sn = jnp.einsum("kgh,kh->kg", qg, kn,
                        preferred_element_type=jnp.float32)
        m_fin = jnp.maximum(m_scr[...], sn)
        pn = jnp.exp(sn - m_fin)
        corr_f = jnp.exp(m_scr[...] - m_fin)
        l_fin = l_scr[...] * corr_f + pn
        # vn is (kvh, hd): lift to (kvh, 1, hd) so the kv-head axis lines
        # up with pn's — bare broadcasting would silently cross axes
        # whenever group == kvh
        acc_fin = (acc_scr[...] * corr_f[..., None]
                   + pn[..., None] * vn[:, None, :])
        out = acc_fin / jnp.maximum(l_fin, 1e-30)[..., None]
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_blocks: jax.Array,
                           v_blocks: jax.Array, tables: jax.Array,
                           pos: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, *,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           window: int = 0,
                           interpret: bool = False) -> jax.Array:
    """Single-query GQA attention straight off the block table.

    q: (slots, H, hd); k/v_blocks: (num_blocks, bs, kvh, hd) — ONE layer's
    pool slice; tables: (slots, mb) int32; pos: (slots,) int32 tokens
    already resident; k_new/v_new: (slots, kvh, hd) — the current token's
    KV, not yet scattered.  Optional k/v_scale: (num_blocks, bs) f32
    per-row int8 scales (pass both or neither).  Returns (slots, H, hd).
    """
    slots, h, hd = q.shape
    nb, bs, kvh, _ = k_blocks.shape
    mb = tables.shape[1]
    assert h % kvh == 0, (h, kvh)
    assert (k_scale is None) == (v_scale is None)
    group = h // kvh
    has_scales = k_scale is not None
    kernel = functools.partial(
        _paged_decode_kernel, has_scales=has_scales, kvh=kvh, group=group,
        block_size=bs, num_blocks=mb, window=window,
        scale=float(1.0 / np.sqrt(hd)))

    def at_slot(s, j, tbl, ps):                      # per-slot operands
        return (s, 0, 0)

    def at_table(s, j, tbl, ps):                     # table-indexed blocks
        return (tbl[s, j], 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, h, hd), at_slot),                      # q
        pl.BlockSpec((1, bs, kvh, hd), at_table),               # k block
        pl.BlockSpec((1, bs, kvh, hd), at_table),               # v block
        pl.BlockSpec((1, kvh, hd), at_slot),                    # k_new
        pl.BlockSpec((1, kvh, hd), at_slot),                    # v_new
    ]
    operands = [q, k_blocks, v_blocks, k_new, v_new]
    if has_scales:
        in_specs += [
            pl.BlockSpec((1, bs), lambda s, j, tbl, ps: (tbl[s, j], 0)),
            pl.BlockSpec((1, bs), lambda s, j, tbl, ps: (tbl[s, j], 0)),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, hd), at_slot),
        scratch_shapes=[
            pltpu.VMEM((kvh, group), jnp.float32),              # m
            pltpu.VMEM((kvh, group), jnp.float32),              # l
            pltpu.VMEM((kvh, group, hd), jnp.float32),          # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, h, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), *operands)
