"""Pallas TPU kernel: fused gated FFN (engine ❶ operator fusion).

Computes y = (act(x @ w_gate) * (x @ w_up)) @ w_down in ONE kernel so the
(M, F) hidden tile never leaves VMEM — the transformer materialization of
the paper's linear/element-wise fusion strategies.

Tiling: grid (M/bm, F/bf), sequential in j (the F axis).  Each program:
  x tile (bm, D)  @  w_gate/w_up tiles (D, bf)  ->  hidden tile (bm, bf)
  hidden @ w_down tile (bf, D) accumulated into the (bm, D) output block
  (output block revisited across j — Pallas guarantees sequential grid
  order on TPU, so the accumulation is race-free).
MXU alignment: bm, bf multiples of 128; D kept whole per tile (d_model up
to ~8k fits VMEM at bm=128: 128*8192*2B = 2MB + weights 2*8192*bf*2B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, activation):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)               # (bm, D)
    wg = wg_ref[...].astype(jnp.float32)             # (D, bf)
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)             # (bf, D)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(x @ wg) * (x @ wu)                       # (bm, bf) stays in VMEM
    partial = h @ wd                                 # (bm, D)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32)
                      + partial).astype(o_ref.dtype)


def fused_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, *, activation: str = "silu",
              block_m: int = 128, block_f: int = 512,
              interpret: bool = False) -> jax.Array:
    """x: (M, D); w_gate/w_up: (D, F); w_down: (F, D) -> (M, D)."""
    m, d = x.shape
    f = w_up.shape[1]
    bm, bf = min(block_m, m), min(block_f, f)
    assert m % bm == 0 and f % bf == 0, (m, f, bm, bf)
    grid = (m // bm, f // bf)
    return pl.pallas_call(
        functools.partial(_fused_ffn_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
