"""Jit'd wrappers around the Pallas kernels with automatic CPU fallback.

The engine flips ``RuntimeOptions.use_pallas``; every op here dispatches to
the Pallas kernel on TPU (or in interpret mode when forced) and to the
``ref.py`` oracle otherwise, so the same model code runs everywhere.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import ref
from .act_quant import act_dequant, act_quant
from .flash_attn import flash_attention
from .fused_ffn import fused_ffn
from .paged_decode_attn import paged_decode_attention
from .ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quantize_activations(x: jax.Array, use_pallas: bool = False,
                         interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return tuple(act_quant(x, interpret=not _on_tpu()))
    return ref.act_quant_ref(x)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "out_dtype"))
def dequantize_activations(q: jax.Array, scales: jax.Array,
                           out_dtype=jnp.bfloat16, use_pallas: bool = False,
                           interpret: bool = False) -> jax.Array:
    if use_pallas and (_on_tpu() or interpret):
        return act_dequant(q, scales, out_dtype=out_dtype,
                           interpret=not _on_tpu())
    return ref.act_dequant_ref(q, scales, out_dtype)


@functools.partial(jax.jit, static_argnames=("activation", "use_pallas",
                                             "interpret"))
def gated_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, activation: str = "silu",
              use_pallas: bool = False, interpret: bool = False) -> jax.Array:
    if use_pallas and (_on_tpu() or interpret):
        return fused_ffn(x, w_gate, w_up, w_down, activation=activation,
                         interpret=not _on_tpu())
    return ref.fused_ffn_ref(x, w_gate, w_up, w_down, activation)


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_len",
                                             "use_pallas", "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              window: int = 0, kv_len: int | None = None,
              use_pallas: bool = False,
              interpret: bool = False) -> jax.Array:
    """q,k,v: (B, H, S, hd) with kv already broadcast to H."""
    b, h, s, hd = q.shape
    if use_pallas and (_on_tpu() or interpret):
        out = flash_attention(q.reshape(b * h, s, hd),
                              k.reshape(b * h, s, hd),
                              v.reshape(b * h, s, hd),
                              causal=causal, window=window, kv_len=kv_len,
                              interpret=not _on_tpu())
        return out.reshape(b, h, s, hd)
    return ref.flash_attn_ref(q, k, v, causal=causal, window=window,
                              kv_len=kv_len)


@functools.partial(jax.jit, static_argnames=("window", "use_pallas",
                                             "interpret"))
def paged_attention(q: jax.Array, k_blocks: jax.Array, v_blocks: jax.Array,
                    tables: jax.Array, pos: jax.Array, k_new: jax.Array,
                    v_new: jax.Array, k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None, window: int = 0,
                    use_pallas: bool = False,
                    interpret: bool = False) -> jax.Array:
    """Single-query decode attention straight off a BlockPool table.

    q: (slots, H, hd); k/v_blocks: (num_blocks, bs, kvh, hd);
    tables: (slots, mb) int32 runtime data; pos: (slots,) resident tokens;
    k/v_new: (slots, kvh, hd) current-token KV (not yet scattered);
    k/v_scale: optional (num_blocks, bs) per-row int8 scales."""
    if use_pallas and (_on_tpu() or interpret):
        return paged_decode_attention(
            q, k_blocks, v_blocks, tables, pos, k_new, v_new,
            k_scale=k_scale, v_scale=v_scale, window=window,
            interpret=not _on_tpu())
    return ref.paged_decode_attn_ref(
        q, k_blocks, v_blocks, tables, pos, k_new, v_new,
        k_scale=k_scale, v_scale=v_scale, window=window)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, chunk: int = 128, use_pallas: bool = False,
        interpret: bool = False):
    """Layout: (BH, S, P) / (BH, S) / (BH,) / (BH, S, N)."""
    if use_pallas and (_on_tpu() or interpret):
        return tuple(ssd_scan(x, dt, a, b, c, chunk=chunk,
                              interpret=not _on_tpu()))
    return ref.ssd_scan_kernel_ref(x, dt, a, b, c, chunk)
