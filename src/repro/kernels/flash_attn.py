"""Pallas TPU kernel: flash attention (causal / sliding-window).

The 32k-prefill hot spot.  Tiling: grid (B*H, Sq/bq, Sk/bk) with the KV
axis innermost (sequential); online-softmax running state (m, l, acc)
lives in VMEM scratch across the KV sweep and is finalized on the last KV
block.  Causal and sliding-window masks are computed from program ids, so
the window variant skips no blocks but masks them — the block-skip
optimization is recorded as a §Perf candidate.

VMEM per program: q (bq, hd) + k/v (bk, hd) + acc (bq, hd) + scores
(bq, bk) in f32 — at bq=bk=512, hd=128 that is ~2.6 MB, inside the 16 MB
v5e VMEM with headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, kv_len: int, block_q: int,
                  block_k: int, num_k_blocks: int, scale: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale         # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                      # (bq, bk)

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = jk * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= cols > rows - window
    if kv_len is not None:
        mask &= cols < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # A fully-masked row has m_new == NEG_INF, where exp(s - m_new) would
    # be exp(0) == 1 for every masked key; force those rows to contribute
    # nothing so they finalize to exactly zero.  exp(m_prev - m_new) is
    # exp(0) == 1 on that path, which correctly preserves the (zero)
    # running state.
    p = jnp.where(m_new == NEG_INF, 0.0, jnp.exp(s - m_new))
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new

    @pl.when(jk == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_len: int | None = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q,k,v: (BH, S, hd) (kv heads pre-broadcast to q heads) -> (BH, S, hd).

    ``kv_len`` (static) masks keys at positions >= kv_len — for padded /
    partially-filled KV.  A query row left with zero valid keys (e.g.
    ``kv_len=0``, or ``window=1`` rows beyond ``kv_len``) outputs exactly
    zero rather than a uniform average over masked keys.
    """
    bh, s, hd = q.shape
    bq, bk = min(block_q, s), min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    grid = (bh, s // bq, s // bk)
    scale = float(1.0 / np.sqrt(hd))
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, kv_len=kv_len,
        block_q=bq, block_k=bk, num_k_blocks=s // bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
