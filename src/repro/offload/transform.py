"""Redundancy-aware cross-platform model transformation (paper §III-B2).

Two-stage conversion over the IR:
  Stage 1 — dependency/data-flow analysis: operator fusion opportunities
            (matmul+bias+act chains, norm folding) and duplicate-operator
            elimination (CSE), computation-preserving.
  Stage 2 — global traversal classifying ops as dynamic vs constant;
            constant subgraphs are folded to precomputed values, redundant
            constants removed, dead ops eliminated.

Each pass returns a new Graph; semantic equivalence is checked by tests
against the executable interpreter (the paper's "guarantees that critical
computational steps are preserved").
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph_ir import Graph, OpNode, execute

FUSABLE_TAIL = ("act", "norm", "reduce")
FUSABLE_BIN = ("add", "mul")


def _rewrite_inputs(nodes: List[OpNode], mapping: Dict[str, str]) -> None:
    for n in nodes:
        n.inputs = tuple(mapping.get(i, i) for i in n.inputs)


# ----------------------------------------------------------- stage 1: fuse --
def fuse_linear_chains(graph: Graph) -> Graph:
    """Fuse matmul -> (add|mul|act|norm|reduce)* single-consumer chains into
    one fused op (strategies ❶ linear, ❷ conv/norm, ❸ element-wise,
    ❹ channel-wise, ❺ reduction — all realized as chain fusion over the
    respective op kinds)."""
    cons = graph.consumers()
    node_of = graph.node_map()
    fused_away: set = set()
    new_nodes: List[OpNode] = []
    for n in graph.toposort():
        if n.output in fused_away:
            continue
        if n.kind not in ("matmul", "conv"):
            new_nodes.append(OpNode(**vars(n)))
            continue
        # walk the single-consumer chain
        chain = [n]
        cur = n
        while True:
            cs = cons.get(cur.output, [])
            if len(cs) != 1:
                break
            nxt = cs[0]
            if nxt.kind in FUSABLE_TAIL:
                chain.append(nxt)
                cur = nxt
            elif nxt.kind in FUSABLE_BIN and all(
                    (i == cur.output or i not in node_of
                     or node_of[i].constant or node_of[i].kind == "const"
                     or node_of[i].kind == "matmul")
                    for i in nxt.inputs):
                # binary with the chain output + const/param-like operand:
                # only fuse when the other operand is produced before the
                # chain head (no cycle); conservatively require const
                other = [i for i in nxt.inputs if i != cur.output]
                if all(i not in node_of or node_of[i].kind == "const"
                       for i in other):
                    chain.append(nxt)
                    cur = nxt
                else:
                    break
            else:
                break
        if len(chain) == 1:
            new_nodes.append(OpNode(**vars(n)))
            continue
        head = {"kind": n.kind}
        head.update({k: v for k, v in n.attrs.items() if k in ("fn", "axis")})
        recipe = [head]
        extra_inputs: List[str] = list(n.inputs)
        for step in chain[1:]:
            entry = {"kind": step.kind}
            entry.update({k: v for k, v in step.attrs.items()
                          if k in ("fn", "axis")})
            recipe.append(entry)
            # binary steps consume inputs POSITIONALLY in recipe order, so
            # duplicates are appended again (e.g. the same const twice)
            for i in step.inputs:
                if i not in [c.output for c in chain]:
                    extra_inputs.append(i)
            fused_away.add(step.output)
        tail = chain[-1]
        new_nodes.append(OpNode(
            name=f"fused:{n.name}+{len(chain)-1}",
            kind="fused", inputs=tuple(extra_inputs), output=tail.output,
            flops=sum(c.flops for c in chain),
            param_bytes=sum(c.param_bytes for c in chain),
            out_bytes=tail.out_bytes,
            attrs={"recipe": recipe, "head_kind": n.kind},
            layer=n.layer, sublayer=n.sublayer))
    g = Graph(nodes=new_nodes, inputs=graph.inputs, outputs=graph.outputs,
              tensors=dict(graph.tensors))
    g.validate()
    return g


def eliminate_duplicates(graph: Graph) -> Graph:
    """CSE: ops with identical (kind, inputs, attrs) compute the same tensor;
    keep the first, rewire consumers (the paper's duplicate-operator
    removal after framework conversion)."""
    seen: Dict[str, str] = {}
    mapping: Dict[str, str] = {}
    new_nodes: List[OpNode] = []
    for n in graph.toposort():
        inputs = tuple(mapping.get(i, i) for i in n.inputs)
        sig_attrs = {k: v for k, v in n.attrs.items() if k != "value"}
        if n.kind == "const":
            v = np.asarray(n.attrs.get("value"))
            sig_attrs["value_hash"] = hashlib.sha1(
                v.tobytes() + str(v.shape).encode()).hexdigest()
        sig = f"{n.kind}|{inputs}|{sorted(sig_attrs.items())!r}"
        if n.kind != "input" and sig in seen:
            mapping[n.output] = seen[sig]
            continue
        seen[sig] = n.output
        m = OpNode(**vars(n))
        m.inputs = inputs
        new_nodes.append(m)
    g = Graph(nodes=new_nodes, inputs=graph.inputs,
              outputs=tuple(mapping.get(o, o) for o in graph.outputs),
              tensors=dict(graph.tensors))
    g.validate()
    return g


# ------------------------------------------------- stage 2: constants/dead --
def classify_constants(graph: Graph) -> Dict[str, bool]:
    """Global traversal: an op is constant iff all its inputs are constants
    (paper: 'operators classified as dynamic or constant')."""
    const: Dict[str, bool] = {}
    for i in graph.inputs:
        const[i] = False
    for n in graph.toposort():
        if n.kind == "const":
            const[n.output] = True
        else:
            const[n.output] = all(const.get(i, False) for i in n.inputs) \
                and len(n.inputs) > 0
    return const


def fold_constants(graph: Graph,
                   params: Optional[Dict[str, np.ndarray]] = None) -> Graph:
    """Replace constant subgraphs by precomputed const nodes."""
    constness = classify_constants(graph)
    node_of = graph.node_map()
    # evaluate maximal constant frontier
    foldable = [n for n in graph.toposort()
                if constness[n.output] and n.kind != "const"]
    if not foldable:
        return graph
    env: Dict[str, np.ndarray] = {}
    for n in graph.toposort():
        if n.kind == "const":
            env[n.output] = np.asarray(n.attrs["value"])
    sub = Graph(nodes=[n for n in graph.nodes
                       if constness[n.output]],
                inputs=(), outputs=tuple(n.output for n in foldable),
                tensors=graph.tensors)
    vals = execute(sub, {}, params or {})
    new_nodes = []
    for n in graph.nodes:
        if n.output in vals:
            new_nodes.append(OpNode(name=n.name, kind="const", inputs=(),
                                    output=n.output,
                                    out_bytes=int(vals[n.output].nbytes),
                                    attrs={"value": vals[n.output]},
                                    layer=n.layer, sublayer=n.sublayer))
        else:
            new_nodes.append(OpNode(**vars(n)))
    g = Graph(nodes=new_nodes, inputs=graph.inputs, outputs=graph.outputs,
              tensors=dict(graph.tensors))
    return eliminate_dead(g)


def eliminate_dead(graph: Graph) -> Graph:
    """Drop ops whose outputs nothing consumes."""
    live: set = set(graph.outputs)
    for n in reversed(graph.toposort()):
        if n.output in live:
            live.update(n.inputs)
    g = Graph(nodes=[n for n in graph.nodes if n.output in live],
              inputs=graph.inputs, outputs=graph.outputs,
              tensors=dict(graph.tensors))
    g.validate()
    return g


def convert(graph: Graph, params: Optional[Dict[str, np.ndarray]] = None
            ) -> Graph:
    """The full two-stage conversion pipeline."""
    g = eliminate_duplicates(graph)
    g = fuse_linear_chains(g)
    g = fold_constants(g, params)
    return eliminate_dead(g)
