"""Adaptive cross-device operator offloading (paper §III-B1).

Given the pre-partitioned units and a pool of device profiles, a
graph-search (exact DP over the sequential unit chain) picks the cut
points and device assignment minimizing end-to-end latency including
transmission (feature bytes / link bandwidth), subject to per-device
memory.  Baselines from the paper's evaluation:

  * CAS  — context-aware heuristic: greedy biggest-bottleneck first
  * DADS — min-cut formulation (for chain graphs the DP is the exact
           min-cut, so DADS here = DP restricted to 2 devices)

TPU adaptation: the same placer maps units onto *mesh slices* (pipeline
stages across the "pod" axis) — a DeviceProfile is then a slice of chips.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .partition import PrePartition, Unit

#: Sentinel ``link_bw`` for the LAST device in a chain: there is no next
#: device, so no egress link exists.  The placement DP never reads the
#: last device's ``link_bw`` (transfers are charged on the *previous*
#: device's link), so any value would work — this constant makes the
#: "terminal device" intent explicit instead of a bare ``0``.  Fleet
#: placement synthesizes real per-hop bandwidths from
#: :class:`repro.fleet.placement.SiteTopology` and uses this only for
#: the chain tail.
NO_NEXT_LINK: float = 0.0


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops: float            # achievable FLOP/s
    mem_bytes: float        # memory available for params + activations
    mem_bw: float           # bytes/s
    # bytes/s to the NEXT device in the chain; NO_NEXT_LINK marks the
    # terminal device (no egress — never consulted by the DP)
    link_bw: float = NO_NEXT_LINK
    power_w: float = 5.0
    kind: str = "edge"      # edge | hub | tpu_slice

    def compute_seconds(self, unit: Unit, eps: float = 0.5) -> float:
        """Roofline-ish unit latency: max(compute, memory) with the paper's
        cache-hit-rate ε folding into effective bandwidth."""
        comp = unit.flops / self.flops
        eff_bw = self.mem_bw * (eps + (1 - eps) / 6.0)  # misses cost ~6x
        mem = (unit.param_bytes + unit.peak_act_bytes) / eff_bw
        return max(comp, mem)


# a small heterogeneous pool mirroring the paper's testbed spirit
# (Raspberry-Pi-class, Jetson-class, phone-class) plus TPU slices
DEVICE_POOLS: Dict[str, Tuple[DeviceProfile, ...]] = {
    "edge_pair": (
        DeviceProfile("rpi4b-class", 12e9, 2e9, 4e9, 10e6 / 8 * 1e3),  # ~1Gbps
        DeviceProfile("jetson-class", 470e9, 6e9, 25e9, NO_NEXT_LINK),
    ),
    "edge_trio": (
        DeviceProfile("watch-class", 4e9, 0.8e9, 2e9, 100e6),
        DeviceProfile("phone-class", 80e9, 4e9, 15e9, 200e6),
        DeviceProfile("hub-class", 470e9, 8e9, 25e9, NO_NEXT_LINK),
    ),
    "pod_pipeline": (
        DeviceProfile("pod0-slice", 256 * 197e12, 256 * 16e9, 256 * 819e9,
                      50e9, kind="tpu_slice"),
        DeviceProfile("pod1-slice", 256 * 197e12, 256 * 16e9, 256 * 819e9,
                      NO_NEXT_LINK, kind="tpu_slice"),
    ),
}


@dataclass
class Placement:
    cuts: Tuple[int, ...]            # unit index AFTER which each cut happens
    assignment: Tuple[int, ...]      # per-unit device index
    latency_s: float
    transfer_s: float
    per_device_mem: Tuple[float, ...]
    level: int

    def describe(self, units: Sequence[Unit],
                 devices: Sequence[DeviceProfile]) -> str:
        segs = []
        start = 0
        for c in list(self.cuts) + [len(units) - 1]:
            d = devices[self.assignment[start]]
            segs.append(f"[{units[start].name}..{units[c].name}]@{d.name}")
            start = c + 1
        return " -> ".join(segs)


def place_dp(pp: PrePartition, devices: Sequence[DeviceProfile],
             level: int = 2, eps: float = 0.5,
             allow_skip: bool = False) -> Placement:
    """Exact DP: best[i][d] = min latency of units[0..i] ending on device d,
    devices used in order (pipeline chain).  O(N^2 * D)."""
    units = pp.units(level)
    n, nd = len(units), len(devices)
    comp = np.array([[dev.compute_seconds(u, eps) for dev in devices]
                     for u in units])                      # (N, D)
    mem = np.array([u.param_bytes + u.peak_act_bytes for u in units])
    bnd = np.array([u.boundary_bytes for u in units])
    pre_comp = np.cumsum(comp, axis=0)
    pre_mem = np.cumsum(mem)

    INF = float("inf")
    best = np.full((n, nd), INF)
    back: Dict[Tuple[int, int], Tuple[int, int]] = {}
    for i in range(n):
        for d in range(nd):
            # units 0..i all on device d (d must be first device used)
            seg_mem = pre_mem[i]
            if d == 0 and seg_mem <= devices[0].mem_bytes:
                best[i][d] = pre_comp[i][d]
            # or: cut after j on previous device e < d
            for j in range(i):
                seg_mem = pre_mem[i] - pre_mem[j]
                if seg_mem > devices[d].mem_bytes:
                    continue
                e_range = range(d) if allow_skip else ([d - 1] if d else [])
                for e in e_range:
                    if best[j][e] == INF:
                        continue
                    xfer = bnd[j] / max(devices[e].link_bw, 1.0)
                    cand = best[j][e] + xfer + (pre_comp[i][d] - pre_comp[j][d])
                    if cand < best[i][d]:
                        best[i][d] = cand
                        back[(i, d)] = (j, e)
    d_end = int(np.argmin(best[n - 1]))
    if best[n - 1][d_end] == INF:
        raise ValueError("no feasible placement (memory limits too tight)")
    # reconstruct
    cuts: List[int] = []
    assign = [0] * n
    i, d = n - 1, d_end
    while True:
        if (i, d) not in back:
            for k in range(i + 1):
                assign[k] = d
            break
        j, e = back[(i, d)]
        for k in range(j + 1, i + 1):
            assign[k] = d
        cuts.append(j)
        i, d = j, e
    cuts = sorted(cuts)
    transfer = sum(bnd[j] / max(devices[assign[j]].link_bw, 1.0) for j in cuts)
    per_mem = [float(mem[np.array(assign) == d].sum()) for d in range(nd)]
    return Placement(cuts=tuple(cuts), assignment=tuple(assign),
                     latency_s=float(best[n - 1][d_end]),
                     transfer_s=float(transfer),
                     per_device_mem=tuple(per_mem), level=level)


def place_cas(pp: PrePartition, devices: Sequence[DeviceProfile],
              level: int = 2, eps: float = 0.5) -> Placement:
    """CAS-style heuristic: walk units in order, move to the next device
    when the current one's accumulated latency exceeds its fair share."""
    units = pp.units(level)
    nd = len(devices)
    total = sum(dev.compute_seconds(u, eps) for u in units
                for dev in [devices[0]])
    share = total / nd
    assign = []
    d, acc = 0, 0.0
    for u in units:
        c = devices[d].compute_seconds(u, eps)
        if acc + c > share * 1.25 and d < nd - 1:
            d, acc = d + 1, 0.0
        assign.append(d)
        acc += devices[d].compute_seconds(u, eps)
    cuts = tuple(i for i in range(len(units) - 1)
                 if assign[i] != assign[i + 1])
    lat = 0.0
    for i, u in enumerate(units):
        lat += devices[assign[i]].compute_seconds(u, eps)
    transfer = sum(units[i].boundary_bytes
                   / max(devices[assign[i]].link_bw, 1.0) for i in cuts)
    mem = np.array([u.param_bytes + u.peak_act_bytes for u in units])
    per_mem = [float(mem[np.array(assign) == dd].sum()) for dd in range(nd)]
    return Placement(cuts=cuts, assignment=tuple(assign),
                     latency_s=lat + transfer, transfer_s=transfer,
                     per_device_mem=tuple(per_mem), level=level)


def place_dads(pp: PrePartition, devices: Sequence[DeviceProfile],
               level: int = 2, eps: float = 0.5) -> Placement:
    """DADS: DAG min-cut between local and remote.  For the sequential
    chains produced by pre-partitioning this is the 2-device exact cut."""
    return place_dp(pp, devices[:2], level=level, eps=eps)


def local_only(pp: PrePartition, devices: Sequence[DeviceProfile],
               level: int = 2, eps: float = 0.5) -> Placement:
    units = pp.units(level)
    lat = sum(devices[0].compute_seconds(u, eps) for u in units)
    mem = float(sum(u.param_bytes + u.peak_act_bytes for u in units))
    return Placement(cuts=(), assignment=tuple([0] * len(units)),
                     latency_s=lat, transfer_s=0.0,
                     per_device_mem=(mem,) + (0.0,) * (len(devices) - 1),
                     level=level)
