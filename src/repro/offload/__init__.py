from .graph_ir import Graph, OpNode, build_model_graph, execute
from .partition import PrePartition, Unit, independent_flows, pre_partition
from .placer import (DEVICE_POOLS, NO_NEXT_LINK, DeviceProfile, Placement,
                     local_only, place_cas, place_dads, place_dp)
from .transform import (classify_constants, convert, eliminate_dead,
                        eliminate_duplicates, fold_constants,
                        fuse_linear_chains)

__all__ = ["Graph", "OpNode", "build_model_graph", "execute", "PrePartition",
           "Unit", "independent_flows", "pre_partition", "DEVICE_POOLS",
           "NO_NEXT_LINK", "DeviceProfile", "Placement", "local_only",
           "place_cas",
           "place_dads", "place_dp", "classify_constants", "convert",
           "eliminate_dead", "eliminate_duplicates", "fold_constants",
           "fuse_linear_chains"]
