"""Operator-based DL model pre-partitioning (paper §III-B1).

Hierarchical hybrid granularity: the graph is decoupled bottom-up into
  level-0  minimal operator units (IR nodes)
  level-1  sublayer flows (attention / ffn / mamba of one layer)
  level-2  layers
  level-3  coarse stages (layer ranges)
independently of any latency requirement or device profile — partitioning
is *decoupled* from the offloading search, which later just combines
pre-partitioned units (the paper's key universality claim).  Topological
sorting yields independent operation flows; a sparse tensor↔op incidence
map records the cut tensors each boundary would transfer.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph_ir import Graph, OpNode


@dataclass(frozen=True)
class Unit:
    """A partitionable unit: a contiguous set of ops with one entry/exit."""
    name: str
    node_names: Tuple[str, ...]
    flops: float
    param_bytes: int
    peak_act_bytes: int
    boundary_bytes: int         # bytes crossing if cut AFTER this unit
    level: int                  # granularity level (0..3)


@dataclass
class PrePartition:
    graph: Graph
    levels: Dict[int, List[Unit]]          # granularity -> ordered units
    incidence: Dict[str, Tuple[str, ...]]  # tensor -> consumer op names

    def units(self, level: int) -> List[Unit]:
        return self.levels[level]

    def cut_points(self, level: int) -> List[int]:
        """Indices i such that cutting after unit i is legal (all are, for
        the sequential flows produced by topological decoupling)."""
        return list(range(len(self.levels[level]) - 1))


def _boundary_bytes(graph: Graph, covered: set, order: Sequence[OpNode]) -> int:
    """Bytes of tensors produced inside `covered` consumed outside it."""
    produced = {n.output for n in order if n.output in covered}
    out = 0
    for n in order:
        if n.output in covered:
            continue
        for i in n.inputs:
            if i in produced:
                out += graph.tensors.get(i, 0)
                produced.discard(i)  # count each tensor once
    for o in graph.outputs:
        if o in produced:
            out += graph.tensors.get(o, 0)
    return out


def _make_units(graph: Graph, groups: List[List[OpNode]], level: int,
                prefix: str) -> List[Unit]:
    order = graph.toposort()
    units = []
    covered: set = set()
    for gi, grp in enumerate(groups):
        covered |= {n.output for n in grp}
        units.append(Unit(
            name=f"{prefix}{gi}",
            node_names=tuple(n.output for n in grp),
            flops=sum(n.flops for n in grp),
            param_bytes=sum(n.param_bytes for n in grp),
            peak_act_bytes=max((n.out_bytes for n in grp), default=0),
            boundary_bytes=_boundary_bytes(graph, covered, order),
            level=level))
    return units


def pre_partition(graph: Graph, coarse_stages: int = 8) -> PrePartition:
    order = graph.toposort()
    # level 0: each op is a unit
    l0 = _make_units(graph, [[n] for n in order], 0, "op")
    # level 1: (layer, sublayer) flows; out-of-layer ops attach to neighbors
    flows: List[List[OpNode]] = []
    keymap: Dict[Tuple[int, str], int] = {}
    for n in order:
        key = (n.layer, n.sublayer)
        if n.layer < 0:
            # pre/post ops (embed, final norm, head) join the adjacent flow
            if not flows:
                flows.append([])
            flows[-1].append(n)
            continue
        if key not in keymap:
            keymap[key] = len(flows)
            flows.append([])
        flows[keymap[key]].append(n)
    l1 = _make_units(graph, flows, 1, "flow")
    # level 2: whole layers
    layers: List[List[OpNode]] = []
    lmap: Dict[int, int] = {}
    for n in order:
        if n.layer < 0:
            if not layers:
                layers.append([])
            layers[-1].append(n)
            continue
        if n.layer not in lmap:
            lmap[n.layer] = len(layers)
            layers.append([])
        layers[lmap[n.layer]].append(n)
    l2 = _make_units(graph, layers, 2, "layer")
    # level 3: coarse stages of roughly equal FLOPs
    total = sum(n.flops for n in order)
    per = total / coarse_stages if coarse_stages else total
    stages: List[List[OpNode]] = [[]]
    acc = 0.0
    for grp in layers:
        stages[-1].extend(grp)
        acc += sum(n.flops for n in grp)
        if acc >= per and len(stages) < coarse_stages:
            stages.append([])
            acc = 0.0
    if not stages[-1]:
        stages.pop()
    l3 = _make_units(graph, stages, 3, "stage")

    incidence = {t: tuple(c.output for c in cons)
                 for t, cons in graph.consumers().items()}
    return PrePartition(graph=graph, levels={0: l0, 1: l1, 2: l2, 3: l3},
                        incidence=incidence)


def independent_flows(graph: Graph) -> List[List[str]]:
    """Topologically independent op chains that may execute in parallel
    (the paper's 'independent operation flows' for operator parallelism).
    Two ops are in the same flow iff connected via producer/consumer edges
    at the same topological frontier."""
    order = graph.toposort()
    depth: Dict[str, int] = {}
    for n in order:
        depth[n.output] = 1 + max([depth.get(i, 0) for i in n.inputs] or [0])
    levels: Dict[int, List[str]] = {}
    for n in order:
        levels.setdefault(depth[n.output], []).append(n.output)
    return [levels[d] for d in sorted(levels)]
