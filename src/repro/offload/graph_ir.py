"""Minimal computation-graph IR (the paper's "intermediary computational
graph format", §III-B2).

The IR serves three middleware components:
  * scalable offloading — pre-partition + placement search over op units,
  * the model-adaptive engine — fusion / memory passes,
  * the profiler — per-op FLOPs and byte counts feed Eq. (1)/(2).

Small graphs are *executable* over numpy tensors so transformation passes
can be verified semantically (the redundancy-elimination guarantee of the
paper's two-stage conversion).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.configs import ATTN, LOCAL, MAMBA, ModelConfig


@dataclass
class OpNode:
    name: str
    kind: str                     # matmul | add | mul | act | norm | softmax |
                                  # attention | embed | const | input | output |
                                  # conv | reduce | fused(...)
    inputs: Tuple[str, ...]
    output: str
    flops: float = 0.0
    param_bytes: int = 0
    out_bytes: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)
    # grouping metadata for hierarchical pre-partition
    layer: int = -1               # transformer layer index (-1 = outside)
    sublayer: str = ""            # "attn" | "ffn" | "moe" | "mamba" | ""
    constant: bool = False        # output independent of graph inputs


@dataclass
class Graph:
    nodes: List[OpNode]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    tensors: Dict[str, int] = field(default_factory=dict)  # name -> bytes

    def node_map(self) -> Dict[str, OpNode]:
        return {n.output: n for n in self.nodes}

    def consumers(self) -> Dict[str, List[OpNode]]:
        cons: Dict[str, List[OpNode]] = {}
        for n in self.nodes:
            for i in n.inputs:
                cons.setdefault(i, []).append(n)
        return cons

    def toposort(self) -> List[OpNode]:
        produced = set(self.inputs)
        remaining = list(self.nodes)
        order: List[OpNode] = []
        while remaining:
            progressed = False
            rest = []
            for n in remaining:
                if all(i in produced for i in n.inputs):
                    order.append(n)
                    produced.add(n.output)
                    progressed = True
                else:
                    rest.append(n)
            remaining = rest
            if not progressed:
                raise ValueError("cycle or missing producer in graph")
        return order

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    def total_param_bytes(self) -> int:
        return sum(n.param_bytes for n in self.nodes)

    def validate(self) -> None:
        self.toposort()
        names = [n.output for n in self.nodes]
        if len(names) != len(set(names)):
            raise ValueError("duplicate tensor producers")


# ------------------------------------------------------------ execution ----
_ACTS = {"relu": lambda x: np.maximum(x, 0),
         "gelu": lambda x: 0.5 * x * (1 + np.tanh(0.79788456 * (x + 0.044715 * x ** 3))),
         "silu": lambda x: x / (1 + np.exp(-np.clip(x, -30, 30)))}


def execute(graph: Graph, feeds: Dict[str, np.ndarray],
            params: Optional[Dict[str, np.ndarray]] = None
            ) -> Dict[str, np.ndarray]:
    """Reference interpreter for small graphs (tests / transform checks)."""
    params = params or {}
    env: Dict[str, np.ndarray] = dict(feeds)
    env.update(params)
    for n in graph.toposort():
        x = [env[i] for i in n.inputs]
        k = n.kind
        if k == "matmul":
            env[n.output] = x[0] @ x[1]
        elif k == "add":
            env[n.output] = x[0] + x[1]
        elif k == "mul":
            env[n.output] = x[0] * x[1]
        elif k == "act":
            env[n.output] = _ACTS[n.attrs.get("fn", "relu")](x[0])
        elif k == "norm":
            mu = x[0].mean(-1, keepdims=True)
            var = x[0].var(-1, keepdims=True)
            y = (x[0] - mu) / np.sqrt(var + 1e-6)
            if len(x) > 1:
                y = y * x[1]
            if len(x) > 2:
                y = y + x[2]
            env[n.output] = y
        elif k == "softmax":
            e = np.exp(x[0] - x[0].max(-1, keepdims=True))
            env[n.output] = e / e.sum(-1, keepdims=True)
        elif k == "const":
            env[n.output] = np.asarray(n.attrs["value"])
        elif k == "reduce":
            fn = {"sum": np.sum, "mean": np.mean, "max": np.max}[
                n.attrs.get("fn", "sum")]
            env[n.output] = fn(x[0], axis=n.attrs.get("axis", -1))
        elif k.startswith("fused"):
            env[n.output] = _exec_fused(n, x)
        else:
            raise NotImplementedError(k)
    return {o: env[o] for o in graph.outputs}


def _exec_fused(n: OpNode, x: List[np.ndarray]) -> np.ndarray:
    """Execute a fused op from its recorded sub-op recipe.

    Convention: y starts as the first input; each binary step (matmul /
    add / mul) consumes the next unused input; unary steps transform y.
    The recipe INCLUDES the head op.
    """
    env = list(x)
    y = env[0]
    used = 1
    for step in n.attrs["recipe"]:
        kind = step["kind"]
        if kind in ("matmul", "conv"):
            y = y @ env[used]; used += 1
        elif kind == "add":
            y = y + env[used]; used += 1
        elif kind == "mul":
            y = y * env[used]; used += 1
        elif kind == "act":
            y = _ACTS[step.get("fn", "relu")](y)
        elif kind == "norm":
            mu = y.mean(-1, keepdims=True)
            var = y.var(-1, keepdims=True)
            y = (y - mu) / np.sqrt(var + 1e-6)
        elif kind == "reduce":
            fn = {"sum": np.sum, "mean": np.mean, "max": np.max}[
                step.get("fn", "sum")]
            y = fn(y, axis=step.get("axis", -1))
        else:
            raise NotImplementedError(kind)
    return y


# ----------------------------------------------- model-config -> IR --------
def build_model_graph(cfg: ModelConfig, batch: int, seq: int,
                      dtype_bytes: int = 2) -> Graph:
    """Lower a ModelConfig to the op-level IR (forward pass).

    One node per weight-touching op plus norms/activations/residuals —
    the granularity at which the paper's pre-partition and fusion operate.
    """
    nodes: List[OpNode] = []
    tensors: Dict[str, int] = {}
    t = batch * seq
    act_bytes = t * cfg.d_model * dtype_bytes

    def emit(name, kind, inputs, flops=0.0, pbytes=0, obytes=None, layer=-1,
             sub="", **attrs):
        nodes.append(OpNode(name=name, kind=kind, inputs=tuple(inputs),
                            output=name, flops=flops, param_bytes=pbytes,
                            out_bytes=obytes if obytes is not None else act_bytes,
                            attrs=attrs, layer=layer, sublayer=sub))
        tensors[name] = nodes[-1].out_bytes
        return name

    x = emit("embed", "embed", ["tokens"],
             pbytes=cfg.vocab_size * cfg.d_model * dtype_bytes)
    hd = cfg.resolved_head_dim
    pattern = cfg.block_pattern()
    li = 0
    for kind in pattern:
        l = li
        if kind == MAMBA:
            di = cfg.ssm_d_inner
            h = emit(f"l{l}.norm", "norm", [x], layer=l, sub="mamba",
                     flops=5 * t * cfg.d_model)
            pj = emit(f"l{l}.in_proj", "matmul", [h], layer=l, sub="mamba",
                      flops=2 * t * cfg.d_model * (2 * di + 2 * cfg.ssm_ngroups
                                                   * cfg.ssm_state_dim
                                                   + cfg.ssm_num_heads),
                      pbytes=cfg.d_model * (2 * di + 2 * cfg.ssm_ngroups
                                            * cfg.ssm_state_dim
                                            + cfg.ssm_num_heads) * dtype_bytes)
            cv = emit(f"l{l}.conv", "conv", [pj], layer=l, sub="mamba",
                      flops=2 * t * cfg.ssm_conv_dim * cfg.ssm_conv_width,
                      pbytes=cfg.ssm_conv_dim * cfg.ssm_conv_width * dtype_bytes)
            sc = emit(f"l{l}.ssd", "attention", [cv], layer=l, sub="mamba",
                      flops=2 * 6 * t * cfg.ssm_num_heads * cfg.ssm_head_dim
                      * cfg.ssm_state_dim)
            op = emit(f"l{l}.out_proj", "matmul", [sc], layer=l, sub="mamba",
                      flops=2 * t * di * cfg.d_model,
                      pbytes=di * cfg.d_model * dtype_bytes)
            x = emit(f"l{l}.res", "add", [x, op], layer=l, sub="mamba")
            li += 1
            continue
        # attention sublayer
        window = cfg.sliding_window if kind == LOCAL else 0
        ctx = min(seq, window) if window else seq
        h = emit(f"l{l}.ln1", "norm", [x], layer=l, sub="attn",
                 flops=5 * t * cfg.d_model)
        q = emit(f"l{l}.wq", "matmul", [h], layer=l, sub="attn",
                 flops=2 * t * cfg.d_model * cfg.q_dim,
                 pbytes=cfg.d_model * cfg.q_dim * dtype_bytes)
        kk = emit(f"l{l}.wk", "matmul", [h], layer=l, sub="attn",
                  flops=2 * t * cfg.d_model * cfg.kv_dim,
                  pbytes=cfg.d_model * cfg.kv_dim * dtype_bytes)
        vv = emit(f"l{l}.wv", "matmul", [h], layer=l, sub="attn",
                  flops=2 * t * cfg.d_model * cfg.kv_dim,
                  pbytes=cfg.d_model * cfg.kv_dim * dtype_bytes)
        at = emit(f"l{l}.attn", "attention", [q, kk, vv], layer=l, sub="attn",
                  flops=2 * 2 * t * cfg.num_heads * hd * (ctx / 2 if not window
                                                          else ctx),
                  window=window)
        ao = emit(f"l{l}.wo", "matmul", [at], layer=l, sub="attn",
                  flops=2 * t * cfg.q_dim * cfg.d_model,
                  pbytes=cfg.q_dim * cfg.d_model * dtype_bytes)
        x = emit(f"l{l}.res1", "add", [x, ao], layer=l, sub="attn")
        # ffn / moe sublayer
        sub = "moe" if cfg.arch_type == "moe" else "ffn"
        h2 = emit(f"l{l}.ln2", "norm", [x], layer=l, sub=sub,
                  flops=5 * t * cfg.d_model)
        f = cfg.d_ff
        if cfg.arch_type == "moe":
            active = cfg.experts_per_token + (1 if cfg.moe_shared_expert else 0)
            rt = emit(f"l{l}.router", "matmul", [h2], layer=l, sub=sub,
                      flops=2 * t * cfg.d_model * cfg.num_experts,
                      pbytes=cfg.d_model * cfg.num_experts * 4)
            mats = 3 if cfg.gated_ffn else 2
            up = emit(f"l{l}.experts", "matmul", [h2, rt], layer=l, sub=sub,
                      flops=2 * mats * t * active * cfg.d_model * f,
                      pbytes=mats * cfg.num_experts * cfg.d_model * f
                      * dtype_bytes)
            y = up
        else:
            up = emit(f"l{l}.w_up", "matmul", [h2], layer=l, sub=sub,
                      flops=2 * t * cfg.d_model * f,
                      pbytes=cfg.d_model * f * dtype_bytes,
                      obytes=t * f * dtype_bytes)
            if cfg.gated_ffn:
                g = emit(f"l{l}.w_gate", "matmul", [h2], layer=l, sub=sub,
                         flops=2 * t * cfg.d_model * f,
                         pbytes=cfg.d_model * f * dtype_bytes,
                         obytes=t * f * dtype_bytes)
                ga = emit(f"l{l}.act", "act", [g], layer=l, sub=sub,
                          flops=4 * t * f, fn=cfg.activation,
                          obytes=t * f * dtype_bytes)
                up = emit(f"l{l}.gate_mul", "mul", [ga, up], layer=l, sub=sub,
                          obytes=t * f * dtype_bytes)
            else:
                up = emit(f"l{l}.act", "act", [up], layer=l, sub=sub,
                          flops=4 * t * f, fn=cfg.activation,
                          obytes=t * f * dtype_bytes)
            y = emit(f"l{l}.w_down", "matmul", [up], layer=l, sub=sub,
                     flops=2 * t * f * cfg.d_model,
                     pbytes=f * cfg.d_model * dtype_bytes)
        x = emit(f"l{l}.res2", "add", [x, y], layer=l, sub=sub)
        li += 1
    x = emit("final_norm", "norm", [x], flops=5 * t * cfg.d_model)
    x = emit("lm_head", "matmul", [x],
             flops=2 * t * cfg.d_model * cfg.vocab_size,
             pbytes=0 if cfg.tie_embeddings else
             cfg.vocab_size * cfg.d_model * dtype_bytes,
             obytes=t * cfg.vocab_size * dtype_bytes)
    g = Graph(nodes=nodes, inputs=("tokens",), outputs=(x,), tensors=tensors)
    g.validate()
    return g
