"""Intermediate activation compression (paper §III-C2 ❼).

Per-block symmetric quantization of activations / KV-cache entries to
int8 or packed int4, with f32 scales.  Used by
  * the TTA path — compress saved activations post-forward, decode for
    backward (store 4/8-bit instead of 32, the paper's claim), and
  * the serving path — quantized KV cache (kv_cache_dtype="int8").

``repro.kernels.act_quant`` is the Pallas TPU kernel of the same codec;
this module is its jnp oracle and the CPU execution path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


def _pad_to_block(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % BLOCK
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8: returns (q (..., n), scales (..., n/BLOCK))."""
    xp, n = _pad_to_block(x)
    shape = xp.shape[:-1] + (xp.shape[-1] // BLOCK, BLOCK)
    blocks = xp.reshape(shape).astype(jnp.float32)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(xp.shape)[..., :n], scale[..., 0]


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    qp, n = _pad_to_block(q)
    shape = qp.shape[:-1] + (qp.shape[-1] // BLOCK, BLOCK)
    blocks = qp.reshape(shape).astype(jnp.float32)
    x = blocks * scale[..., None]
    return x.reshape(qp.shape)[..., :n].astype(dtype)


def quantize_int4(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int4 packed two-per-byte (uint8 storage)."""
    xp, n = _pad_to_block(x)
    shape = xp.shape[:-1] + (xp.shape[-1] // BLOCK, BLOCK)
    blocks = xp.reshape(shape).astype(jnp.float32)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = amax / 7.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -7, 7).astype(jnp.int8) + 8
    q = q.reshape(xp.shape).astype(jnp.uint8)
    lo, hi = q[..., 0::2], q[..., 1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scale[..., 0]


def dequantize_int4(packed: jax.Array, scale: jax.Array, n: int,
                    dtype=jnp.bfloat16) -> jax.Array:
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1]
                                             + (packed.shape[-1] * 2,))
    qp = q.astype(jnp.float32)
    shape = qp.shape[:-1] + (qp.shape[-1] // BLOCK, BLOCK)
    x = qp.reshape(shape) * scale[..., None]
    return x.reshape(qp.shape)[..., :n].astype(dtype)


def compressed_bytes(x_shape: Tuple[int, ...], bits: int) -> int:
    n = 1
    for s in x_shape:
        n *= s
    payload = n * bits // 8
    scales = (n // BLOCK) * 4
    return payload + scales


def compression_error(x: jax.Array, bits: int = 8) -> float:
    """Relative L2 reconstruction error (profiler accuracy-impact proxy)."""
    if bits == 8:
        q, s = quantize_int8(x)
        y = dequantize_int8(q, s, jnp.float32)
    else:
        q, s = quantize_int4(x)
        y = dequantize_int4(q, s, x.shape[-1], jnp.float32)
    x = x.astype(jnp.float32)
    return float(jnp.linalg.norm(x - y) / (jnp.linalg.norm(x) + 1e-9))
