"""Engine scheduling: operator parallelism + backprop reordering + the
EngineConfig → RuntimeOptions bridge (paper §III-C ❷/❹).

Cross-core operator parallelism: on mobile the paper co-schedules CPU+GPU;
on TPU the analogue is (a) independent op flows dispatched concurrently by
XLA and (b) compute/collective overlap.  ``plan_parallelism`` computes the
critical path over the IR and the achievable speedup with n concurrent
streams — the number the profiler charges.

Backprop operator reordering: gradients are applied per-layer immediately
(discarding the gradient right after its update), which in JAX is a scan
over layers whose carry holds no gradient tree — realized in
``repro.optim`` as layerwise-update mode for TTA.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.models.runtime import RuntimeOptions
from repro.offload.graph_ir import Graph
from repro.offload.partition import independent_flows


@dataclass(frozen=True)
class EngineConfig:
    """θ_s: the backend scheduling action surface."""
    fuse: bool = True
    parallel_streams: int = 2
    remat_policy: str = "none"          # none | dots | full
    kv_cache_dtype: str = "bfloat16"    # bfloat16 | int8 (via act_compress)
    attn_impl: str = "auto"
    q_chunk: int = 512
    k_chunk: int = 1024
    decode_window: int = 0
    use_pallas: bool = False
    sub_batches: int = 1
    host_swap: bool = False

    def to_runtime_options(self) -> RuntimeOptions:
        return RuntimeOptions(
            attn_impl=self.attn_impl, q_chunk=self.q_chunk,
            k_chunk=self.k_chunk, decode_window=self.decode_window,
            remat=self.remat_policy,
            use_pallas=self.use_pallas,
            kv_cache_dtype=("bfloat16" if self.kv_cache_dtype == "int8"
                            else self.kv_cache_dtype))


@dataclass
class ParallelPlan:
    serial_cost: float
    critical_path: float
    streams: int
    speedup: float
    level_widths: List[int]


def plan_parallelism(graph: Graph, streams: int = 2,
                     core_speed_ratio: float = 1.0) -> ParallelPlan:
    """Critical-path schedule of independent op flows over `streams` units.

    speedup = serial / max(critical_path, serial/streams) — the classic
    DAG bound; ``core_speed_ratio`` derates the second core (the paper's
    heterogeneous CPU+GPU case)."""
    levels = independent_flows(graph)
    node_cost = {n.output: max(n.flops, 1.0) for n in graph.nodes}
    serial = sum(node_cost.values())
    crit = 0.0
    widths = []
    eff_streams = 1.0 + (streams - 1) * core_speed_ratio
    for level in levels:
        costs = sorted((node_cost.get(t, 0.0) for t in level), reverse=True)
        widths.append(len(costs))
        # greedy LPT onto streams
        lanes = [0.0] * max(1, int(streams))
        for c in costs:
            lanes[lanes.index(min(lanes))] += c
        crit += max(lanes) if core_speed_ratio >= 1.0 else sum(costs) / eff_streams
    speedup = serial / max(crit, serial / eff_streams, 1e-30)
    return ParallelPlan(serial_cost=serial, critical_path=crit,
                        streams=streams, speedup=min(speedup, eff_streams),
                        level_widths=widths)


def backprop_reorder_savings(n_layers: int, grad_bytes_per_layer: int
                             ) -> Tuple[int, int]:
    """Engine ❹: retaining all gradients vs immediate per-layer update.

    Returns (bytes held at peak without reordering, with reordering)."""
    return n_layers * grad_bytes_per_layer, grad_bytes_per_layer
