"""Model-adaptive memory swapping (paper §III-C2 ❽).

On mobile the paper swaps activations between GPU and CPU memory; the TPU
analogue is HBM ↔ host offload.  JAX exposes this through sharding memory
kinds ("device" vs "pinned_host"); on the CPU-only container the transfer
is *modeled* — the Swapper tracks bytes moved and charges them at the
host-link bandwidth so the middleware optimizer sees honest costs either
way.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

HOST_LINK_BW = 32e9   # bytes/s PCIe-class host link (v5e host DMA)


@dataclass
class SwapRecord:
    name: str
    bytes: int
    direction: str   # "out" (to host) | "in" (to device)


@dataclass
class Swapper:
    """Tracks (and when supported, performs) HBM<->host transfers."""
    use_memory_kinds: bool = False      # real host offload (TPU runtime)
    records: List[SwapRecord] = field(default_factory=list)
    resident_host: Dict[str, Any] = field(default_factory=dict)

    def offload(self, name: str, x: jax.Array) -> jax.Array:
        self.records.append(SwapRecord(name, x.size * x.dtype.itemsize, "out"))
        if self.use_memory_kinds:
            try:
                dev = x.devices().pop()
                host = jax.sharding.SingleDeviceSharding(
                    dev, memory_kind="pinned_host")
                x = jax.device_put(x, host)
            except Exception:
                pass  # backend without pinned_host: keep on device
        self.resident_host[name] = x
        return x

    def fetch(self, name: str) -> jax.Array:
        x = self.resident_host.pop(name)
        self.records.append(SwapRecord(name, x.size * x.dtype.itemsize, "in"))
        if self.use_memory_kinds:
            try:
                dev = x.devices().pop()
                dsh = jax.sharding.SingleDeviceSharding(dev,
                                                        memory_kind="device")
                x = jax.device_put(x, dsh)
            except Exception:
                pass
        return x

    def total_bytes(self) -> int:
        return sum(r.bytes for r in self.records)

    def transfer_seconds(self, link_bw: float = HOST_LINK_BW) -> float:
        return self.total_bytes() / link_bw


def swap_plan(act_bytes_per_layer: List[int], budget_bytes: float
              ) -> Tuple[List[int], int]:
    """Choose which layers' saved activations to host-offload.

    DL inference is sequential (the paper's observation), so activations
    needed latest in the backward pass (earliest layers) are the best swap
    candidates: they have the longest idle window to prefetch back.
    Returns (layer indices to swap, resident bytes after swapping)."""
    total = sum(act_bytes_per_layer)
    swapped: List[int] = []
    resident = total
    for i, b in enumerate(act_bytes_per_layer):      # earliest first
        if resident <= budget_bytes:
            break
        swapped.append(i)
        resident -= b
    return swapped, int(resident)


def swap_overlap_latency(swapped_bytes: int, compute_seconds: float,
                         link_bw: float = HOST_LINK_BW) -> float:
    """Exposed (non-overlapped) transfer time: transfers hide under compute
    when the sequential window allows; only the excess is charged."""
    xfer = swapped_bytes / link_bw
    return max(0.0, xfer - compute_seconds)
