"""Tensor-lifetime-aware memory allocation (paper §III-C1 ❸).

From the computation graph's topological order we derive each tensor's
[first-def, last-use] lifetime interval, build global lifecycle constraints
(operator dependencies), and run a best-fit offset allocator with idle-block
reuse — the heuristic conflict-resolution step of the paper.  Outputs a
static allocation plan (tensor → offset) and the peak arena size, compared
against the no-reuse baseline.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.offload.graph_ir import Graph


@dataclass
class Lifetime:
    tensor: str
    size: int
    start: int     # producing step
    end: int       # last consuming step (inclusive)


@dataclass
class AllocationPlan:
    offsets: Dict[str, int]
    peak_bytes: int
    naive_bytes: int
    lifetimes: List[Lifetime]

    @property
    def reuse_ratio(self) -> float:
        return self.peak_bytes / max(self.naive_bytes, 1)

    def validate(self) -> None:
        """No two temporally-overlapping tensors may overlap in address."""
        lt = {l.tensor: l for l in self.lifetimes}
        items = list(self.offsets.items())
        for i, (t1, o1) in enumerate(items):
            for t2, o2 in items[i + 1:]:
                a, b = lt[t1], lt[t2]
                time_overlap = not (a.end < b.start or b.end < a.start)
                addr_overlap = not (o1 + a.size <= o2 or o2 + b.size <= o1)
                if time_overlap and addr_overlap:
                    raise AssertionError(
                        f"overlap: {t1}@{o1}+{a.size} vs {t2}@{o2}+{b.size}")


def tensor_lifetimes(graph: Graph, donate_inputs: bool = False
                     ) -> List[Lifetime]:
    order = graph.toposort()
    step_of = {n.output: i for i, n in enumerate(order)}
    last_use: Dict[str, int] = {}
    for i, n in enumerate(order):
        for inp in n.inputs:
            last_use[inp] = i
    for o in graph.outputs:
        last_use[o] = len(order)  # outputs live to the end
    lts = []
    for n in order:
        if n.kind == "const":
            continue  # weights/constants live in the param arena
        end = last_use.get(n.output, step_of[n.output])
        lts.append(Lifetime(tensor=n.output, size=max(n.out_bytes, 1),
                            start=step_of[n.output], end=end))
    return lts


def plan_memory(graph: Graph, alignment: int = 512) -> AllocationPlan:
    """Best-fit-with-reuse offset assignment over lifetime intervals.

    Tensors are placed in order of decreasing size (classic offset
    allocation); each placement scans existing allocations that overlap in
    time and picks the lowest gap that fits (idle-block reuse priority,
    paper ❸)."""
    lts = tensor_lifetimes(graph)
    naive = sum(l.size for l in lts)
    placed: List[Tuple[Lifetime, int]] = []
    offsets: Dict[str, int] = {}
    for l in sorted(lts, key=lambda x: (-x.size, x.start)):
        conflicts = [(off, p.size) for p, off in placed
                     if not (p.end < l.start or l.end < p.start)]
        conflicts.sort()
        best: Optional[int] = None
        cursor = 0
        for off, size in conflicts:
            if off - cursor >= l.size:
                best = cursor
                break
            cursor = max(cursor, off + size)
            cursor = (cursor + alignment - 1) // alignment * alignment
        if best is None:
            best = cursor
        offsets[l.tensor] = best
        placed.append((l, best))
    peak = max((off + l.size for l, off in placed), default=0)
    plan = AllocationPlan(offsets=offsets, peak_bytes=peak,
                          naive_bytes=naive, lifetimes=lts)
    plan.validate()
    return plan


def greedy_no_reuse(graph: Graph) -> int:
    """Baseline: every tensor gets fresh memory (what the paper compares
    its allocator against)."""
    return sum(l.size for l in tensor_lifetimes(graph))


def peak_live_bytes(graph: Graph) -> int:
    """Information-theoretic lower bound: max over time of live bytes."""
    lts = tensor_lifetimes(graph)
    horizon = max((l.end for l in lts), default=0) + 1
    live = [0] * (horizon + 1)
    for l in lts:
        for t in range(l.start, min(l.end, horizon) + 1):
            live[t] += l.size
    return max(live, default=0)
