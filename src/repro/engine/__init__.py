from .act_compress import (compressed_bytes, compression_error,
                           dequantize_int4, dequantize_int8, quantize_int4,
                           quantize_int8)
from .fusion import STRATEGIES, FusionReport, fuse_graph, fusion_memory_saving
from .memory import (AllocationPlan, greedy_no_reuse, peak_live_bytes,
                     plan_memory, tensor_lifetimes)
from .remat import (POLICY_LADDER, RematDecision, activation_bytes,
                    choose_policy, sub_batch_split)
from .schedule import (EngineConfig, ParallelPlan, backprop_reorder_savings,
                       plan_parallelism)
from .swap import Swapper, swap_overlap_latency, swap_plan

__all__ = ["compressed_bytes", "compression_error", "dequantize_int4",
           "dequantize_int8", "quantize_int4", "quantize_int8", "STRATEGIES",
           "FusionReport", "fuse_graph", "fusion_memory_saving",
           "AllocationPlan", "greedy_no_reuse", "peak_live_bytes",
           "plan_memory", "tensor_lifetimes", "POLICY_LADDER",
           "RematDecision", "activation_bytes", "choose_policy",
           "sub_batch_split", "EngineConfig", "ParallelPlan",
           "backprop_reorder_savings", "plan_parallelism", "Swapper",
           "swap_overlap_latency", "swap_plan"]
