"""Runtime operator fusion — the engine's five strategies (paper §III-C1 ❶).

The engine classifies ops by input→output mapping and progressively attempts
fusion across types, extending the offload component's generic chain fusion
with strategy-targeted passes.  Each pass reports the memory traffic it
eliminates (intermediate feature-map bytes) — that number feeds the
profiler's M_l terms, closing the paper's back-to-front feedback loop.

On the JAX side the same decisions surface as RuntimeOptions: fused Pallas
kernels (fused_ffn, flash_attn) replace the unfused jnp chains when
``use_pallas`` is on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.offload.graph_ir import Graph, OpNode
from repro.offload.transform import eliminate_duplicates, fuse_linear_chains

STRATEGIES = ("linear", "conv_norm", "elementwise", "channelwise", "reduction")


@dataclass
class FusionReport:
    strategy: str
    ops_before: int
    ops_after: int
    bytes_saved: int        # intermediate tensors no longer materialized

    @property
    def ops_fused(self) -> int:
        return self.ops_before - self.ops_after


def _classify(n: OpNode) -> str:
    """Classify by input->output mapping (the paper's fusion taxonomy)."""
    if n.kind in ("matmul",):
        return "linear"
    if n.kind in ("conv",):
        return "conv_norm"
    if n.kind in ("act", "add", "mul"):
        return "elementwise"
    if n.kind in ("norm", "softmax"):
        return "channelwise"
    if n.kind in ("reduce",):
        return "reduction"
    return "opaque"


def fuse_graph(graph: Graph, strategies: Tuple[str, ...] = STRATEGIES
               ) -> Tuple[Graph, List[FusionReport]]:
    """Progressively apply fusion strategies; report per-strategy savings."""
    reports: List[FusionReport] = []
    g = graph
    before_bytes = _intermediate_bytes(g)
    for strat in strategies:
        ops_before = len(g.nodes)
        g2 = _apply_strategy(g, strat)
        saved = _intermediate_bytes(g) - _intermediate_bytes(g2)
        reports.append(FusionReport(strategy=strat, ops_before=ops_before,
                                    ops_after=len(g2.nodes),
                                    bytes_saved=max(0, saved)))
        g = g2
    return g, reports


def _apply_strategy(graph: Graph, strategy: str) -> Graph:
    # all strategies reduce to targeted chain fusion over their op classes;
    # the generic fuser already walks matmul/conv heads, so strategies
    # narrow WHICH tails fuse by temporarily filtering eligibility.
    import repro.offload.transform as T
    saved_tail, saved_bin = T.FUSABLE_TAIL, T.FUSABLE_BIN
    try:
        if strategy == "linear":
            T.FUSABLE_TAIL, T.FUSABLE_BIN = ("act",), ("add",)
        elif strategy == "conv_norm":
            T.FUSABLE_TAIL, T.FUSABLE_BIN = ("norm",), ()
        elif strategy == "elementwise":
            T.FUSABLE_TAIL, T.FUSABLE_BIN = ("act",), ("add", "mul")
        elif strategy == "channelwise":
            T.FUSABLE_TAIL, T.FUSABLE_BIN = ("norm", "softmax"), ()
        elif strategy == "reduction":
            T.FUSABLE_TAIL, T.FUSABLE_BIN = ("reduce",), ()
        return fuse_linear_chains(graph)
    finally:
        T.FUSABLE_TAIL, T.FUSABLE_BIN = saved_tail, saved_bin


def _intermediate_bytes(graph: Graph) -> int:
    outs = set(graph.outputs)
    return sum(n.out_bytes for n in graph.nodes if n.output not in outs)


def fusion_memory_saving(graph: Graph) -> Dict[str, int]:
    """bytes saved per strategy if applied alone (for optimizer napkin math)."""
    out = {}
    for s in STRATEGIES:
        g2 = _apply_strategy(graph, s)
        out[s] = max(0, _intermediate_bytes(graph) - _intermediate_bytes(g2))
    return out
