"""Progressive recomputation (paper §III-C2 ❺/❻ for TTA workloads).

On TPU/JAX, recomputation is ``jax.checkpoint`` with a policy.  The engine
exposes a *progressive* ladder of policies ordered by activation memory vs
recompute FLOPs; given a live memory budget it walks down the ladder until
the analytic activation footprint fits — the paper's "proactively discards
tensors when memory exceeds thresholds, recomputes when budget changes".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.configs import InputShape, ModelConfig

# (name, activation fraction kept, recompute FLOP overhead fraction)
POLICY_LADDER: Tuple[Tuple[str, float, float], ...] = (
    ("none", 1.00, 0.00),   # keep everything
    ("dots", 0.45, 0.18),   # keep matmul outputs, recompute elementwise/norm
    ("full", 0.08, 0.33),   # keep only layer boundaries (classic 1/L remat)
)


@dataclass(frozen=True)
class RematDecision:
    policy: str
    act_bytes: int
    recompute_flops: float


def activation_bytes(cfg: ModelConfig, batch: int, seq: int,
                     dtype_bytes: int = 2) -> int:
    """Forward activation footprint per step without any remat."""
    t = batch * seq
    per_layer = t * (
        4 * cfg.d_model                      # block inputs/residuals/norms
        + 2 * cfg.q_dim + 2 * cfg.kv_dim     # qkvo
        + (3 if cfg.gated_ffn else 2) * cfg.d_ff   # ffn hiddens
    ) * dtype_bytes
    if cfg.arch_type in ("ssm", "hybrid"):
        per_layer = t * (4 * cfg.d_model + 3 * cfg.ssm_d_inner
                         + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim
                         ) * dtype_bytes
    n = cfg.num_layers * per_layer
    n += t * cfg.vocab_size * dtype_bytes   # logits
    return int(n)


def choose_policy(cfg: ModelConfig, batch: int, seq: int,
                  budget_bytes: float, dtype_bytes: int = 2,
                  train_flops: Optional[float] = None) -> RematDecision:
    """Walk the ladder progressively; return the cheapest policy that fits.

    If even 'full' misses the budget, return it anyway (the middleware then
    escalates to sub-batch accumulation / offloading instead)."""
    base = activation_bytes(cfg, batch, seq, dtype_bytes)
    flops = train_flops or (3.0 * cfg.flops_per_token(seq) * batch * seq)
    decision = None
    for name, keep, overhead in POLICY_LADDER:
        decision = RematDecision(policy=name,
                                 act_bytes=int(base * keep),
                                 recompute_flops=flops * overhead)
        if decision.act_bytes <= budget_bytes:
            return decision
    return decision  # the most aggressive one


def sub_batch_split(cfg: ModelConfig, batch: int, seq: int,
                    budget_bytes: float, policy: str = "full",
                    dtype_bytes: int = 2) -> int:
    """Engine ❽: number of gradient-accumulation sub-batches needed so the
    per-sub-batch activation footprint fits the budget."""
    keep = dict((n, k) for n, k, _ in POLICY_LADDER)[policy]
    per_example = activation_bytes(cfg, 1, seq, dtype_bytes) * keep
    max_examples = max(1, int(budget_bytes / max(per_example, 1)))
    n = 1
    while batch // n > max_examples and n < batch:
        n *= 2
    return min(n, batch)
