"""CrowdHMTware middleware facade (paper §III-D3).

The paper's public surface is ``run.py(device_id, model, IP, PORT, fuse,
quan)``; the TPU-framework analogue keeps the same spirit: register a
model once, then let the middleware own variant selection, placement and
engine configuration while the application just calls ``infer`` /
``train_step``.  "It hides run-time system issues from developers."
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.elastic.supernet import ElasticSupernet
from repro.elastic.tta import tta_step
from repro.models.configs import InputShape, ModelConfig, TRAIN_4K
from repro.models.layers import Params
from repro.models.model import decode_step, forward, init_cache, prefill
from repro.models.runtime import RuntimeOptions

from .loop import AdaptationLoop, Decision
from .monitor import ResourceContext
from .optimizer import Budgets
from .profiler import HardwareProfile, TPU_V5E


@dataclass
class Middleware:
    """run(device_id, model, ...) → adaptive execution."""
    cfg: ModelConfig
    params: Params
    shape: InputShape = TRAIN_4K
    hw: HardwareProfile = TPU_V5E
    budgets: Budgets = field(default_factory=Budgets)
    fuse: bool = True
    quan: bool = False              # the paper API's activation-quant flag
    tta_enabled: bool = True
    allow_offload: bool = True

    def __post_init__(self):
        self.supernet = ElasticSupernet(self.cfg, self.params)
        self.loop = AdaptationLoop(cfg=self.cfg, shape=self.shape,
                                   supernet=self.supernet, hw=self.hw,
                                   budgets=self.budgets,
                                   allow_offload=self.allow_offload)
        self.loop.build_pareto(evolve=False)
        self._compiled: Dict[Any, Callable] = {}
        self._drift_seen = 0.0

    # ------------------------------------------------------------ control --
    def adapt(self, ctx: ResourceContext) -> Decision:
        """One loop tick: monitor -> profile -> optimize -> reconfigure."""
        d = self.loop.tick(ctx)
        if self.tta_enabled and ctx.data_drift - self._drift_seen > 0.25:
            self._drift_seen = ctx.data_drift
        return d

    def current_runtime(self) -> Tuple[ModelConfig, Params, RuntimeOptions]:
        if self.loop.current is None:
            self.adapt(ResourceContext())
        return self.loop.materialize()

    # ------------------------------------------------------------ serving --
    def infer(self, tokens: jax.Array, **fwd_kw) -> jax.Array:
        vcfg, vparams, opts = self.current_runtime()
        key = (vcfg.name, vcfg.num_layers, vcfg.d_ff, vcfg.num_kv_heads,
               opts, "fwd")
        if key not in self._compiled:
            self._compiled[key] = jax.jit(
                lambda p, t, kw: forward(p, vcfg, t, opts, **kw)[0],
                static_argnames=())
        return self._compiled[key](vparams, tokens, fwd_kw)

    def adapt_weights(self, live_tokens: jax.Array, lr: float = 1e-3
                      ) -> float:
        """Test-time adaptation on unlabeled live data (drift mitigation)."""
        vcfg, vparams, opts = self.current_runtime()
        new_params, ent = tta_step(self.supernet.backbone_params, self.cfg,
                                   live_tokens, lr=lr)
        self.supernet.backbone_params = new_params
        self.supernet._cache.clear()       # variants re-derive lazily
        self._drift_seen = 0.0
        return float(ent)

    def report(self) -> str:
        lines = ["tick  reason                      action"]
        for d in self.loop.decisions[-10:]:
            lines.append(f"{d.tick:4d}  {d.reason:26s} {d.action.describe()}")
        return "\n".join(lines)
