from .actions import Action, OffloadChoice, default_action_space
from .loop import AdaptationLoop, Decision
from .middleware import Middleware
from .monitor import (ResourceContext, ResourceMonitor, budget_sweep_trace,
                      case_study_trace, constant_trace, dvfs_spike_trace,
                      shape_context, shaped_trace)
from .optimizer import (ActionEvaluator, Budgets, Evaluation, ahp_weights,
                        context_ahp, evolve_pareto, nondominated_front,
                        select_online)
from .profiler import (Calibration, HardwareProfile,
                       LayerCost, MOBILE_CPU, analytic_step_costs,
                       RooflineTerms, TPU_V5E, collective_bytes_from_hlo,
                       estimate_energy, estimate_latency, layer_costs,
                       model_flops_estimate, rank_consistency,
                       roofline_terms)

__all__ = ["analytic_step_costs", "Action", "OffloadChoice", "default_action_space",
           "AdaptationLoop", "Calibration", "Decision",
           "Middleware", "ResourceContext",
           "ResourceMonitor", "budget_sweep_trace", "case_study_trace",
           "constant_trace", "dvfs_spike_trace", "shape_context",
           "shaped_trace", "ActionEvaluator",
           "Budgets", "Evaluation", "ahp_weights", "context_ahp",
           "evolve_pareto", "nondominated_front", "select_online",
           "HardwareProfile", "LayerCost", "MOBILE_CPU", "RooflineTerms",
           "TPU_V5E", "collective_bytes_from_hlo", "estimate_energy",
           "estimate_latency", "layer_costs", "model_flops_estimate",
           "rank_consistency", "roofline_terms"]
