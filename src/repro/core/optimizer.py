"""Two-stage runtime optimizer (paper §III-D2).

Offline: evolutionary search (NSGA-II-style nondominated sorting with
channel-wise variance / Gaussian-noise diversity injection) over the
cross-level action space, producing a Pareto front of (accuracy, energy)
— importance-free, as the paper insists.

Online: the decision variables adjust to the live context; an analytical
hierarchy process (AHP) derives the importance weights, μ = Norm(B_r)
balances accuracy vs energy, and the feasible action maximizing
μ·Norm(A) − (1−μ)·Norm(E) subject to T ≤ T_bgt, M ≤ M_bgt is selected.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.elastic.operators import VariantSpec, variant_cost
from repro.engine.remat import POLICY_LADDER, activation_bytes
from repro.models.configs import InputShape, ModelConfig
from repro.offload.placer import DEVICE_POOLS, place_dp

from .actions import Action, OffloadChoice

# the modeled accuracy cost of one unit of unmitigated data drift
# (``accuracy_of`` subtracts DRIFT_ACCURACY_COST × ctx.data_drift); the
# telemetry accuracy channel uses the same constant to back modeled
# drift out of crowd-labeled observations before pooling them
DRIFT_ACCURACY_COST = 0.10
from .monitor import ResourceContext
from .profiler import (Calibration, HardwareProfile, TPU_V5E,
                       estimate_energy, estimate_latency, layer_costs)


@dataclass
class Evaluation:
    accuracy: float          # proxy or measured, higher better
    energy_j: float
    latency_s: float
    memory_bytes: float
    action: Action


# pre-partitions are pure functions of (cfg, batch, seq); memoize them so
# re-evaluating offload actions (which the fleet placer makes routine)
# doesn't rebuild the op graph on every profiler call
_PP_CACHE: Dict[tuple, object] = {}


def _prepartition(cfg: ModelConfig, batch: int, seq: int):
    key = (cfg, batch, seq)
    if key not in _PP_CACHE:
        from repro.offload.graph_ir import build_model_graph
        from repro.offload.partition import pre_partition
        if len(_PP_CACHE) > 64:        # bound: variant ladders are small
            _PP_CACHE.clear()
        _PP_CACHE[key] = pre_partition(
            build_model_graph(cfg, batch, seq))
    return _PP_CACHE[key]


class ActionEvaluator:
    """Maps an Action + context -> (A, E, T, M) through the profiler.

    Accuracy is a calibrated proxy: monotone in retained FLOPs, penalized
    by unmitigated data drift, with optional measured overrides (the
    benchmarks inject real accuracies for the paper-backbone model, and
    the fleet's accuracy telemetry channel feeds crowd-measured values
    back in here).

    ``pool_resolver`` maps an ``OffloadChoice`` to the device chain it
    places onto; the default resolves ``offload.pool`` in the static
    ``DEVICE_POOLS``, while a fleet-attached evaluator gets a resolver
    that synthesizes live calibrated profiles for ``offload.peers``
    chains.  A resolver returning an empty chain marks the action
    infeasible (e.g. every helper in the chain left the fleet)."""

    def __init__(self, cfg: ModelConfig, shape: InputShape,
                 hw: HardwareProfile = TPU_V5E, base_accuracy: float = 0.76,
                 measured: Optional[Dict[VariantSpec, float]] = None,
                 calibration: Optional[Calibration] = None,
                 pool_resolver: Optional[Callable[
                     [OffloadChoice], Sequence]] = None):
        self.cfg = cfg
        self.shape = shape
        self.hw = hw
        self.base_accuracy = base_accuracy
        self.measured = measured or {}
        self.calibration = calibration
        self.pool_resolver = pool_resolver
        self._full = variant_cost(cfg, VariantSpec(), shape.seq_len)

    def resolve_pool(self, offload: OffloadChoice) -> Sequence:
        """The device chain an offload choice places onto (see
        ``pool_resolver``)."""
        if self.pool_resolver is not None:
            return self.pool_resolver(offload)
        return DEVICE_POOLS[offload.pool]

    def _variant_cfg(self, spec: VariantSpec) -> ModelConfig:
        c = self.cfg
        if spec.depth_ratio < 1.0:
            c = c.with_updates(num_layers=max(1, int(round(
                c.num_layers * spec.depth_ratio))))
        if spec.width_ratio < 1.0 and c.d_ff:
            c = c.with_updates(d_ff=max(8, int(c.d_ff * spec.width_ratio)
                                        // 8 * 8))
        return c

    def proxy_accuracy(self, spec: VariantSpec) -> float:
        """The drift-free analytic accuracy proxy for one variant —
        never consults ``measured`` (the telemetry accuracy channel fits
        crowd observations *against* this value)."""
        ratio = (variant_cost(self.cfg, spec, self.shape.seq_len)
                 ["flops_per_token"] / self._full["flops_per_token"])
        # empirical supernet curve: gentle until ~50% FLOPs, then steep
        return self.base_accuracy * (1.0 - 0.25 * (1 - ratio) ** 2
                                     - 0.35 * max(0.0, 0.45 - ratio))

    def accuracy_of(self, spec: VariantSpec, ctx: ResourceContext) -> float:
        a = (self.measured[spec] if spec in self.measured
             else self.proxy_accuracy(spec))
        a -= DRIFT_ACCURACY_COST * ctx.data_drift   # unmitigated drift cost
        return max(a, 0.0)

    def evaluate(self, action: Action, ctx: ResourceContext,
                 calibrate: bool = True) -> Evaluation:
        """Evaluate an action.  ``calibrate=False`` yields the raw analytic
        prediction even when a telemetry ``Calibration`` is installed —
        telemetry stores need the uncorrected value to fit against."""
        cfg = self._variant_cfg(action.variant)
        decode = self.shape.is_decode
        costs = layer_costs(cfg, self.shape.global_batch, self.shape.seq_len,
                            decode=decode)
        # engine effects on the M_l terms / ε
        eps = 0.55
        if action.engine.fuse:
            eps = 0.70                     # fusion keeps intermediates in VMEM
        kv_scale = 0.5 if action.engine.kv_cache_dtype == "int8" else 1.0
        if decode and kv_scale != 1.0:
            costs = [dataclasses.replace(c, bytes=c.bytes * kv_scale)
                     for c in costs]
        eff_flops = ctx.effective_flops(self.hw.peak_flops)
        lat = estimate_latency(costs, eps, self.hw, effective_flops=eff_flops)
        if action.engine.parallel_streams > 1:
            lat /= min(1.35, 1.0 + 0.35 * (action.engine.parallel_streams - 1))
        energy = estimate_energy(costs, eps, self.hw)

        # memory: params + activations (remat policy) + KV cache
        keep = dict((n, k) for n, k, _ in POLICY_LADDER)[
            action.engine.remat_policy]
        act_b = activation_bytes(cfg, self.shape.global_batch,
                                 self.shape.seq_len) * keep
        act_b /= max(action.engine.sub_batches, 1)
        if action.engine.sub_batches > 1:
            lat *= 1.0 + 0.05 * (action.engine.sub_batches - 1)
        mem = cfg.param_count() * 2 + act_b
        if decode:
            mem += cfg.kv_cache_bytes(self.shape.global_batch,
                                      self.shape.seq_len) * kv_scale

        # offloading: replace local latency with the placed pipeline's
        if action.offload.enabled:
            pp = _prepartition(cfg, 1, min(self.shape.seq_len, 512))
            devices = self.resolve_pool(action.offload)
            try:
                if not devices:
                    raise ValueError("empty device chain")
                pl = place_dp(pp, devices, level=action.offload.level)
                scale = (self.shape.global_batch * self.shape.seq_len
                         / (1 * min(self.shape.seq_len, 512)))
                lat = pl.latency_s * scale
                # the LOCAL device is what the memory budget constrains
                mem = pl.per_device_mem[0]
            except ValueError:
                lat = float("inf")
        if calibrate and self.calibration is not None \
                and not action.offload.enabled:
            lat = self.calibration.latency(lat)
            energy = self.calibration.energy(energy)
        return Evaluation(accuracy=self.accuracy_of(action.variant, ctx),
                          energy_j=energy, latency_s=lat, memory_bytes=mem,
                          action=action)


# ----------------------------------------------------- offline: Pareto -----
def nondominated_front(evals: Sequence[Evaluation]) -> List[Evaluation]:
    """Pareto front over (maximize accuracy, minimize energy) — no
    importance coefficients, per the paper."""
    front = []
    for e in evals:
        dominated = False
        for f in evals:
            if f is e:
                continue
            if (f.accuracy >= e.accuracy and f.energy_j <= e.energy_j
                    and (f.accuracy > e.accuracy or f.energy_j < e.energy_j)):
                dominated = True
                break
        if not dominated:
            front.append(e)
    return sorted(front, key=lambda e: -e.accuracy)


def mutate_spec(spec: VariantSpec, rng: random.Random) -> VariantSpec:
    """Diversity injection: channel-wise variance + Gaussian noise on the
    continuous knobs (paper's candidate-diversity enhancement)."""
    def jitter(x, lo, hi, s=0.1):
        return float(np.clip(x + rng.gauss(0, s), lo, hi))
    return VariantSpec(
        rank_ratio=round(jitter(spec.rank_ratio, 0.25, 1.0), 2),
        kv_merge=spec.kv_merge if rng.random() > 0.2 else
        rng.choice((1, 2)),
        ghost=spec.ghost if rng.random() > 0.2 else not spec.ghost,
        depth_ratio=round(jitter(spec.depth_ratio, 0.25, 1.0), 2),
        width_ratio=round(jitter(spec.width_ratio, 0.25, 1.0), 2),
        head_ratio=spec.head_ratio,
        window=spec.window)


def evolve_pareto(evaluator: ActionEvaluator, seed_actions: Sequence[Action],
                  ctx: ResourceContext, generations: int = 6,
                  population: int = 32, seed: int = 0) -> List[Evaluation]:
    """Offline evolutionary stage: static problem, broad exploration."""
    rng = random.Random(seed)
    pop = list(seed_actions)[:population]
    while len(pop) < population:
        base = rng.choice(seed_actions)
        pop.append(dataclasses.replace(
            base, variant=mutate_spec(base.variant, rng)))
    for _ in range(generations):
        evals = [evaluator.evaluate(a, ctx) for a in pop]
        front = nondominated_front(evals)
        parents = [e.action for e in front] or pop[:4]
        children = []
        while len(children) + len(parents) < population:
            p = rng.choice(parents)
            children.append(dataclasses.replace(
                p, variant=mutate_spec(p.variant, rng)))
        pop = parents + children
    final = [evaluator.evaluate(a, ctx) for a in pop]
    return nondominated_front(final)


# ------------------------------------------------------- online: AHP + μ ---
def ahp_weights(pairwise: np.ndarray) -> np.ndarray:
    """Principal-eigenvector weights from a pairwise comparison matrix."""
    vals, vecs = np.linalg.eig(pairwise)
    w = np.abs(np.real(vecs[:, np.argmax(np.real(vals))]))
    return w / w.sum()


def context_ahp(ctx: ResourceContext) -> np.ndarray:
    """Importance of (accuracy, energy, latency, memory) given the context.
    Battery low -> energy dominates; memory scarce -> memory dominates."""
    a_vs_e = max(0.2, 5.0 * ctx.battery_frac)       # rich battery favors A
    a_vs_m = max(0.2, 5.0 * ctx.mem_free_frac)
    a_vs_t = 1.0 / max(ctx.request_rate, 0.25)
    m = np.array([
        [1.0,       a_vs_e,    a_vs_t,   a_vs_m],
        [1/a_vs_e,  1.0,       1.0,      1.0],
        [1/a_vs_t,  1.0,       1.0,      1.0],
        [1/a_vs_m,  1.0,       1.0,      1.0]])
    return ahp_weights(m)


@dataclass
class Budgets:
    latency_s: float = float("inf")
    memory_bytes: float = float("inf")


def select_online(front: Sequence[Evaluation], ctx: ResourceContext,
                  budgets: Budgets) -> Optional[Evaluation]:
    """μ = Norm(B_r); score = μ·Norm(A) − (1−μ)·Norm(E) over feasible set."""
    feasible = [e for e in front
                if e.latency_s <= budgets.latency_s
                and e.memory_bytes <= budgets.memory_bytes]
    pool = feasible or None
    if pool is None:
        # constraint relaxation: fall back to minimum-violation action
        def viol(e):
            return (max(0.0, e.latency_s / budgets.latency_s - 1)
                    + max(0.0, e.memory_bytes / budgets.memory_bytes - 1))
        return min(front, key=viol) if front else None
    mu = float(np.clip(ctx.battery_frac, 0.05, 0.95))
    accs = np.array([e.accuracy for e in pool])
    ens = np.array([e.energy_j for e in pool])
    def norm(x):
        lo, hi = float(x.min()), float(x.max())
        return (x - lo) / (hi - lo) if hi > lo else np.zeros_like(x)
    w = context_ahp(ctx)
    lat = np.array([e.latency_s for e in pool])
    mem = np.array([e.memory_bytes for e in pool])
    score = mu * norm(accs) - (1 - mu) * norm(ens) \
        - w[2] * norm(lat) - w[3] * norm(mem)
    return pool[int(np.argmax(score))]
