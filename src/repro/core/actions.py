"""The cross-level action space (θ_p, θ_o, θ_s) the optimizer searches
(paper §III-D2).

θ_p — elastic model variant (compression-operator combination, η1…η6)
θ_o — offloading placement (pre-partition level + device pool cut)
θ_s — engine schedule (fusion, remat, KV dtype, chunking, sub-batching)
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.elastic.operators import FULL_SPEC, VariantSpec
from repro.engine.schedule import EngineConfig


@dataclass(frozen=True)
class OffloadChoice:
    """θ_o: where (and at what granularity) to place the partitioned
    model.

    ``pool`` names the placement target.  With empty ``peers`` it is a
    key into the static ``repro.offload.placer.DEVICE_POOLS`` (or a
    mesh-slice pipeline).  When ``peers`` is non-empty the target is a
    chain of live *fleet members* — ``peers[0]`` is the requesting
    device itself, the rest are helper device-ids — and the evaluator
    resolves it through its installed ``pool_resolver`` (the fleet
    placer synthesizing calibrated live profiles) instead of the static
    table; ``pool`` then serves only as a display label (``"fleet"``).
    """
    enabled: bool = False
    pool: str = "edge_pair"      # DEVICE_POOLS key, or "fleet" with peers
    level: int = 2               # pre-partition granularity
    peers: Tuple[str, ...] = ()  # live fleet chain; [0] = requester


@dataclass(frozen=True)
class Action:
    variant: VariantSpec = FULL_SPEC
    offload: OffloadChoice = OffloadChoice()
    engine: EngineConfig = EngineConfig()

    def describe(self) -> str:
        ops = "+".join(self.variant.operators()) or "full"
        target = (">".join(self.offload.peers) if self.offload.peers
                  else self.offload.pool)
        off = (f"offload[{target}/L{self.offload.level}]"
               if self.offload.enabled else "local")
        eng = (f"fuse={int(self.engine.fuse)},remat={self.engine.remat_policy},"
               f"kv={self.engine.kv_cache_dtype},streams={self.engine.parallel_streams}")
        return f"θp={ops} θo={off} θs=({eng})"


def default_action_space(variants: Sequence[VariantSpec],
                         allow_offload: bool = True,
                         decode: bool = False) -> Tuple[Action, ...]:
    """A tractable cross-product of the three levels."""
    engines = [
        EngineConfig(fuse=False, remat_policy="none"),
        EngineConfig(fuse=True, remat_policy="none"),
        EngineConfig(fuse=True, remat_policy="dots"),
        EngineConfig(fuse=True, remat_policy="full", sub_batches=2),
        EngineConfig(fuse=True, kv_cache_dtype="int8"),
    ]
    if decode:
        engines.append(EngineConfig(fuse=True, decode_window=8192))
    offloads = [OffloadChoice(False)]
    if allow_offload:
        offloads += [OffloadChoice(True, "edge_pair", 2),
                     OffloadChoice(True, "edge_trio", 2),
                     OffloadChoice(True, "pod_pipeline", 3)]
    actions = []
    for v, o, e in itertools.product(variants, offloads, engines):
        actions.append(Action(variant=v, offload=o, engine=e))
    return tuple(actions)
