"""Runtime performance profiler (paper §III-D1, Eq. 1 / Eq. 2) + the
TPU roofline backend.

Two estimation modes, exactly as the paper splits them:

offline  — unit costs are measured/fixed per platform: σ1:σ2:σ3:σSM =
           1:6:200:2 (energy of MAC : cache : DRAM : shared-mem access) and
           the λ latency analogues.  On TPU the "cache" is VMEM reuse and
           ε becomes the fraction of operand bytes served from VMEM.

online   — per-layer C_l (MACs) and M_l (bytes) come from the *current*
           elastic variant's architecture; ε and arithmetic intensity δ are
           observed at runtime (here: derived from the compiled HLO's
           cost_analysis, the dry-run's ground truth).

The same module computes the three roofline terms (compute / memory /
collective) for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.configs import ATTN, LOCAL, MAMBA, InputShape, ModelConfig

# ------------------------------------------------------- hardware profiles --
@dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # per chip
    idle_w: float = 80.0
    peak_w: float = 250.0
    # paper Eq.(1) unit-cost ratios (MAC : cache : DRAM : shared)
    sigma: Tuple[float, float, float, float] = (1.0, 6.0, 200.0, 2.0)
    # Eq.(2) latency unit ratios
    lam: Tuple[float, float, float] = (1.0, 6.0, 200.0)


TPU_V5E = HardwareProfile(
    name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    hbm_bytes=16e9, idle_w=80.0, peak_w=220.0)

MOBILE_CPU = HardwareProfile(
    name="mobile_cpu", peak_flops=12e9, hbm_bw=4e9, ici_bw=12.5e6,
    hbm_bytes=2e9, idle_w=1.0, peak_w=5.0,
    sigma=(1.0, 6.0, 200.0, 0.0), lam=(1.0, 6.0, 200.0))


# ------------------------------------------------- measurement calibration --
@dataclass(frozen=True)
class Calibration:
    """Back-end→front-end feedback: an affine correction mapping the
    analytical Eq.(1)/(2) estimates onto *observed* step measurements.

    Produced by ``repro.fleet.telemetry`` from runtime telemetry and
    installed into the profiler/optimizer (the loop the paper centers on:
    "feeding back runtime performance from the back-end level to the
    front-end level optimization decision")."""
    latency_scale: float = 1.0
    latency_bias_s: float = 0.0
    energy_scale: float = 1.0
    samples: int = 0

    def latency(self, pred_s: float) -> float:
        return max(self.latency_scale * pred_s + self.latency_bias_s, 1e-12)

    def energy(self, pred_j: float) -> float:
        return max(self.energy_scale * pred_j, 0.0)


# ---------------------------------------------------- per-layer cost model --
@dataclass
class LayerCost:
    name: str
    macs: float           # C_l
    bytes: float          # M_l (params + activations touched)


def layer_costs(cfg: ModelConfig, batch: int, seq: int, decode: bool = False,
                dtype_bytes: int = 2, kv_bytes: int = 2) -> List[LayerCost]:
    """C_l and M_l per layer for the current (possibly elastic) config.

    The paper notes the unit set differs per family: transformer units are
    the QKV/O projections + FFN; Mamba units are in/out projections + SSD."""
    t = batch * (1 if decode else seq)
    hd = cfg.resolved_head_dim
    out: List[LayerCost] = []
    for li, kind in enumerate(cfg.block_pattern()):
        if kind == MAMBA:
            di = cfg.ssm_d_inner
            in_dim = 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim \
                + cfg.ssm_num_heads
            macs = t * (cfg.d_model * in_dim + di * cfg.d_model
                        + 6 * cfg.ssm_num_heads * cfg.ssm_head_dim
                        * cfg.ssm_state_dim)
            mbytes = (cfg.d_model * in_dim + di * cfg.d_model) * dtype_bytes \
                + 2 * t * cfg.d_model * dtype_bytes
            out.append(LayerCost(f"l{li}.mamba", macs, mbytes))
            continue
        window = cfg.sliding_window if kind == LOCAL else 0
        ctx = min(seq, window) if window else seq
        attn_ctx = ctx if (window or decode) else seq / 2
        macs = t * (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                    + cfg.q_dim * cfg.d_model
                    + 2 * cfg.num_heads * hd * attn_ctx)
        mbytes = (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                  + cfg.q_dim * cfg.d_model) * dtype_bytes \
            + 2 * t * cfg.d_model * dtype_bytes
        if decode:
            mbytes += batch * seq * 2 * cfg.kv_dim * kv_bytes  # KV read
        out.append(LayerCost(f"l{li}.attn", macs, mbytes))
        if cfg.arch_type == "moe":
            active = cfg.experts_per_token + (1 if cfg.moe_shared_expert else 0)
            mats = 3 if cfg.gated_ffn else 2
            macs = t * (mats * active * cfg.d_model * cfg.d_ff
                        + cfg.d_model * cfg.num_experts)
            # decode touches only routed experts' weights; prefill touches all
            touched = active if decode else cfg.num_experts
            mbytes = mats * touched * cfg.d_model * cfg.d_ff * dtype_bytes
        else:
            mats = 3 if cfg.gated_ffn else 2
            macs = t * mats * cfg.d_model * cfg.d_ff
            mbytes = mats * cfg.d_model * cfg.d_ff * dtype_bytes \
                + 2 * t * cfg.d_ff * dtype_bytes
        out.append(LayerCost(f"l{li}.ffn", macs, mbytes))
    if cfg.is_encoder_decoder:
        # decoder cross-attention (per decoder layer) + the encoder stack
        se = cfg.encoder_seq_len
        for li in range(cfg.num_layers):
            macs = t * (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                        + cfg.q_dim * cfg.d_model
                        + 2 * cfg.num_heads * hd * se)
            mbytes = (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                      + cfg.q_dim * cfg.d_model) * dtype_bytes                 + 2 * t * cfg.d_model * dtype_bytes
            out.append(LayerCost(f"l{li}.cross", macs, mbytes))
        te = batch * se
        mats = 3 if cfg.gated_ffn else 2
        # the encoder runs once per REQUEST, not per decode step
        for li in range(0 if decode else cfg.encoder_layers):
            macs = te * (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                         + cfg.q_dim * cfg.d_model
                         + 2 * cfg.num_heads * hd * se
                         + mats * cfg.d_model * cfg.d_ff)
            mbytes = ((cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                       + cfg.q_dim * cfg.d_model
                       + mats * cfg.d_model * cfg.d_ff) * dtype_bytes
                      + 2 * te * cfg.d_model * dtype_bytes)
            out.append(LayerCost(f"enc{li}", macs, mbytes))
    out.append(LayerCost("lm_head", t * cfg.d_model * cfg.vocab_size,
                         cfg.d_model * cfg.vocab_size * dtype_bytes))
    return out


# --------------------------------------------------------------- Eq 1 & 2 --
def estimate_energy(costs: List[LayerCost], eps: float,
                    hw: HardwareProfile = TPU_V5E) -> float:
    """Paper Eq. (1): E = Σ σ1·C_l + ε·σ2·M_l + (1-ε)·σ3·M_l + σSM·M_l.

    Returned in joules: the σ ratios are anchored so that one MAC at peak
    utilization costs peak_w / peak_flops joules.  Telemetry-learned
    ``Calibration`` corrections are applied one level up, in
    ``ActionEvaluator.evaluate`` — a single application point."""
    s1, s2, s3, ssm = hw.sigma
    unit = hw.peak_w / hw.peak_flops      # J per MAC-equivalent
    e = 0.0
    for lc in costs:
        e += s1 * lc.macs + eps * s2 * lc.bytes + (1 - eps) * s3 * lc.bytes \
            + ssm * lc.bytes
    return e * unit


def estimate_latency(costs: List[LayerCost], eps: float,
                     hw: HardwareProfile = TPU_V5E,
                     effective_flops: Optional[float] = None) -> float:
    """Paper Eq. (2): T = Σ λ1·δ_l·C_l + ε·λ2·M_l + (1-ε)·λ3·M_l.

    δ_l (arithmetic intensity C_l/M_l) modulates how efficiently compute
    hides memory traffic; we realize λ1·δ_l·C_l as compute time at an
    efficiency that saturates with δ (roofline knee)."""
    flops = effective_flops or hw.peak_flops
    lam1, lam2, lam3 = hw.lam
    t = 0.0
    knee = hw.peak_flops / hw.hbm_bw      # FLOPs per byte at the ridge
    for lc in costs:
        delta = lc.macs / max(lc.bytes, 1.0)
        eff = min(1.0, delta / knee)      # below the knee: bandwidth-bound
        t += lam1 * (2 * lc.macs) / (flops * max(eff, 1e-3))
        # memory term: a hit costs λ2/λ3 of the full-miss (DRAM/HBM) time
        mem_t_miss = lc.bytes / hw.hbm_bw
        t += (eps * lam2 / lam3 + (1 - eps)) * mem_t_miss
    return t


def rank_consistency(est: List[float], actual: List[float]) -> float:
    """Spearman rank correlation — the paper's stated profiler goal is
    consistent *ranking*, not absolute accuracy."""
    e = np.argsort(np.argsort(est)).astype(float)
    a = np.argsort(np.argsort(actual)).astype(float)
    if len(e) < 2:
        return 1.0
    n = len(e)
    return float(1 - 6 * np.sum((e - a) ** 2) / (n * (n ** 2 - 1)))


# ------------------------------------------------------------- roofline ----
@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_compute_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, chips: int,
                   model_flops: float = 0.0,
                   hw: HardwareProfile = TPU_V5E) -> RooflineTerms:
    """The three §Roofline terms, in seconds (whole-step, chips aggregate).

    NOTE: hlo_flops / hlo_bytes from XLA cost_analysis are *per-shard
    program* totals; multiply by chips happens at the caller if needed —
    here we treat inputs as whole-job totals and divide by the fleet."""
    return RooflineTerms(
        compute_s=hlo_flops / (chips * hw.peak_flops),
        memory_s=hlo_bytes / (chips * hw.hbm_bw),
        collective_s=collective_bytes / (chips * hw.ici_bw),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes, model_flops=model_flops,
        chips=chips)


_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|u64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}
# result shape(s) appear between '=' and the op name; layouts {2,1,0} and
# tuple shapes are tolerated.  -start/-done async pairs: count -start only.
_COLL_LINE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def _line_collective_bytes(line: str):
    m = _COLL_LINE.search(line)
    if not m or m.group("suffix") == "-done":
        return None
    kind = m.group("kind")
    nbytes = 0.0
    for dt, dims in _SHAPE_RE.findall(m.group("shapes")):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES.get(dt, 2)
    return kind, nbytes


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Parse lowered/compiled HLO text, summing result bytes of every
    collective op.  Returns per-kind byte totals (one shard's program)."""
    totals: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        r = _line_collective_bytes(line.strip())
        if r is None:
            continue
        kind, nbytes = r
        totals[kind] = totals.get(kind, 0.0) + nbytes
    return totals


def analytic_step_costs(cfg: ModelConfig, shape: InputShape,
                        remat: str = "none", kv_bytes: int = 2,
                        decode_window: int = 0) -> Tuple[float, float]:
    """(flops, hbm_bytes) for one whole step, scan-trip-exact.

    XLA's CPU cost_analysis counts while-loop bodies ONCE (verified), so
    the dry-run uses this analytic model for the compute/memory roofline
    terms and the HLO only for the collective schedule.  Training flops =
    fwd(2C) + bwd(4C) + remat recompute; bytes = weight traffic per pass +
    activation/KV traffic from the per-layer model."""
    decode = shape.kind == "decode"
    eff_seq = shape.seq_len
    if decode and decode_window:
        eff_seq = min(shape.seq_len, decode_window)   # windowed KV reads
    costs = layer_costs(cfg, shape.global_batch, eff_seq, decode=decode,
                        kv_bytes=kv_bytes)
    fwd_flops = sum(2.0 * c.macs for c in costs)
    fwd_bytes = sum(c.bytes for c in costs)
    if shape.kind == "train":
        overhead = {"none": 0.0, "dots": 0.18, "full": 0.33}.get(remat, 0.0)
        flops = fwd_flops * 3.0 * (1.0 + overhead)
        nbytes = fwd_bytes * (3.0 + (1.0 if remat != "none" else 0.0))
    else:
        flops = fwd_flops
        nbytes = fwd_bytes
    return flops, nbytes


def collective_bytes_scan_corrected(hlo_text: str, trip_count: int
                                    ) -> Dict[str, float]:
    """Collective bytes with while-body correction.

    XLA's printed HLO lists each while-body computation once; collectives
    inside computations referenced as ``body=%name`` execute ``trip_count``
    times (the layer scan), so their bytes are multiplied accordingly.
    Returns per-kind totals for ONE shard's program."""
    body_names = set(re.findall(r"body=%([\w.\-]+)", hlo_text))
    totals: Dict[str, float] = {}
    cur_name = ""
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = header.match(stripped)
        if m and "{" in line:
            cur_name = m.group(1)
        mult = trip_count if cur_name in body_names else 1
        r = _line_collective_bytes(stripped)
        if r is None:
            continue
        kind, nbytes = r
        totals[kind] = totals.get(kind, 0.0) + nbytes * mult
    return totals


def scan_trip_count(cfg: ModelConfig) -> int:
    """Layer-scan trip count (periods) for while-body cost correction."""
    if cfg.arch_type == "hybrid":
        period = cfg.shared_attn_period or cfg.num_layers
    elif cfg.local_global_ratio:
        period = cfg.local_global_ratio + 1
    else:
        period = 1
    return max(1, cfg.num_layers // period)


def model_flops_estimate(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N_active·D for
    inference, D = tokens processed this step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq
