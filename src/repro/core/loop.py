"""The automated cross-level co-adaptation loop (paper §III-D, Fig. 6).

monitor → profiler → (violation | drift | context change?) → optimizer →
apply (θ_p variant switch, θ_o re-placement, θ_s engine reconfig) — at a
fixed tick frequency.  On-device (local mesh) execution is preferred;
offloading engages only when local resources cannot meet the budgets,
mirroring the paper's policy.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.elastic.operators import FULL_SPEC, VariantSpec
from repro.elastic.supernet import ElasticSupernet
from repro.models.configs import InputShape, ModelConfig

from repro.engine.schedule import EngineConfig

from .actions import Action, OffloadChoice, default_action_space
from .monitor import ResourceContext, ResourceMonitor
from .optimizer import (ActionEvaluator, Budgets, Evaluation, evolve_pareto,
                        nondominated_front, select_online)
from .profiler import Calibration, HardwareProfile, TPU_V5E


@dataclass
class Decision:
    tick: int
    ctx: ResourceContext
    action: Action
    eval: Evaluation
    reason: str


@dataclass
class AdaptationLoop:
    cfg: ModelConfig
    shape: InputShape
    supernet: Optional[ElasticSupernet] = None
    hw: HardwareProfile = TPU_V5E
    budgets: Budgets = field(default_factory=Budgets)
    measured_accuracy: Dict[VariantSpec, float] = field(default_factory=dict)
    allow_offload: bool = True
    hysteresis: float = 0.05        # don't switch for <5% predicted gain
    # observability hooks: the fleet controller installs its recorder and
    # the owning device's id, so each decision lands as a loop.decide
    # trace instant on that device's track
    recorder: object = None
    obs_pid: str = "loop"

    def __post_init__(self):
        if self.recorder is None:
            from repro.obs import NULL_RECORDER
            self.recorder = NULL_RECORDER
        self.monitor = ResourceMonitor()
        self.evaluator = ActionEvaluator(self.cfg, self.shape, self.hw,
                                         measured=self.measured_accuracy)
        variants = (self.supernet.action_space() if self.supernet
                    else (FULL_SPEC,
                          VariantSpec(depth_ratio=0.75),
                          VariantSpec(width_ratio=0.5),
                          VariantSpec(rank_ratio=0.5, width_ratio=0.5)))
        self._variants = tuple(variants)
        self.actions = default_action_space(
            variants, allow_offload=self.allow_offload,
            decode=self.shape.is_decode)
        self._base_actions = self.actions
        self.front: List[Evaluation] = []
        self.current: Optional[Decision] = None
        self.decisions: List[Decision] = []
        self._tick = 0
        # SLO burn-rate pressure (0.0 = healthy).  Set by the fleet
        # controller while an SLO is burning; tick() then short-circuits
        # to the cheapest variant instead of the accuracy-first policy.
        self._pressure = 0.0

    # ----------------------------------------------------- slo pressure --
    def set_pressure(self, p: float) -> None:
        """Install (or clear, with 0.0) SLO burn-rate pressure.  The
        healthy path is untouched while pressure is zero — SLO-healthy
        runs stay bit-identical to pressure-free ones."""
        self._pressure = float(p)

    @property
    def pressure(self) -> float:
        return self._pressure

    # --------------------------------------------------- placement targets --
    def set_offload_targets(self, choices: Sequence[OffloadChoice]) -> None:
        """Install fleet-peer offload targets into the action space.

        Each choice (typically one ``OffloadChoice`` with ``peers`` set,
        produced by the fleet placer) is crossed with the loop's variant
        ladder and appended to the static action space; previous fleet
        targets are replaced and the Pareto front invalidated.  An empty
        sequence strips fleet targets (back to static pools only)."""
        extra = tuple(Action(variant=v, offload=ch,
                             engine=EngineConfig(fuse=True))
                      for ch in choices for v in self._variants)
        self.actions = self._base_actions + extra
        self.front = []

    def abandon_current(self) -> None:
        """Forget the held decision.  Failure-path only: hysteresis
        re-evaluates the incumbent action each tick, so a decision whose
        offload chain just died would otherwise survive as "hold" even
        after its fleet targets were stripped from the action space."""
        self.current = None

    # ------------------------------------------------------- calibration --
    def set_calibration(self, cal: Optional[Calibration]) -> None:
        """Install a telemetry-derived correction into the evaluator and
        invalidate the Pareto front (its stored latencies/energies were
        computed under the previous correction)."""
        self.evaluator.calibration = cal
        self.front = []

    # ---------------------------------------------------------- offline ---
    def build_pareto(self, ctx: Optional[ResourceContext] = None,
                     evolve: bool = True) -> List[Evaluation]:
        ctx = ctx or ResourceContext()
        evals = [self.evaluator.evaluate(a, ctx) for a in self.actions]
        self.front = nondominated_front(evals)
        if evolve:
            # evolutionary refinement around the seed front
            refined = evolve_pareto(self.evaluator,
                                    [e.action for e in self.front] or
                                    list(self.actions)[:8], ctx)
            self.front = nondominated_front(list(self.front) + list(refined))
        return self.front

    # ----------------------------------------------------------- online ---
    def tick(self, ctx: ResourceContext) -> Decision:
        """One adaptation-loop iteration."""
        self.monitor.set(ctx)
        self._tick += 1
        budgets = Budgets(
            latency_s=self.budgets.latency_s,
            memory_bytes=min(self.budgets.memory_bytes,
                             ctx.mem_budget_bytes(
                                 self.hw.hbm_bytes * ctx.chips_available)))
        if not self.front:
            self.build_pareto(ctx, evolve=False)

        if self._pressure > 0.0:
            # SLO burn feedback: while the error budget is burning, the
            # objective flips from accuracy-first to latency-first —
            # take the *cheapest* variant on the front (local preferred)
            # and skip hysteresis, which would otherwise defend the
            # expensive incumbent against a <5%-gain downshift.
            pool = ([e for e in self.front if not e.action.offload.enabled]
                    or list(self.front))
            cheap = min(pool, key=lambda e: (e.latency_s, e.energy_j))
            choice = self.evaluator.evaluate(cheap.action, ctx)
            d = Decision(tick=self._tick, ctx=ctx, action=choice.action,
                         eval=choice, reason="slo_pressure")
            if self.recorder.enabled:
                self.recorder.instant(
                    "loop.decide", pid=self.obs_pid, tid="loop",
                    cat="fleet",
                    args={"tick": self._tick, "reason": "slo_pressure",
                          "pressure": self._pressure,
                          "variant": str(choice.action.variant),
                          "offloaded": choice.action.offload.enabled,
                          "latency_s": choice.latency_s,
                          "accuracy": choice.accuracy})
            self.current = d
            self.decisions.append(d)
            return d

        # prefer local: filter offloaded actions unless local infeasible
        local = [e for e in self.front if not e.action.offload.enabled]
        choice = select_online(local, ctx, budgets)
        reason = "local"
        if choice is None or choice.latency_s > budgets.latency_s \
                or choice.memory_bytes > budgets.memory_bytes:
            full = select_online(self.front, ctx, budgets)
            if full is not None:
                choice, reason = full, "offloaded (local infeasible)"
        if choice is None:
            raise RuntimeError("no action available")
        # re-evaluate under the live context (DVFS derate etc.)
        choice = self.evaluator.evaluate(choice.action, ctx)

        if self.current is not None:
            cur = self.evaluator.evaluate(self.current.action, ctx)
            cur_feasible = (cur.latency_s <= budgets.latency_s
                            and cur.memory_bytes <= budgets.memory_bytes)
            gain = (choice.accuracy - cur.accuracy) \
                + (cur.energy_j - choice.energy_j) / max(cur.energy_j, 1e-9)
            if cur_feasible and gain < self.hysteresis:
                choice, reason = cur, "hold (hysteresis)"
        d = Decision(tick=self._tick, ctx=ctx, action=choice.action,
                     eval=choice, reason=reason)
        if self.recorder.enabled:
            self.recorder.instant(
                "loop.decide", pid=self.obs_pid, tid="loop", cat="fleet",
                args={"tick": self._tick, "reason": reason,
                      "variant": str(choice.action.variant),
                      "offloaded": choice.action.offload.enabled,
                      "latency_s": choice.latency_s,
                      "accuracy": choice.accuracy})
        self.current = d
        self.decisions.append(d)
        return d

    def run_trace(self, trace) -> List[Decision]:
        return [self.tick(ctx) for ctx in trace]

    def materialize(self):
        """Return (variant_cfg, variant_params, runtime_options) for the
        currently selected action (requires a supernet)."""
        if self.current is None or self.supernet is None:
            raise RuntimeError("no decision or no supernet attached")
        a = self.current.action
        vcfg, vparams = self.supernet.variant(a.variant)
        return vcfg, vparams, a.engine.to_runtime_options()
