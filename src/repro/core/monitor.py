"""Resource availability monitor (paper §III-D, first loop component).

Tracks compute/memory availability within and across devices.  On mobile
the signals are battery, DVFS state, competing processes and cache
contention; the TPU-pod analogues are power caps, free HBM fraction,
available chips (preemptions / co-tenancy) and ICI contention.  A
``ContextTrace`` drives benchmarks and the real-world case-study
reproduction (paper Fig. 13) with battery/memory curves over time.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class ResourceContext:
    """A snapshot of runtime resource availability."""
    time_s: float = 0.0
    battery_frac: float = 1.0        # mobile battery  <-> pod power headroom
    mem_free_frac: float = 1.0       # free HBM fraction
    chips_available: int = 256
    ici_contention: float = 0.0      # 0..1 fraction of link bw lost
    cpu_temp_derate: float = 1.0     # DVFS clock derate (1 = full speed)
    competing_procs: int = 0
    data_drift: float = 0.0          # distribution-shift magnitude (0..1)
    request_rate: float = 1.0        # relative inference request pressure

    def mem_budget_bytes(self, hbm_bytes: float) -> float:
        return self.mem_free_frac * hbm_bytes

    def effective_flops(self, peak: float) -> float:
        derate = self.cpu_temp_derate / (1.0 + 0.15 * self.competing_procs)
        return peak * derate

    def effective_link_bw(self, peak: float) -> float:
        return peak * (1.0 - self.ici_contention)


class ResourceMonitor:
    """Polls a context source (synthetic trace or live callbacks).

    ``recorder``/``obs_pid`` are the observability hooks: when a
    :class:`~repro.obs.recorder.TraceRecorder` is installed (the fleet
    controller wires its own into every member's monitor), each context
    update lands as a ``monitor.context`` trace instant."""

    def __init__(self, source: Optional[Iterator[ResourceContext]] = None):
        self._source = source
        self._history: List[ResourceContext] = []
        self.current = ResourceContext()
        from repro.obs import NULL_RECORDER
        self.recorder = NULL_RECORDER
        self.obs_pid = "monitor"

    def tick(self) -> ResourceContext:
        if self._source is not None:
            try:
                self.current = next(self._source)
            except StopIteration:
                pass
        self._history.append(self.current)
        return self.current

    def history(self) -> List[ResourceContext]:
        return list(self._history)

    def set(self, ctx: ResourceContext) -> None:
        if self.recorder.enabled:
            self.recorder.instant(
                "monitor.context", pid=self.obs_pid, tid="monitor",
                cat="fleet",
                args={"battery_frac": ctx.battery_frac,
                      "mem_free_frac": ctx.mem_free_frac,
                      "cpu_temp_derate": ctx.cpu_temp_derate,
                      "competing_procs": ctx.competing_procs,
                      "data_drift": ctx.data_drift})
        self.current = ctx


# -------------------------------------------------------------- traces -----
def constant_trace(ctx: ResourceContext, n: int) -> Iterator[ResourceContext]:
    for i in range(n):
        yield dataclasses.replace(ctx, time_s=float(i))


def case_study_trace(n: int = 24, seed: int = 0) -> Iterator[ResourceContext]:
    """The paper's Fig. 13 scenario: a day of operation — battery drains
    90%→21%, memory availability dips mid-run (e2: 85%→28%), lighting/scene
    drift rises in the evening."""
    import random
    rng = random.Random(seed)
    for i in range(n):
        t = i / max(n - 1, 1)
        battery = 0.90 - 0.69 * t
        if 0.35 < t < 0.6:
            mem = 0.28 + 0.06 * rng.random()          # e2: memory pressure
        else:
            mem = 0.85 - 0.1 * t + 0.05 * rng.random()
        drift = 0.1 + (0.5 * max(0.0, t - 0.7) / 0.3)  # evening lighting
        yield ResourceContext(
            time_s=i * 3600.0 / n, battery_frac=battery,
            mem_free_frac=mem,
            chips_available=256,
            ici_contention=0.1 * rng.random(),
            cpu_temp_derate=1.0 - 0.2 * max(0.0, t - 0.5),
            competing_procs=rng.randint(0, 3),
            data_drift=min(drift, 1.0),
            request_rate=0.5 + 0.8 * math.sin(math.pi * t) ** 2)


def budget_sweep_trace(levels=(1.0, 0.75, 0.5, 0.25)) -> Iterator[ResourceContext]:
    """Paper Table II: stepped memory-budget restriction."""
    for i, m in enumerate(levels):
        yield ResourceContext(time_s=float(i), mem_free_frac=m)


def dvfs_spike_trace(n: int = 10) -> Iterator[ResourceContext]:
    """Thermal throttling event mid-run (paper's DVFS discussion)."""
    for i in range(n):
        derate = 0.55 if n // 3 <= i < 2 * n // 3 else 1.0
        yield ResourceContext(time_s=float(i), cpu_temp_derate=derate,
                              competing_procs=2 if derate < 1 else 0)


# ------------------------------------------- per-device trace plumbing -----
def shape_context(ctx: ResourceContext, *, battery_scale: float = 1.0,
                  mem_scale: float = 1.0, derate_floor: float = 0.0,
                  chips: Optional[int] = None,
                  extra_procs: int = 0) -> ResourceContext:
    """Project a fleet-wide context onto one device's resource envelope.

    A shared scenario (the case-study day) hits every device, but each
    device has its own battery capacity, memory headroom and DVFS floor —
    the same evening drains a small phone's battery faster than a plugged
    edge server's."""
    return dataclasses.replace(
        ctx,
        battery_frac=min(1.0, max(0.0, ctx.battery_frac * battery_scale)),
        mem_free_frac=min(1.0, max(0.02, ctx.mem_free_frac * mem_scale)),
        cpu_temp_derate=max(derate_floor, ctx.cpu_temp_derate),
        chips_available=(chips if chips is not None else ctx.chips_available),
        competing_procs=ctx.competing_procs + extra_procs)


def shaped_trace(base: Iterator[ResourceContext], **envelope
                 ) -> Iterator[ResourceContext]:
    """Map ``shape_context`` over a base trace — the monitor-level hook the
    fleet registry uses to derive per-device traces from one scenario."""
    for ctx in base:
        yield shape_context(ctx, **envelope)
