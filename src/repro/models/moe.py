"""Mixture-of-Experts FFN with token-choice routing and capacity dispatch.

Dispatch strategy (TPU adaptation of the paper's "offloading" all-to-all):
per-expert top-C token *gather* + batched expert matmul + scatter-combine.
Expert weights are stacked (E, D, F) and sharded over the "model" mesh axis
(expert parallelism); the gather/scatter pair is what SPMD lowers to the
all-to-all exchange.  Compute cost is k·T·FFN (capacity factor 1.0), not
E·T·FFN — tokens beyond capacity are dropped Switch-style.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .layers import Params, ffn_apply, ffn_init


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    if cfg.moe_shared_expert:
        p["shared"] = ffn_init(ks[4], d, f, gated=cfg.gated_ffn, dtype=dtype)
    return p


def _capacity(num_tokens: int, cfg: ModelConfig, cap_factor: float) -> int:
    c = int(cap_factor * cfg.experts_per_token * num_tokens / cfg.num_experts)
    c = max(8, int(np.ceil(c / 8) * 8))
    return min(c, num_tokens)


def moe_apply(params: Params, x: jax.Array, cfg: ModelConfig, *,
              capacity_factor: float = 1.0) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Routing: softmax router -> per-token top-k gates -> per-expert top-C
    token selection (ties broken by router prob).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = _capacity(t, cfg, capacity_factor)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)                      # (T, k)
    # renormalize the selected gates
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    # gate matrix restricted to the chosen experts: (T, E)
    gates = jax.nn.one_hot(topk_i, e, dtype=jnp.float32) * topk_p[..., None]
    gates = jnp.sum(gates, axis=1)                                # (T, E)

    # per-expert capacity-C token selection
    scores = jnp.where(gates > 0, gates, -1.0).T                  # (E, T)
    sel_score, sel_idx = jax.lax.top_k(scores, cap)               # (E, C)
    valid = sel_score > 0                                         # dropped slots

    xg = jnp.take(xf, sel_idx.reshape(-1), axis=0)                # (E*C, D)
    xg = xg.reshape(e, cap, d) * valid[..., None].astype(x.dtype)

    h_gate = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = act(h_gate) * h_up if cfg.gated_ffn else act(h_up)
    yg = jnp.einsum("ecf,efd->ecd", h, params["w_down"])          # (E, C, D)

    gate_sel = jnp.take_along_axis(gates.T, sel_idx, axis=1)      # (E, C)
    yg = yg * (gate_sel * valid).astype(yg.dtype)[..., None]
    y = jnp.zeros((t, d), yg.dtype).at[sel_idx.reshape(-1)].add(
        yg.reshape(-1, d))

    if cfg.moe_shared_expert:
        y = y + ffn_apply(params["shared"], xf, gated=cfg.gated_ffn,
                          activation=cfg.activation)

    # Switch-style load-balance auxiliary loss
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean((gates > 0).astype(jnp.float32), axis=0)         # (E,)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_decode(params: Params, x: jax.Array, cfg: ModelConfig
                     ) -> jax.Array:
    """Decode path: DENSE dispatch.

    The decode batch is tiny (T = global_batch tokens), so every expert
    computes all tokens and a top-k one-hot gate combines the results.
    This is k/E more FLOPs — negligible at decode utilization — but
    expert weights NEVER move: under expert parallelism each shard runs
    its resident experts and the combine is one small (T, D) all-reduce.
    (§Perf: replaced a per-token expert-weight gather that moved
    k·3·D·F·T bytes across the mesh per layer.)"""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    gates = jnp.sum(jax.nn.one_hot(topk_i, e, dtype=jnp.float32)
                    * topk_p[..., None], axis=1)                   # (T, E)

    hg = jnp.einsum("td,edf->tef", x, params["w_gate"])
    hu = jnp.einsum("td,edf->tef", x, params["w_up"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = act(hg) * hu if cfg.gated_ffn else act(hu)
    y = jnp.einsum("tef,efd,te->td", h, params["w_down"],
                   gates.astype(h.dtype))
    if cfg.moe_shared_expert:
        y = y + ffn_apply(params["shared"], x, gated=cfg.gated_ffn,
                          activation=cfg.activation)
    return y.astype(x.dtype)
