"""Unified decoder stacks: dense / MoE / SSM / hybrid / enc-dec / VLM.

Layer stacks are ``lax.scan``-ed over *stacked* per-layer parameters so that
compile time and HLO size are O(1) in depth.  Heterogeneous repeating layer
patterns (gemma3's 5 local : 1 global, zamba2's mamba-blocks + shared
attention) are handled by scanning over pattern *periods* and unrolling the
(static) period internally.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .configs import ATTN, LOCAL, MAMBA, SHARED_ATTN, ModelConfig
from .layers import (Params, dtype_of, embed_init, embed_lookup, ffn_apply,
                     ffn_init, mask_padded_logits_raw, rms_norm, unembed)
from .runtime import DEFAULT_OPTIONS, RuntimeOptions


# ----------------------------------------------------------------- init ----
def _attn_layer_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, hd, dtype, cfg.qkv_bias),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.arch_type == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff,
                            gated=cfg.gated_ffn, dtype=dtype)
    if cross:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = attn_mod.attn_init(ks[2], cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, hd, dtype, False)
    return p


def _mamba_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln": jnp.zeros((cfg.d_model,), dtype),
        "mamba": ssm_mod.mamba_init(key, cfg, dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    layer_keys = jax.random.split(keys[1], max(cfg.num_layers, 1))
    if cfg.arch_type in ("ssm", "hybrid"):
        params["layers"] = jax.vmap(
            lambda k: _mamba_layer_init(k, cfg, dtype))(layer_keys)
        if cfg.arch_type == "hybrid":
            params["shared_attn"] = _attn_layer_init(keys[2], cfg, dtype)
    else:
        cross = cfg.is_encoder_decoder
        params["layers"] = jax.vmap(
            lambda k: _attn_layer_init(k, cfg, dtype, cross=cross))(layer_keys)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _attn_layer_init(k, cfg, dtype))(enc_keys)
        params["encoder_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.vision_embed_dim:
        params["vision_proj"] = {
            "w": (jax.random.normal(keys[4], (cfg.vision_embed_dim, cfg.d_model))
                  / jnp.sqrt(cfg.vision_embed_dim)).astype(dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ----------------------------------------------------------- block apply ---
def _select_impl(cfg: ModelConfig, opts: RuntimeOptions, s: int, window: int
                 ) -> str:
    impl = opts.attn_impl
    if impl != "auto":
        return impl
    if window and s > 2 * window and s % min(opts.q_chunk, s) == 0:
        return "banded"
    if s > 1024 and s % min(opts.q_chunk, s) == 0 and s % min(opts.k_chunk, s) == 0:
        return "chunked"
    return "full"


def attn_block(layer: Params, x: jax.Array, cfg: ModelConfig,
               opts: RuntimeOptions, *, window: int, causal: bool = True
               ) -> jax.Array:
    s = x.shape[1]
    h = attn_mod.attention_block(
        layer["attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        causal=causal, window=window,
        impl=_select_impl(cfg, opts, s, window),
        q_chunk=opts.q_chunk, k_chunk=opts.k_chunk)
    return x + h.astype(x.dtype)


def ffn_or_moe_block(layer: Params, x: jax.Array, cfg: ModelConfig,
                     opts: RuntimeOptions) -> Tuple[jax.Array, jax.Array]:
    h = rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y, aux = moe_mod.moe_apply(layer["moe"], h, cfg,
                                   capacity_factor=opts.moe_capacity_factor)
    else:
        y = ffn_apply(layer["ffn"], h, gated=cfg.gated_ffn,
                      activation=cfg.activation,
                      hidden_shard_axis=opts.ffn_shard_axis)
        aux = jnp.zeros((), jnp.float32)
    return x + y.astype(x.dtype), aux


def transformer_block(layer: Params, x: jax.Array, cfg: ModelConfig,
                      opts: RuntimeOptions, *, window: int,
                      causal: bool = True,
                      cross_src: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    x = attn_block(layer, x, cfg, opts, window=window, causal=causal)
    if cross_src is not None and "cross" in layer:
        q = rms_norm(x, layer["ln_cross"], cfg.norm_eps)
        b, s, _ = q.shape
        hd = cfg.resolved_head_dim
        se = cross_src.shape[1]
        qh = (q @ layer["cross"]["wq"]).reshape(b, s, cfg.num_heads, hd)
        kh = (cross_src @ layer["cross"]["wk"]).reshape(b, se, cfg.num_kv_heads, hd)
        vh = (cross_src @ layer["cross"]["wv"]).reshape(b, se, cfg.num_kv_heads, hd)
        out = attn_mod.full_attention(qh, kh, vh, causal=False)
        x = x + (out.reshape(b, s, cfg.num_heads * hd)
                 @ layer["cross"]["wo"]).astype(x.dtype)
    return ffn_or_moe_block(layer, x, cfg, opts)


def mamba_block(layer: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    return x + ssm_mod.mamba_forward(
        layer["mamba"], rms_norm(x, layer["ln"], cfg.norm_eps), cfg).astype(x.dtype)


# -------------------------------------------------------------- the stack --
def _pattern_period(cfg: ModelConfig) -> Tuple[Tuple[str, ...], bool]:
    """Return (kinds of one period over *stacked* layers, shared_attn_after)."""
    if cfg.arch_type == "ssm":
        return (MAMBA,), False
    if cfg.arch_type == "hybrid":
        p = cfg.shared_attn_period or cfg.num_layers
        return tuple([MAMBA] * p), True
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        return tuple([LOCAL] * r + [ATTN]), False
    return (ATTN,), False


def _maybe_seq_shard(x: jax.Array, opts: RuntimeOptions) -> jax.Array:
    """§Perf lever: constrain the residual stream to sequence-parallel
    sharding at block boundaries (Megatron-SP on the TPU mesh)."""
    if opts.seq_shard_axis and x.ndim == 3:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(None, opts.seq_shard_axis, None))
    return x


def _remat_wrap(fn, opts: RuntimeOptions):
    if opts.remat == "none":
        return fn
    if opts.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def apply_stack(stack: Params, x: jax.Array, cfg: ModelConfig,
                opts: RuntimeOptions, *,
                shared: Optional[Params] = None,
                causal: bool = True,
                cross_src: Optional[jax.Array] = None,
                num_layers: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Run a stacked layer pytree over x.  Returns (x, aux_loss_sum).

    ``num_layers`` (static) < full depth realizes the elastic depth-scaling
    operator η5: only the first n layers' stacked weights are used.
    """
    kinds, shared_after = _pattern_period(cfg)
    period = len(kinds)
    total = jax.tree_util.tree_leaves(stack)[0].shape[0]
    n = total if num_layers is None else min(num_layers, total)
    n_full = (n // period) * period
    aux0 = jnp.zeros((), jnp.float32)

    def one_layer(kind: str, layer: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        if kind == MAMBA:
            return mamba_block(layer, x, cfg), aux0
        window = cfg.sliding_window if kind == LOCAL else 0
        return transformer_block(layer, x, cfg, opts, window=window,
                                 causal=causal, cross_src=cross_src)

    def period_body(x, period_params):
        aux = aux0
        x = _maybe_seq_shard(x, opts)
        for j, kind in enumerate(kinds):
            layer = jax.tree_util.tree_map(lambda a: a[j], period_params)
            x, a = one_layer(kind, layer, x)
            aux = aux + a
        if shared_after and shared is not None:
            x, a = transformer_block(shared, x, cfg, opts, window=0,
                                     causal=causal)
            aux = aux + a
        return x, aux

    period_body = _remat_wrap(period_body, opts)

    aux_total = aux0
    if n_full:
        grouped = jax.tree_util.tree_map(
            lambda a: a[:n_full].reshape(n_full // period, period, *a.shape[1:]),
            stack)
        if opts.scan_layers and n_full // period > 1:
            def scan_body(carry, period_params):
                x, aux = carry
                x, a = period_body(x, period_params)
                return (x, aux + a), None
            (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total), grouped)
        else:
            for i in range(n_full // period):
                pp = jax.tree_util.tree_map(lambda a: a[i], grouped)
                x, a = period_body(x, pp)
                aux_total = aux_total + a
    # leftover layers (pattern remainder, e.g. zamba2's 38 % 6 == 2)
    for j in range(n_full, n):
        layer = jax.tree_util.tree_map(lambda a: a[j], stack)
        x, a = one_layer(kinds[(j - n_full) % period], layer, x)
        aux_total = aux_total + a
    return x, aux_total


# ------------------------------------------------------------- forward -----
def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            opts: RuntimeOptions = DEFAULT_OPTIONS, *,
            encoder_frames: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None,
            num_layers: Optional[int] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / prefill).  Returns (logits, aux_loss).

    tokens: (B, S) int32.
    encoder_frames: (B, S_enc, D) stub audio embeddings (enc-dec archs).
    vision_embeds: (B, n_vis, vision_embed_dim) stub patch embeddings (VLM).
    """
    from .layers import cast_params
    act_dt = dtype_of(cfg.activation_dtype)
    params = cast_params(params, act_dt)
    x = embed_lookup(params["embed"], tokens).astype(act_dt)

    if cfg.vision_embed_dim and vision_embeds is not None:
        v = (vision_embeds.astype(act_dt) @ params["vision_proj"]["w"]
             + params["vision_proj"]["b"]).astype(act_dt)
        # vision embeddings occupy the first n_vis positions; the token ids
        # at those positions are placeholders (paper: modality frontend stub)
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)

    cross_src = None
    if cfg.is_encoder_decoder and encoder_frames is not None:
        enc = encoder_frames.astype(act_dt)
        enc, _ = apply_stack(params["encoder"], enc, cfg,
                             opts.replace(attn_impl="full"), causal=False)
        cross_src = rms_norm(enc, params["encoder_norm"], cfg.norm_eps)

    x, aux = apply_stack(params["layers"], x, cfg, opts,
                         shared=params.get("shared_attn"),
                         cross_src=cross_src, num_layers=num_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    if "logit_bias" in params:
        # TTA prior recalibration (paper §III-A2): a label-free-adaptable
        # output bias absorbing live unigram drift
        logits = logits + params["logit_bias"].astype(logits.dtype)
    logits = mask_padded_logits(logits, cfg)
    return logits, aux


def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross entropy.  logits: (B,S,V); labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def mask_padded_logits(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab rows beyond cfg.vocab_size are sharding padding — mask them."""
    return mask_padded_logits_raw(logits, cfg.vocab_size)
