"""Attention: GQA/MQA, chunked-flash prefill, banded sliding-window, decode.

Three execution paths, selectable by the engine (paper §III-C: the backend
engine picks the operator implementation that fits the resource context):

* ``full_attention``        — reference O(S^2) einsum path (small seq / tests)
* ``chunked_attention``     — flash-style online-softmax over KV chunks
                              (bounded memory; the 32k-prefill default)
* ``banded_attention``      — sliding-window with *static* KV slices, cost
                              O(S * (W + cq)) — the sub-quadratic variant the
                              long_500k configs select
* ``decode_attention``      — one token vs. a KV cache (full or windowed)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, apply_rotary, matmul_w, rotary_embedding

NEG_INF = -1e30


def attn_init(key: jax.Array, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype, qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d_model)
    so = 1.0 / np.sqrt(num_heads * head_dim)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, num_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, num_kv_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, num_kv_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (num_heads * head_dim, d_model)) * so).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def qkv_project(params: Params, x: jax.Array, num_heads: int,
                num_kv_heads: int, head_dim: int):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    b, s, _ = x.shape
    q = matmul_w(x, params["wq"])
    k = matmul_w(x, params["wk"])
    v = matmul_w(x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(b, s, num_heads, head_dim),
            k.reshape(b, s, num_kv_heads, head_dim),
            v.reshape(b, s, num_kv_heads, head_dim))


def _group(q: jax.Array, num_kv_heads: int) -> jax.Array:
    """(B,S,H,hd) -> (B,S,K,G,hd) for GQA einsums."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv_heads, h // num_kv_heads, hd)


# ------------------------------------------------------------- full (oracle)
def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_offset: int | jax.Array = 0) -> jax.Array:
    """Reference attention.  q: (B,Sq,H,hd); k,v: (B,Sk,K,hd)."""
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    qg = _group(q, kheads)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    rows = jnp.arange(sq) + q_offset
    cols = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= rows[:, None] >= cols[None, :]
    if window:
        mask &= cols[None, :] > rows[:, None] - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ------------------------------------------------------- chunked flash-style
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = 512, k_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention with bounded memory.

    Scans over query chunks (outer) and KV chunks (inner), keeping running
    max / denominator, so the (Sq x Sk) score matrix is never materialized.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kheads = k.shape[2]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    assert sq % q_chunk == 0 and sk % k_chunk == 0, (sq, q_chunk, sk, k_chunk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / np.sqrt(hd)

    qr = _group(q, kheads).reshape(b, nq, q_chunk, kheads, h // kheads, hd)
    qr = jnp.moveaxis(qr, 1, 0)                        # (nq, b, cq, K, G, hd)
    kr = jnp.moveaxis(k.reshape(b, nk, k_chunk, kheads, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, k_chunk, kheads, hd), 1, 0)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        row = iq * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, kj_idx):
            m, l, acc = carry
            kj, vj, jk = kj_idx
            col = jk * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= row[:, None] >= col[None, :]
            if window:
                mask &= col[None, :] > row[:, None] - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kheads, h // kheads, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kheads, h // kheads, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kheads, h // kheads, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)                  # (b, cq, K, G, hd)
        return None, out.reshape(b, q_chunk, h, hd)

    _, chunks = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# ------------------------------------------------- banded (sub-quadratic) ---
def banded_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, q_chunk: int = 512) -> jax.Array:
    """Sliding-window causal attention with static KV slices.

    Each query chunk [r0, r0+cq) attends to a *static-width* KV slice of
    ``window + cq`` columns ending at its last row — total cost
    O(S * (window + cq)) instead of O(S^2).
    """
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    q_chunk = min(q_chunk, sq)
    assert sq % q_chunk == 0
    nq = sq // q_chunk
    span = window + q_chunk
    scale = 1.0 / np.sqrt(hd)

    # pad K/V at the front so every slice is in range
    kp = jnp.pad(k, ((0, 0), (span, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (span, 0), (0, 0), (0, 0)))
    qr = jnp.moveaxis(_group(q, kheads).reshape(
        b, nq, q_chunk, kheads, h // kheads, hd), 1, 0)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        r0 = iq * q_chunk
        row = r0 + jnp.arange(q_chunk)
        # padded col range [r0 + q_chunk - span, r0 + q_chunk) maps to
        # absolute cols [r0 + q_chunk - span - span_pad ...]; slice start in
        # padded coords = r0 + q_chunk (end) - span + span(pad) = r0 + q_chunk
        start = r0 + q_chunk - span + span
        kj = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        col = start - span + jnp.arange(span)          # absolute column ids
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        mask = (col[None, :] >= 0) & (row[:, None] >= col[None, :]) \
            & (col[None, :] > row[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", p, vj.astype(jnp.float32))
        return None, out.reshape(b, q_chunk, h, hd)

    _, chunks = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    return jnp.moveaxis(chunks, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


# -------------------------------------------------------------------- decode
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token attention against a cache.

    q: (B, H, hd); caches: (B, S, K, hd); pos: scalar int32 (current index,
    cache already contains the new token at ``pos``).

    ``window > 0`` slices a static-width window ending at ``pos`` — per-token
    cost independent of cache length (the long_500k sub-quadratic path).
    """
    b, h, hd = q.shape
    kheads = k_cache.shape[2]
    s_len = k_cache.shape[1]
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, kheads, h // kheads, hd)

    if window and window < s_len:
        start = jnp.clip(pos + 1 - window, 0, s_len - window)
        kj = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        col = start + jnp.arange(window)
    else:
        kj, vj = k_cache, v_cache
        col = jnp.arange(s_len)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   kj.astype(jnp.float32)) * scale
    mask = col <= pos
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vj.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def update_kv_cache(k_cache: jax.Array, v_cache: jax.Array, k_new: jax.Array,
                    v_new: jax.Array, pos: jax.Array):
    """Insert one token.  k_new/v_new: (B, K, hd); pos scalar."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new[:, None].astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new[:, None].astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


def attention_block(params: Params, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, rope_theta: float,
                    causal: bool = True, window: int = 0,
                    impl: str = "chunked", q_chunk: int = 512,
                    k_chunk: int = 1024, positions: Optional[jax.Array] = None
                    ) -> jax.Array:
    """Self-attention over a full sequence (train / prefill path)."""
    b, s, _ = x.shape
    q, k, v = qkv_project(params, x, num_heads, num_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    sin, cos = rotary_embedding(positions, head_dim, rope_theta)
    q = apply_rotary(q, sin, cos)
    k = apply_rotary(k, sin, cos)
    if impl == "banded" and window:
        out = banded_attention(q, k, v, window=window, q_chunk=min(q_chunk, s))
    elif impl == "chunked" and s > q_chunk:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                q_chunk=q_chunk, k_chunk=min(k_chunk, s))
    else:
        out = full_attention(q, k, v, causal=causal, window=window)
    return matmul_w(out.reshape(b, s, num_heads * head_dim), params["wo"])
