"""Runtime (engine-selected) execution options.

This is the θ_s action surface of the paper's back-end engine (§III-C) as it
exists on TPU: attention implementation / chunking, rematerialization policy,
KV-cache numerics, decode windowing and MoE capacity.  The middleware
optimizer mutates these; the model code only *reads* them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class RuntimeOptions:
    attn_impl: str = "auto"        # auto | full | chunked | banded
    q_chunk: int = 512
    k_chunk: int = 1024
    decode_window: int = 0         # 0 = attend to the full KV cache
    remat: str = "none"            # none | dots | full
    use_pallas: bool = False       # TPU hot-path kernels (interpret on CPU)
    kv_cache_dtype: str = "bfloat16"
    moe_capacity_factor: float = 1.0
    logit_chunk: int = 0           # chunk the LM loss over sequence (0 = off)
    scan_layers: bool = True
    # §Perf: sequence-parallel activation sharding between blocks — the
    # residual stream is constrained to (batch, seq->axis, none) so TP
    # partial-sum all-reduces become reduce-scatter (+ per-block gather)
    seq_shard_axis: str = ""
    # §Perf: constrain FFN hidden activations to (batch, seq, f->axis) so
    # the up/gate matmul outputs stay sharded on d_ff (matching the weight
    # sharding) and only the (B,S,D)-sized w_down output is reduced
    ffn_shard_axis: str = ""
    # Paged-pool storage dtype: "auto" follows kv_cache_dtype; "int8" stores
    # KV blocks as int8 with per-row f32 scales (~4x resident slots per
    # device).  Only read by decode_mode="paged"; dense caches keep
    # kv_cache_dtype.
    kv_dtype: str = "auto"
    # Paged decode reads KV straight from block tables via the Pallas
    # decode-attention op instead of gathering the pool to dense first.
    # Tables stay runtime data either way, so flipping this only changes
    # which program the CompileCache builds — never how it is keyed.
    paged_kernel: bool = False

    def replace(self, **kw) -> "RuntimeOptions":
        return dataclasses.replace(self, **kw)


DEFAULT_OPTIONS = RuntimeOptions()
