"""Unified model configuration for every architecture family the framework serves.

A single ``ModelConfig`` describes dense, MoE, SSM (Mamba2), hybrid
(Mamba2 + shared attention), encoder-decoder (Whisper-style) and VLM
(vision-stub + LLM) architectures.  The elastic-inference component
(``repro.elastic``) derives runtime variants from the same config via the
paper's compression operators; the analytic cost helpers here feed the
runtime performance profiler (paper Eq. 1 / Eq. 2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

# Block kinds used in ``block_pattern``.
ATTN = "attn"          # global self-attention + FFN
LOCAL = "local_attn"   # sliding-window self-attention + FFN
MAMBA = "mamba"        # Mamba2 (SSD) block
SHARED_ATTN = "shared_attn"  # hybrid: shared-weight attention block (Zamba2)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    qkv_bias: bool = False
    gated_ffn: bool = True              # SwiGLU/GeGLU vs plain MLP
    activation: str = "silu"            # silu | gelu
    tie_embeddings: bool = True

    # --- attention pattern -------------------------------------------------
    sliding_window: int = 0             # window size for LOCAL blocks
    local_global_ratio: int = 0         # gemma3-style N local : 1 global
    rope_theta: float = 10000.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False     # llama4-style shared expert
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # --- hybrid (Zamba2) ----------------------------------------------------
    shared_attn_period: int = 0         # apply shared attn block every N blocks

    # --- encoder-decoder (Whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500         # whisper: 30s of audio at 50 fps

    # --- VLM ------------------------------------------------------------------
    vision_embed_dim: int = 0           # stub vision encoder output width
    num_vision_tokens: int = 0

    # --- numerics -------------------------------------------------------------
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    max_seq_len: int = 131072
    norm_eps: float = 1e-6

    # elastic-inference applicability notes (DESIGN.md §Arch-applicability)
    inapplicable_operators: Tuple[str, ...] = ()
    source: str = ""                    # citation for the config

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_conv_dim(self) -> int:
        # channels that go through the causal conv: x, B, C
        return self.ssm_d_inner + 2 * self.ssm_ngroups * self.ssm_state_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/LM head shard
        evenly over a 16-way model axis (MaxText-style vocab padding)."""
        return (self.vocab_size + 255) // 256 * 256

    def block_pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds.  Homogeneous stacks collapse to one kind."""
        if self.arch_type == "ssm":
            return tuple([MAMBA] * self.num_layers)
        if self.arch_type == "hybrid":
            pat = []
            for i in range(self.num_layers):
                pat.append(MAMBA)
                if self.shared_attn_period and (i + 1) % self.shared_attn_period == 0:
                    pat.append(SHARED_ATTN)
            return tuple(pat)
        if self.local_global_ratio:
            # gemma3: N local then 1 global, repeating
            pat = []
            for i in range(self.num_layers):
                if (i + 1) % (self.local_global_ratio + 1) == 0:
                    pat.append(ATTN)
                else:
                    pat.append(LOCAL)
            return tuple(pat)
        return tuple([ATTN] * self.num_layers)

    # ------------------------------------------------------------ cost model
    def param_count(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        ffn_mats = 3 if self.gated_ffn else 2
        per_ffn = ffn_mats * d * f
        norms = 2 * d
        n = 0
        if self.arch_type in ("dense", "audio", "vlm"):
            n += self.num_layers * (per_attn + per_ffn + norms)
        elif self.arch_type == "moe":
            experts = self.num_experts + (1 if self.moe_shared_expert else 0)
            router = d * self.num_experts
            n += self.num_layers * (per_attn + experts * per_ffn + router + norms)
        elif self.arch_type == "ssm":
            n += self.num_layers * self._mamba_block_params()
        elif self.arch_type == "hybrid":
            n += self.num_layers * self._mamba_block_params()
            n += per_attn + per_ffn + norms  # ONE shared attention block
        if self.is_encoder_decoder:
            # encoder self-attn+ffn, decoder adds cross-attn
            n += self.encoder_layers * (per_attn + per_ffn + norms)
            n += self.num_layers * per_attn  # cross attention
        if self.vision_embed_dim:
            n += self.vision_embed_dim * d  # projector
        n += self.vocab_size * d  # embedding (tied with lm head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        n += d  # final norm
        return int(n)

    def _mamba_block_params(self) -> int:
        d, di = self.d_model, self.ssm_d_inner
        nh, st = self.ssm_num_heads, self.ssm_state_dim
        in_proj = d * (2 * di + 2 * self.ssm_ngroups * st + nh)
        conv = self.ssm_conv_dim * self.ssm_conv_width + self.ssm_conv_dim
        extras = 3 * nh          # A_log, D, dt_bias
        out_proj = di * d
        norm = di + d            # gated RMSNorm + pre-norm
        return in_proj + conv + extras + out_proj + norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        ffn_mats = 3 if self.gated_ffn else 2
        per_ffn = ffn_mats * d * f
        active_experts = self.experts_per_token + (1 if self.moe_shared_expert else 0)
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        router = d * self.num_experts
        n = self.num_layers * (per_attn + active_experts * per_ffn + router + 2 * d)
        n += self.vocab_size * d + d
        return int(n)

    def flops_per_token(self, seq_len: int, decode: bool = False) -> float:
        """Approximate forward FLOPs per token (2*MACs), incl. attention.

        ``decode=True``: one new token attending to a cache of ``seq_len``.
        """
        hd = self.resolved_head_dim
        mm = 2.0 * self.active_param_count()  # weight matmuls (fwd)
        attn = 0.0
        pattern = self.block_pattern()
        for kind in pattern:
            if kind in (ATTN, LOCAL, SHARED_ATTN):
                ctx = seq_len if kind != LOCAL else min(seq_len, max(self.sliding_window, 1))
                if decode:
                    span = ctx if kind == LOCAL else seq_len
                    attn += 2.0 * 2.0 * self.num_heads * hd * span
                else:
                    attn += 2.0 * 2.0 * self.num_heads * hd * (ctx / 2.0 if kind != LOCAL else ctx)
            elif kind == MAMBA:
                # SSD: per-token state update ~ nh*hd*state MACs * few
                attn += 2.0 * 6.0 * self.ssm_num_heads * self.ssm_head_dim * self.ssm_state_dim
        return mm + attn

    def kv_cache_bytes(self, batch: int, seq_len: int, dtype_bytes: int = 2) -> int:
        n_attn = sum(1 for k in self.block_pattern() if k in (ATTN, LOCAL, SHARED_ATTN))
        kv = 2 * n_attn * batch * seq_len * self.kv_dim * dtype_bytes
        n_mamba = sum(1 for k in self.block_pattern() if k == MAMBA)
        ssm = n_mamba * batch * (
            self.ssm_num_heads * self.ssm_head_dim * self.ssm_state_dim
            + self.ssm_conv_dim * (self.ssm_conv_width - 1)
        ) * 4
        return int(kv + ssm)

    def with_updates(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------- elastic hooks
    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        ratio = d_model / self.d_model
        nh = max(2, int(self.num_heads * ratio)) if self.num_heads else 0
        nkv = max(1, min(self.num_kv_heads, nh)) if self.num_kv_heads else 0
        if nh and nh % nkv:
            nkv = 1
        kw = dict(
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=(d_model // nh) if nh else 0,
            d_ff=(max(64, int(round(self.d_ff * ratio / 64)) * 64)
                  if self.d_ff else 0),
            vocab_size=min(self.vocab_size, 1024),
            max_seq_len=4096,
        )
        if self.num_experts:
            kw["num_experts"] = min(self.num_experts, max_experts)
            kw["experts_per_token"] = min(self.experts_per_token, kw["num_experts"])
        if self.ssm_state_dim:
            kw["ssm_state_dim"] = min(self.ssm_state_dim, 32)
            kw["ssm_head_dim"] = 32
        if self.is_encoder_decoder:
            kw["encoder_layers"] = num_layers
            kw["encoder_seq_len"] = 64
        if self.vision_embed_dim:
            kw["vision_embed_dim"] = 128
            kw["num_vision_tokens"] = 4
        if self.sliding_window:
            kw["sliding_window"] = 64
        if self.shared_attn_period:
            kw["shared_attn_period"] = 1
        return self.with_updates(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def tokens_per_step(shape: InputShape) -> int:
    if shape.is_decode:
        return shape.global_batch  # one new token per sequence
    return shape.global_batch * shape.seq_len
