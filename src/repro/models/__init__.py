from .configs import (ATTN, INPUT_SHAPES, LOCAL, MAMBA, SHARED_ATTN,
                      DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                      InputShape, ModelConfig, tokens_per_step)
from .model import (Cache, decode_step, forward, init_cache, init_params,
                    lm_loss, prefill)
from .runtime import DEFAULT_OPTIONS, RuntimeOptions

__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "tokens_per_step", "Cache", "decode_step",
    "forward", "init_cache", "init_params", "lm_loss", "prefill",
    "RuntimeOptions", "DEFAULT_OPTIONS", "ATTN", "LOCAL", "MAMBA",
    "SHARED_ATTN",
]
