"""Mamba2 (state-space duality) blocks — chunked SSD scan + O(1) decode step.

Follows the SSD formulation of arXiv:2405.21060: within-chunk attention-like
quadratic form + inter-chunk linear recurrence carried by ``lax.scan``.
The pure-jnp path below is the oracle for the Pallas ``ssd_scan`` kernel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .layers import (Params, causal_conv1d, causal_conv1d_step,
                     gated_rms_norm, rms_norm)


def segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{k=j+1..i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, *, chunk: int,
                 initial_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, S, H, P)   input (pre-discretization)
    dt: (B, S, H)      positive step sizes (softplus applied by caller)
    a:  (H,)           negative decay rates
    b,c:(B, S, G, N)   input/output projections (G groups broadcast to H)
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, s)
    if s % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and zero input leave the
        # carried state untouched, so the final state is exact.
        pad = chunk - s % chunk
        y, final = ssd_scan_ref(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), a,
            jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk=chunk, initial_state=initial_state)
        return y[:, :s], final
    nc = s // chunk
    rep = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)         # discretized input
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)

    xb = xd.reshape(bsz, nc, chunk, h, p)
    bb = bh.reshape(bsz, nc, chunk, h, n)
    cb = ch.reshape(bsz, nc, chunk, h, n)
    da = (dt.astype(jnp.float32) * a.astype(jnp.float32)).reshape(bsz, nc, chunk, h)
    da = jnp.moveaxis(da, -1, -2)                        # (B, nc, H, L)
    da_cs = jnp.cumsum(da, axis=-1)                      # (B, nc, H, L)

    # --- intra-chunk (diagonal blocks) ---
    decay = jnp.exp(segsum(da))                          # (B, nc, H, L, L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", cb, bb, decay, xb)

    # --- chunk-final states ---
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)      # (B, nc, H, L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", bb, decay_states, xb)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(da_cs[..., -1])                # (B, nc, H)
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(prev, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        new = prev * dec[..., None, None] + st
        return new, prev                                 # emit state *entering* chunk

    final, prev_states = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B, nc, H, P, N)

    # --- contribution of the carried state ---
    state_decay = jnp.exp(da_cs)                         # (B, nc, H, L)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", cb, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def ssd_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array, a: jax.Array,
             b_t: jax.Array, c_t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the SSD recurrence.

    state: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H); b_t,c_t: (B,G,N).
    """
    h = x_t.shape[1]
    g = b_t.shape[1]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    chh = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    da = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32))  # (B,H)
    xd = (x_t * dt_t[..., None]).astype(jnp.float32)
    state = state * da[..., None, None] + xd[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, chh)
    return y.astype(x_t.dtype), state


# ------------------------------------------------------------------ block ---
def mamba_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, di = cfg.d_model, cfg.ssm_d_inner
    nh, st, gr = cfg.ssm_num_heads, cfg.ssm_state_dim, cfg.ssm_ngroups
    conv_dim, w = cfg.ssm_conv_dim, cfg.ssm_conv_width
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * gr * st + nh
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, in_dim)) / np.sqrt(d)).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, w)) / np.sqrt(w)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (di, d)) / np.sqrt(di)).astype(dtype),
        "norm_scale": jnp.zeros((di,), dtype),
    }
    return p


def _split_in_proj(cfg: ModelConfig, proj: jax.Array):
    di, gr, st, nh = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state_dim,
                      cfg.ssm_num_heads)
    z = proj[..., :di]
    xbc = proj[..., di:di + cfg.ssm_conv_dim]
    dt = proj[..., di + cfg.ssm_conv_dim:]
    return z, xbc, dt


def mamba_forward(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence Mamba2 block.  x: (B, S, D) -> (B, S, D)."""
    bsz, s, _ = x.shape
    di, nh, hd = cfg.ssm_d_inner, cfg.ssm_num_heads, cfg.ssm_head_dim
    gr, st = cfg.ssm_ngroups, cfg.ssm_state_dim
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_in_proj(cfg, proj)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv_w"], params["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(bsz, s, nh, hd)
    b = xbc[..., di:di + gr * st].reshape(bsz, s, gr, st)
    c = xbc[..., di + gr * st:].reshape(bsz, s, gr, st)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, _ = ssd_scan_ref(xs, dt, a, b, c, chunk=cfg.ssm_chunk)
    y = y + params["d_skip"][None, None, :, None] * xs
    y = y.reshape(bsz, s, di)
    y = gated_rms_norm(y, z, params["norm_scale"], cfg.norm_eps)
    return y @ params["out_proj"]


def mamba_step(params: Params, x_t: jax.Array, ssm_state: jax.Array,
               conv_state: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step.  x_t: (B, D); ssm_state: (B,H,P,N);
    conv_state: (B, W-1, conv_dim)."""
    bsz = x_t.shape[0]
    di, nh, hd = cfg.ssm_d_inner, cfg.ssm_num_heads, cfg.ssm_head_dim
    gr, st = cfg.ssm_ngroups, cfg.ssm_state_dim
    proj = x_t @ params["in_proj"]
    z, xbc, dt = _split_in_proj(cfg, proj)
    xbc, conv_state = causal_conv1d_step(xbc, conv_state, params["conv_w"],
                                         params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x_t.dtype)
    xs = xbc[..., :di].reshape(bsz, nh, hd)
    b = xbc[..., di:di + gr * st].reshape(bsz, gr, st)
    c = xbc[..., di + gr * st:].reshape(bsz, gr, st)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, ssm_state = ssd_step(ssm_state, xs, dt, a, b, c)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(bsz, di)
    y = gated_rms_norm(y, z, params["norm_scale"], cfg.norm_eps)
    return y @ params["out_proj"], ssm_state, conv_state


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    return (
        (batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim),
        (batch, cfg.ssm_conv_width - 1, cfg.ssm_conv_dim),
    )
