"""Unified model API: init / forward / prefill / decode_step.

Every architecture family exposes the same four entry points; the launcher,
serving runtime and middleware only talk to these.  Decode carries an
explicit cache pytree (attention KV, SSM state, conv state, cross-attn KV)
that is threaded through ``lax.scan`` over the stacked layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from ..kernels import ops as kernel_ops
from ..kernels.act_quant import kv_dequant_rows, kv_quant_rows
from .configs import ATTN, LOCAL, MAMBA, ModelConfig
from .layers import (Params, dtype_of, embed_lookup, ffn_apply, matmul_w,
                     rms_norm, unembed)
from .runtime import DEFAULT_OPTIONS, RuntimeOptions
from .transformer import (_pattern_period, apply_stack, forward, init_params,
                          lm_loss)

Cache = Dict[str, Any]

__all__ = ["init_params", "forward", "lm_loss", "init_cache", "prefill",
           "decode_step", "Cache", "init_slot_cache", "write_cache_slot",
           "greedy_batched_step", "sample_logits", "sample_step",
           "sample_batched_step", "admit_slot", "batched_prefill_admit",
           "init_paged_pool", "init_paged_slot_cache",
           "paged_sample_batched_step", "paged_kernel_sample_batched_step",
           "paged_prefill_admit", "paged_thaw_write", "paged_copy_block"]


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.arch_type in ("ssm", "hybrid"):
        return 0
    return cfg.num_layers


def _n_shared_sites(cfg: ModelConfig) -> int:
    if cfg.arch_type != "hybrid":
        return 0
    return cfg.num_layers // (cfg.shared_attn_period or cfg.num_layers)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               opts: RuntimeOptions = DEFAULT_OPTIONS) -> Cache:
    kv_dt = dtype_of(opts.kv_cache_dtype)
    hd = cfg.resolved_head_dim
    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    n_attn = _n_attn_layers(cfg)
    if n_attn:
        shape = (n_attn, batch, max_seq, cfg.num_kv_heads, hd)
        cache["k"] = jnp.zeros(shape, kv_dt)
        cache["v"] = jnp.zeros(shape, kv_dt)
    if cfg.arch_type in ("ssm", "hybrid"):
        st, cv = ssm_mod.mamba_state_shapes(cfg, batch)
        cache["ssm"] = jnp.zeros((cfg.num_layers,) + st, jnp.float32)
        cache["conv"] = jnp.zeros((cfg.num_layers,) + cv, kv_dt)
    ns = _n_shared_sites(cfg)
    if ns:
        shape = (ns, batch, max_seq, cfg.num_kv_heads, hd)
        cache["shared_k"] = jnp.zeros(shape, kv_dt)
        cache["shared_v"] = jnp.zeros(shape, kv_dt)
    if cfg.is_encoder_decoder:
        shape = (cfg.num_layers, batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd)
        cache["cross_k"] = jnp.zeros(shape, kv_dt)
        cache["cross_v"] = jnp.zeros(shape, kv_dt)
    return cache


# ====================================================== slot-stacked cache ==
# The serving engine holds ONE cache pytree for all of its decode slots:
# every leaf of a batch=1 cache gains a leading ``(slots,)`` axis, including
# ``pos`` (each slot sits at its own sequence position).  ``vmap`` over that
# axis turns the per-sequence decode step into a single batched program, so
# per-tick decode cost scales with the model, not with the slot count.

def init_slot_cache(cfg: ModelConfig, slots: int, max_seq: int,
                    opts: RuntimeOptions = DEFAULT_OPTIONS) -> Cache:
    """A zeroed slot-stacked cache: ``init_cache(cfg, 1, ...)`` leaves with
    a leading ``(slots,)`` axis, plus a ``"sample"`` subtree holding each
    slot's sampling state (PRNG key, temperature, top-k) — per-slot policy
    rides in the cache pytree so it is donated, vmapped and slot-scattered
    exactly like the model state.  The zero init is greedy (temperature
    0), so a cache never touched by admission argmaxes."""
    one = init_cache(cfg, 1, max_seq, opts)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.zeros((slots,) + a.shape, a.dtype), one)
    stacked["sample"] = {"key": jnp.zeros((slots, 2), jnp.uint32),
                         "temp": jnp.zeros((slots,), jnp.float32),
                         "top_k": jnp.zeros((slots,), jnp.int32)}
    return stacked


def write_cache_slot(stacked: Cache, cache: Cache, slot: jax.Array) -> Cache:
    """Write a batch=1 cache (e.g. a fresh prefill) into slot ``slot`` of a
    slot-stacked cache.  ``slot`` may be traced, so one compiled program
    serves every slot index.  The two trees must match leaf-for-leaf —
    for an engine cache carrying a ``"sample"`` subtree use
    :func:`admit_slot`, which also sets the slot's sampling state."""
    return jax.tree_util.tree_map(
        lambda s, c: jax.lax.dynamic_update_index_in_dim(
            s, c.astype(s.dtype), slot, 0), stacked, cache)


def admit_slot(stacked: Cache, cache: Cache, slot: jax.Array,
               key: jax.Array, temp: jax.Array, top_k: jax.Array) -> Cache:
    """Write a prefilled batch=1 *model* cache plus its slot sampling state
    (``key (2,) uint32``, ``temp ()``, ``top_k ()``) into slot ``slot`` of
    a slot-stacked serving cache.  ``slot`` is traced — one program covers
    every slot index."""
    model_side = {k: v for k, v in stacked.items() if k != "sample"}
    out = write_cache_slot(model_side, cache, slot)
    s = stacked["sample"]

    def upd(arr, val):
        return jax.lax.dynamic_update_index_in_dim(
            arr, val.astype(arr.dtype), slot, 0)

    out["sample"] = {"key": upd(s["key"], key), "temp": upd(s["temp"], temp),
                     "top_k": upd(s["top_k"], top_k)}
    return out


def greedy_batched_step(params: Params, cfg: ModelConfig, cache: Cache,
                        tokens: jax.Array,
                        opts: RuntimeOptions = DEFAULT_OPTIONS):
    """One greedy decode step over a slot-stacked cache.

    tokens: (slots,) int32 — the last emitted token of each slot.  Returns
    ``(next_tokens (slots,), positions (slots,), new cache)``.  The argmax
    runs on device, so a serving tick needs a single bulk device→host
    transfer of ``2 * slots`` scalars instead of one sync per slot.  Each
    vmapped instance is exactly the batch=1 ``decode_step`` computation, so
    greedy tokens are bit-identical to the per-slot reference path.
    """
    def one(c: Cache, tok: jax.Array):
        logits, c2 = decode_step(params, cfg, c, tok[None], opts)
        nxt = jnp.argmax(logits[0, : cfg.vocab_size]).astype(jnp.int32)
        return (nxt, c2["pos"]), c2

    (nxt, pos), new_cache = jax.vmap(one)(cache, tokens)
    return nxt, pos, new_cache


# ================================================================ sampling ==
def sample_logits(logits: jax.Array, key: jax.Array, temp: jax.Array,
                  top_k: jax.Array, vocab: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Draw the next token from one sequence's (vocab-padded) logits row.

    ``temp == 0`` reduces *exactly* to the greedy argmax the pre-sampling
    engine computed (the sampled branch is selected away by ``where``);
    ``top_k == 0`` samples the full vocabulary, ``top_k == 1`` keeps only
    the argmax.  The key is split on every call, sampled or not, so a
    stream depends only on the initial key and the emission index — never
    on which other slots are decoding.  Returns ``(token, advanced key)``.
    """
    lg = logits[:vocab]
    greedy = jnp.argmax(lg).astype(jnp.int32)
    key, sub = jax.random.split(key)
    scaled = lg.astype(jnp.float32) / jnp.maximum(
        temp.astype(jnp.float32), 1e-6)
    # top-k by stable descending rank (ties keep the lowest index, like
    # argmax) so top_k==1 is *exactly* greedy even on tied logits;
    # top_k<=0 keeps the whole vocabulary
    order = jnp.argsort(-scaled)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(vocab))
    masked = jnp.where((top_k > 0) & (ranks >= jnp.clip(top_k, 1, vocab)),
                       jnp.finfo(jnp.float32).min, scaled)
    sampled = jax.random.categorical(sub, masked).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy), key


def sample_step(params: Params, cfg: ModelConfig, cache: Cache,
                token: jax.Array, opts: RuntimeOptions = DEFAULT_OPTIONS
                ) -> Tuple[jax.Array, Cache]:
    """One sampling decode step for a single sequence.

    ``token`` is a ``()`` int32 scalar; ``cache`` is a batch=1 cache
    carrying a ``"sample"`` subtree ``{key (2,) uint32, temp (), top_k
    ()}`` (``decode_step`` threads unknown keys through untouched).
    :func:`sample_batched_step` is exactly ``vmap`` of this function, so
    per-request streams are bit-identical across the batched and per-slot
    decode paths."""
    logits, c2 = decode_step(params, cfg, cache, token[None], opts)
    s = cache["sample"]
    nxt, new_key = sample_logits(logits[0], s["key"], s["temp"],
                                 s["top_k"], cfg.vocab_size)
    c2["sample"] = {"key": new_key, "temp": s["temp"], "top_k": s["top_k"]}
    return nxt, c2


def sample_batched_step(params: Params, cfg: ModelConfig, cache: Cache,
                        tokens: jax.Array,
                        opts: RuntimeOptions = DEFAULT_OPTIONS):
    """One sampling decode step over a slot-stacked cache.

    The per-slot temperature/top-k/PRNG key live in the cache's
    ``"sample"`` subtree, so heterogeneous per-slot policies run under ONE
    compiled program — sampling parameters are runtime data, not compile
    constants.  Slots with ``temp == 0`` produce exactly the greedy argmax
    (the engine's historical behavior).  Returns ``(next_tokens (slots,),
    positions (slots,), new cache)``."""
    def one(c: Cache, tok: jax.Array):
        nxt, c2 = sample_step(params, cfg, c, tok, opts)
        return (nxt, c2["pos"]), c2

    (nxt, pos), new_cache = jax.vmap(one)(cache, tokens)
    return nxt, pos, new_cache


# ===================================================== batched admission ====
def batched_prefill_admit(params: Params, cfg: ModelConfig, stacked: Cache,
                          tokens: jax.Array, slot_ids: jax.Array,
                          keys: jax.Array, temps: jax.Array,
                          top_ks: jax.Array, opts: RuntimeOptions,
                          max_seq: int):
    """Prefill ``k`` left-padded same-bucket prompts in ONE call and
    scatter each row's cache, sampling state and first sampled token into
    its decode slot of the slot-stacked serving cache.

    ``tokens`` is ``(k, bucket)`` int32; ``slot_ids``/``keys``/``temps``/
    ``top_ks`` are per-row.  Rows are written in order, so callers pad a
    burst up to a k-bucket by *prepending* rows that target the first real
    row's slot — the real row then overwrites the padding's garbage.
    Returns ``((k,) first tokens, new stacked cache)``; each row's first
    token is drawn by the same :func:`sample_logits` the decode step uses
    (argmax when its temperature is 0)."""
    k, bucket = tokens.shape
    # the scratch cache is sized to the prompt *bucket*, not max_seq:
    # burst admission's transient memory is k×bucket + one max_seq row
    # (padded below, per row) instead of a second full k×max_seq cache —
    # the zero padding is identical to what a max_seq prefill writes
    cache = init_cache(cfg, k, min(bucket, max_seq), opts)
    logits, cache = prefill(params, cfg, tokens, cache, opts)
    first, new_keys = jax.vmap(
        lambda lg, ky, t, tk: sample_logits(lg, ky, t, tk, cfg.vocab_size)
    )(logits[:, -1], keys, temps, top_ks)
    out = stacked
    model_side = {key: v for key, v in stacked.items() if key != "sample"}
    for i in range(k):
        # batch lives at axis 1 of every array leaf; ``pos`` is a scalar
        # shared by the whole bucket (all rows are left-padded to it)
        row = jax.tree_util.tree_map(
            lambda a, i=i: a if a.ndim == 0 else
            jax.lax.slice_in_dim(a, i, i + 1, axis=1), cache)
        row = jax.tree_util.tree_map(
            lambda s, c: c if c.ndim == 0 else jnp.pad(
                c, [(0, t - n) for t, n in zip(s.shape[1:], c.shape)]),
            model_side, row)
        out = admit_slot(out, row, slot_ids[i], new_keys[i], temps[i],
                         top_ks[i])
    return first, out


# ============================================================ paged cache ==
# Block-paged KV: self-attention K/V live in a pool of fixed-size blocks
# shared by every slot, and each slot carries a host-side block table —
# a (slots, max_seq // block_size) int32 array of pool indices passed to
# the jitted step as *runtime data* (constant shape, so occupancy changes
# never recompile).  The paged step gathers each slot's blocks into a
# dense (1, max_seq) view and runs the *same* ``sample_step`` computation
# the dense engine runs: positions beyond ``pos`` read garbage from
# not-yet-written / trash blocks, but ``decode_attention`` replaces
# masked scores with NEG_INF, so their contribution is exactly 0 and the
# paged stream is bit-identical to the dense one.  Only self-attention
# K/V are paged — SSM/conv state is O(1) per sequence and stays a dense
# slot leaf, which is why paged mode requires an attention stack.

def init_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    opts: RuntimeOptions = DEFAULT_OPTIONS) -> Cache:
    """The device block pool: ``{"k","v"}`` of shape ``(num_blocks,
    n_attn_layers, block_size, num_kv_heads, head_dim)``.  Block 0 is
    the trash block (see :mod:`repro.serving.paging`).

    ``opts.kv_dtype == "int8"`` stores the blocks int8 and adds
    ``{"k_scale","v_scale"}`` leaves of shape ``(num_blocks, n_attn,
    block_size)`` — one f32 scale per KV *row* (token × layer), the
    append granularity of both prefill blockify and the decode scatter.
    Every paged writer quantizes through :func:`kv_quant_rows` and every
    reader (gather step, kernel step, engine freeze) dequantizes, so the
    pool is ~4x denser for the same HBM."""
    n_attn = _n_attn_layers(cfg)
    if not n_attn:
        raise ValueError("paged decode requires an attention stack "
                         f"(arch_type={cfg.arch_type!r} has no KV cache)")
    if opts.kv_dtype not in ("auto", "int8"):
        raise ValueError(f"kv_dtype={opts.kv_dtype!r} (want 'auto' or 'int8')")
    store_int8 = opts.kv_dtype == "int8"
    kv_dt = jnp.int8 if store_int8 else dtype_of(opts.kv_cache_dtype)
    shape = (num_blocks, n_attn, block_size, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    pool = {"k": jnp.zeros(shape, kv_dt), "v": jnp.zeros(shape, kv_dt)}
    if store_int8:
        sshape = (num_blocks, n_attn, block_size)
        pool["k_scale"] = jnp.zeros(sshape, jnp.float32)
        pool["v_scale"] = jnp.zeros(sshape, jnp.float32)
    return pool


def init_paged_slot_cache(cfg: ModelConfig, slots: int, max_seq: int,
                          opts: RuntimeOptions = DEFAULT_OPTIONS) -> Cache:
    """A slot-stacked serving cache *without* the dense ``k``/``v``
    leaves (those live in the block pool); everything else — ``pos``,
    the ``"sample"`` subtree, cross-attention KV — stays per-slot."""
    stacked = init_slot_cache(cfg, slots, max_seq, opts)
    return {k: v for k, v in stacked.items() if k not in ("k", "v")}


def paged_sample_batched_step(params: Params, cfg: ModelConfig,
                              slot_cache: Cache, pool: Cache,
                              tokens: jax.Array, tables: jax.Array,
                              opts: RuntimeOptions = DEFAULT_OPTIONS):
    """One sampling decode step over paged KV.

    ``tables`` is ``(slots, max_seq // block_size)`` int32.  Per slot:
    gather its blocks into a dense view, run the exact dense
    ``sample_step``, slice the newly written KV row back out.  One
    batched scatter then writes every slot's row into its tail block —
    active slots always own their tail block (buckets are block-aligned
    and thawed blocks are private), so no two real writes collide;
    masked slots write the trash block, whose content is never read
    unmasked.  Returns ``(next_tokens, positions, new slot cache,
    new pool)``.

    An int8 pool (``opts.kv_dtype == "int8"``) dequantizes per row while
    gathering and re-quantizes the newly written row before the scatter —
    the dense computation in the middle is unchanged."""
    pk, pv = pool["k"], pool["v"]
    psk, psv = pool.get("k_scale"), pool.get("v_scale")
    _, n_attn, bs, kvh, hd = pk.shape
    mb = tables.shape[1]
    kv_dt = dtype_of(opts.kv_cache_dtype)

    def one(c: Cache, tok: jax.Array, tbl: jax.Array):
        def dense_view(p, scl):
            g = p[tbl]                          # (mb, n_attn, bs, kvh, hd)
            if scl is not None:
                g = kv_dequant_rows(g, scl[tbl], kv_dt)
            return jnp.moveaxis(g, 0, 1).reshape(n_attn, 1, mb * bs, kvh, hd)

        dense = dict(c)
        dense["k"], dense["v"] = dense_view(pk, psk), dense_view(pv, psv)
        wpos = c["pos"]                         # this step writes row wpos
        nxt, c2 = sample_step(params, cfg, dense, tok, opts)
        row_k = jax.lax.dynamic_slice_in_dim(c2["k"], wpos, 1, axis=2)
        row_v = jax.lax.dynamic_slice_in_dim(c2["v"], wpos, 1, axis=2)
        slot_side = {k: v for k, v in c2.items() if k not in ("k", "v")}
        blk = tbl[wpos // bs]
        return (nxt, c2["pos"], slot_side, row_k[:, 0, 0], row_v[:, 0, 0],
                blk, wpos % bs)

    nxt, pos, new_cache, rk, rv, blks, offs = jax.vmap(one)(
        slot_cache, tokens, tables)
    new_pool = _scatter_kv_rows(pool, rk, rv, blks, offs)
    return nxt, pos, new_cache, new_pool


def _scatter_kv_rows(pool: Cache, rk: jax.Array, rv: jax.Array,
                     blks: jax.Array, offs: jax.Array) -> Cache:
    """Write one KV row per slot into its tail block.  ``rk``/``rv``:
    ``(slots, n_attn, kvh, hd)``; ``blks``/``offs``: ``(slots,)``.
    Quantizes the rows first when the pool stores int8."""
    new_pool = dict(pool)
    if "k_scale" in pool:
        rk, sk = kv_quant_rows(rk)
        rv, sv = kv_quant_rows(rv)
        new_pool["k_scale"] = pool["k_scale"].at[blks, :, offs].set(sk)
        new_pool["v_scale"] = pool["v_scale"].at[blks, :, offs].set(sv)
    new_pool["k"] = pool["k"].at[blks, :, offs].set(rk.astype(pool["k"].dtype))
    new_pool["v"] = pool["v"].at[blks, :, offs].set(rv.astype(pool["v"].dtype))
    return new_pool


def _attn_decode_paged(layer: Params, x: jax.Array, kb, vb, ks, vs,
                       tables, pos, sin, cos, cfg: ModelConfig,
                       opts: RuntimeOptions, *, window: int, cross_kv=None):
    """One-token attention block reading KV straight off the block table.

    Slot-batched twin of :func:`_attn_decode`: x is ``(slots, D)``,
    ``kb``/``vb`` are ONE layer's pool blocks ``(num_blocks, bs, kvh,
    hd)`` (``ks``/``vs`` the matching int8 scales or ``None``), ``pos``
    is per-slot.  Attention runs through :func:`kernel_ops.paged_attention`
    (Pallas on TPU, ``ref.py`` oracle elsewhere); the new token's KV is
    *returned* — ``(slots, kvh, hd)`` each — for one batched scatter at
    the end of the step instead of being written into the pool here."""
    b, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    a = layer["attn"]
    q = matmul_w(h, a["wq"]).reshape(b, cfg.num_heads, hd)
    k = matmul_w(h, a["wk"]).reshape(b, cfg.num_kv_heads, hd)
    v = matmul_w(h, a["wv"]).reshape(b, cfg.num_kv_heads, hd)
    if "bq" in a:
        q = q + a["bq"].reshape(cfg.num_heads, hd)
        k = k + a["bk"].reshape(cfg.num_kv_heads, hd)
        v = v + a["bv"].reshape(cfg.num_kv_heads, hd)
    q = _apply_rot1(q, sin, cos)
    k = _apply_rot1(k, sin, cos)
    w = window or opts.decode_window
    out = kernel_ops.paged_attention(
        q, kb, vb, tables, pos, k, v, ks, vs, window=w,
        use_pallas=opts.use_pallas)
    x = x + matmul_w(out.reshape(b, cfg.num_heads * hd), a["wo"]).astype(x.dtype)

    if cross_kv is not None and "cross" in layer:
        hq = rms_norm(x, layer["ln_cross"], cfg.norm_eps)
        c = layer["cross"]
        qc = (hq @ c["wq"]).reshape(b, cfg.num_heads, hd)
        ck, cv = cross_kv
        out = attn_mod.decode_attention(qc, ck.astype(x.dtype),
                                        cv.astype(x.dtype),
                                        jnp.int32(ck.shape[1] - 1), window=0)
        x = x + (out.reshape(b, cfg.num_heads * hd) @ c["wo"]).astype(x.dtype)

    h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y = moe_mod.moe_apply_decode(layer["moe"], h2, cfg)
    else:
        y = ffn_apply(layer["ffn"], h2, gated=cfg.gated_ffn,
                      activation=cfg.activation)
    return x + y.astype(x.dtype), k, v


def paged_kernel_sample_batched_step(params: Params, cfg: ModelConfig,
                                     slot_cache: Cache, pool: Cache,
                                     tokens: jax.Array, tables: jax.Array,
                                     opts: RuntimeOptions = DEFAULT_OPTIONS):
    """One sampling decode step over paged KV — no gather-to-dense detour.

    Drop-in twin of :func:`paged_sample_batched_step` (same signature,
    same return contract) selected by ``opts.paged_kernel``: instead of
    materializing a dense ``(mb * bs)`` view per slot, every layer's
    attention reads its pool blocks *through the block table* via
    :func:`kernel_ops.paged_attention` (the Pallas decode kernel on TPU,
    its ``ref.py`` oracle elsewhere).  The whole step is slot-batched
    directly — q/k/v projections, FFN and sampling run at batch = slots
    with per-slot rotary phases — rather than ``vmap`` of a batch-1 step.
    Tables and positions stay runtime data, so occupancy/fragmentation
    never recompiles; int8 pools pass their per-row scales straight into
    the kernel's block loop (dequant on chip, never in HBM).

    §Perf: the pool is viewed layer-major (``moveaxis(pool, 1, 0)``) so
    ``lax.scan`` can carry one layer's blocks per iteration — XLA fuses
    the transpose into the scan gather, but a layer-major pool layout
    would make it free."""
    from .layers import (cast_params, mask_padded_logits_raw,
                         rotary_embedding)
    act_dt = dtype_of(cfg.activation_dtype)
    params = cast_params(params, act_dt)
    x = embed_lookup(params["embed"], tokens).astype(act_dt)  # (slots, D)
    pos = slot_cache["pos"]                                   # (slots,)
    pk, pv = pool["k"], pool["v"]
    _, n_attn, bs, kvh, hd = pk.shape
    has_scales = "k_scale" in pool
    pk_l = jnp.moveaxis(pk, 1, 0)       # (n_attn, num_blocks, bs, kvh, hd)
    pv_l = jnp.moveaxis(pv, 1, 0)
    ks_l = jnp.moveaxis(pool["k_scale"], 1, 0) if has_scales else None
    vs_l = jnp.moveaxis(pool["v_scale"], 1, 0) if has_scales else None
    sin, cos = rotary_embedding(pos[:, None], hd, cfg.rope_theta)
    tables = tables.astype(jnp.int32)

    kinds, _ = _pattern_period(cfg)
    period = len(kinds)
    has_cross = cfg.is_encoder_decoder
    n = cfg.num_layers
    n_full = (n // period) * period
    new_cache = dict(slot_cache)

    def run_layer(x, layer, j_kind, kb, vb, ksb, vsb, ckv):
        w = cfg.sliding_window if j_kind == LOCAL else 0
        return _attn_decode_paged(layer, x, kb, vb, ksb, vsb, tables, pos,
                                  sin, cos, cfg, opts, window=w,
                                  cross_kv=ckv)

    def layer_step(carry, xs):
        x = carry
        if has_cross:
            layer_pp, kbp, vbp, ksp, vsp, ck, cv = xs
        else:
            layer_pp, kbp, vbp, ksp, vsp = xs
            ck = cv = None
        rks, rvs = [], []
        for j, kind in enumerate(kinds):
            layer = jax.tree_util.tree_map(lambda a: a[j], layer_pp)
            ckv = (ck[j], cv[j]) if has_cross else None
            x, k1, v1 = run_layer(x, layer, kind, kbp[j], vbp[j],
                                  None if ksp is None else ksp[j],
                                  None if vsp is None else vsp[j], ckv)
            rks.append(k1)
            rvs.append(v1)
        return x, (jnp.stack(rks), jnp.stack(rvs))

    row_k = row_v = None
    if n_full:
        def group(a):
            return a[:n_full].reshape(n_full // period, period, *a.shape[1:])

        grouped = jax.tree_util.tree_map(group, params["layers"])
        xs = (grouped, group(pk_l), group(pv_l),
              None if ks_l is None else group(ks_l),
              None if vs_l is None else group(vs_l))
        if has_cross:
            # cross KV is a slot leaf (slots, n_layers, 1, enc_seq, kvh, hd);
            # rearrange layer-major for the scan, dropping the batch=1 axis
            ckg = group(jnp.moveaxis(slot_cache["cross_k"][:, :, 0], 0, 1))
            cvg = group(jnp.moveaxis(slot_cache["cross_v"][:, :, 0], 0, 1))
            xs = xs + (ckg, cvg)
        # None scale entries are empty pytrees — scan passes them through
        x, (rk_o, rv_o) = jax.lax.scan(layer_step, x, xs)
        row_k = rk_o.reshape(n_full, *rk_o.shape[2:])   # (n_full, slots, ...)
        row_v = rv_o.reshape(n_full, *rv_o.shape[2:])
    rows_k_tail, rows_v_tail = [], []
    for j in range(n_full, n):
        layer = jax.tree_util.tree_map(lambda a: a[j], params["layers"])
        kind = kinds[(j - n_full) % period]
        ckv = ((slot_cache["cross_k"][:, j, 0],
                slot_cache["cross_v"][:, j, 0]) if has_cross else None)
        x, k1, v1 = run_layer(x, layer, kind, pk_l[j], pv_l[j],
                              None if ks_l is None else ks_l[j],
                              None if vs_l is None else vs_l[j], ckv)
        rows_k_tail.append(k1)
        rows_v_tail.append(v1)
    if rows_k_tail:
        tail_k, tail_v = jnp.stack(rows_k_tail), jnp.stack(rows_v_tail)
        row_k = tail_k if row_k is None else jnp.concatenate([row_k, tail_k])
        row_v = tail_v if row_v is None else jnp.concatenate([row_v, tail_v])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    logits = mask_padded_logits_raw(logits, cfg.vocab_size)
    s = slot_cache["sample"]
    nxt, new_keys = jax.vmap(
        lambda lg, ky, t, tk: sample_logits(lg, ky, t, tk, cfg.vocab_size)
    )(logits, s["key"], s["temp"], s["top_k"])
    new_cache["sample"] = {"key": new_keys, "temp": s["temp"],
                           "top_k": s["top_k"]}
    new_cache["pos"] = pos + 1

    # one batched scatter of every layer's new row into each slot's tail
    # block (same collision-freedom argument as the gather step)
    rk = jnp.moveaxis(row_k, 0, 1)                  # (slots, n_attn, kvh, hd)
    rv = jnp.moveaxis(row_v, 0, 1)
    blks = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
    offs = pos % bs
    new_pool = _scatter_kv_rows(pool, rk, rv, blks, offs)
    return nxt, new_cache["pos"], new_cache, new_pool


def paged_prefill_admit(params: Params, cfg: ModelConfig, slot_cache: Cache,
                        pool: Cache, tokens: jax.Array, slot_ids: jax.Array,
                        keys: jax.Array, temps: jax.Array,
                        top_ks: jax.Array, dest_blocks: jax.Array,
                        opts: RuntimeOptions):
    """Burst admission into the paged cache: prefill ``(k, bucket)``
    left-padded prompts in ONE call, scatter each row's KV into its
    destination pool blocks and its non-KV leaves + sampling state into
    its slot.  ``dest_blocks`` is ``(k, bucket // block_size)`` int32 —
    padding rows target the trash block.  Returns ``((k,) first tokens,
    (k, vocab) last-position logits, new slot cache, new pool)``; the
    logits rows let the caller cache the prefill for prefix reuse."""
    k, bucket = tokens.shape
    _, n_attn, bs, kvh, hd = pool["k"].shape
    nblk = bucket // bs
    cache = init_cache(cfg, k, bucket, opts)
    logits, cache = prefill(params, cfg, tokens, cache, opts)
    last = logits[:, -1]
    first, new_keys = jax.vmap(
        lambda lg, ky, t, tk: sample_logits(lg, ky, t, tk, cfg.vocab_size)
    )(last, keys, temps, top_ks)

    def blockify(a):                     # (n_attn, k, bucket, kvh, hd)
        a = jnp.moveaxis(a, 0, 1).reshape(k, n_attn, nblk, bs, kvh, hd)
        return jnp.moveaxis(a, 2, 1).reshape(k * nblk, n_attn, bs, kvh, hd)

    flat = dest_blocks.reshape(-1)
    new_pool = dict(pool)
    bk, bv = blockify(cache["k"]), blockify(cache["v"])
    if "k_scale" in pool:                # quantize at append time
        bk, sk = kv_quant_rows(bk)
        bv, sv = kv_quant_rows(bv)
        new_pool["k_scale"] = pool["k_scale"].at[flat].set(sk)
        new_pool["v_scale"] = pool["v_scale"].at[flat].set(sv)
    new_pool["k"] = pool["k"].at[flat].set(bk.astype(pool["k"].dtype))
    new_pool["v"] = pool["v"].at[flat].set(bv.astype(pool["v"].dtype))
    out = slot_cache
    model_side = {key: v for key, v in slot_cache.items() if key != "sample"}
    row_src = {key: v for key, v in cache.items() if key not in ("k", "v")}
    for i in range(k):
        row = jax.tree_util.tree_map(
            lambda a, i=i: a if a.ndim == 0 else
            jax.lax.slice_in_dim(a, i, i + 1, axis=1), row_src)
        row = jax.tree_util.tree_map(
            lambda s, c: c if c.ndim == 0 else jnp.pad(
                c, [(0, t - n) for t, n in zip(s.shape[1:], c.shape)]),
            model_side, row)
        out = admit_slot(out, row, slot_ids[i], new_keys[i], temps[i],
                         top_ks[i])
    return first, last, out, new_pool


def paged_thaw_write(pool: Cache, rows_k: jax.Array, rows_v: jax.Array,
                     ids: jax.Array) -> Cache:
    """Scatter a thawed request's densified KV back into pool blocks.
    ``rows_k``/``rows_v``: ``(nblk, n_attn, block_size, kvh, hd)``;
    ``ids``: ``(nblk,)`` freshly allocated (private) block indices.
    Frozen blobs stay portable (``kv_cache_dtype``), so an int8 pool
    re-quantizes on thaw — for rows that were quantized at freeze this is
    effectively the identity (the max-code row recovers its scale)."""
    new_pool = dict(pool)
    if "k_scale" in pool:
        rows_k, sk = kv_quant_rows(rows_k)
        rows_v, sv = kv_quant_rows(rows_v)
        new_pool["k_scale"] = pool["k_scale"].at[ids].set(sk)
        new_pool["v_scale"] = pool["v_scale"].at[ids].set(sv)
    new_pool["k"] = pool["k"].at[ids].set(rows_k.astype(pool["k"].dtype))
    new_pool["v"] = pool["v"].at[ids].set(rows_v.astype(pool["v"].dtype))
    return new_pool


def paged_copy_block(pool: Cache, src: jax.Array, dst: jax.Array) -> Cache:
    """Copy-on-write: duplicate block ``src`` into ``dst`` (both traced,
    one program covers every pair).  Generic over the pool's leaves, so
    int8 scale planes ride along with their blocks."""
    return {name: arr.at[dst].set(arr[src]) for name, arr in pool.items()}


# =========================================================== decode blocks ==
def _decode_rotary(pos: jax.Array, head_dim: int, theta: float):
    from .layers import rotary_embedding
    return rotary_embedding(pos[None, None], head_dim, theta)  # (1,1,half)


def _apply_rot1(x: jax.Array, sin, cos):
    """x: (B, H, hd) one-token rotary."""
    from .layers import apply_rotary
    return apply_rotary(x[:, None], sin, cos)[:, 0]


def _attn_decode(layer: Params, x: jax.Array, k_cache, v_cache, pos,
                 cfg: ModelConfig, opts: RuntimeOptions, *, window: int,
                 cross_kv=None):
    """One-token attention block.  x: (B, D)."""
    b, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    a = layer["attn"]
    q = matmul_w(h, a["wq"]).reshape(b, cfg.num_heads, hd)
    k = matmul_w(h, a["wk"]).reshape(b, cfg.num_kv_heads, hd)
    v = matmul_w(h, a["wv"]).reshape(b, cfg.num_kv_heads, hd)
    if "bq" in a:
        q = q + a["bq"].reshape(cfg.num_heads, hd)
        k = k + a["bk"].reshape(cfg.num_kv_heads, hd)
        v = v + a["bv"].reshape(cfg.num_kv_heads, hd)
    sin, cos = _decode_rotary(pos, hd, cfg.rope_theta)
    q = _apply_rot1(q, sin, cos)
    k = _apply_rot1(k, sin, cos)
    k_cache, v_cache = attn_mod.update_kv_cache(k_cache, v_cache, k, v, pos)
    w = window or opts.decode_window
    out = attn_mod.decode_attention(q, k_cache, v_cache, pos, window=w)
    x = x + matmul_w(out.reshape(b, cfg.num_heads * hd), a["wo"]).astype(x.dtype)

    if cross_kv is not None and "cross" in layer:
        hq = rms_norm(x, layer["ln_cross"], cfg.norm_eps)
        c = layer["cross"]
        qc = (hq @ c["wq"]).reshape(b, cfg.num_heads, hd)
        ck, cv = cross_kv
        # non-causal attention over the fixed encoder output
        out = attn_mod.decode_attention(qc, ck.astype(x.dtype),
                                        cv.astype(x.dtype),
                                        jnp.int32(ck.shape[1] - 1), window=0)
        x = x + (out.reshape(b, cfg.num_heads * hd) @ c["wo"]).astype(x.dtype)

    h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y = moe_mod.moe_apply_decode(layer["moe"], h2, cfg)
    else:
        y = ffn_apply(layer["ffn"], h2, gated=cfg.gated_ffn,
                      activation=cfg.activation)
    return x + y.astype(x.dtype), k_cache, v_cache


def _mamba_decode(layer: Params, x: jax.Array, ssm_state, conv_state,
                  cfg: ModelConfig):
    h = rms_norm(x, layer["ln"], cfg.norm_eps)
    y, ssm_state, conv_state = ssm_mod.mamba_step(
        layer["mamba"], h, ssm_state, conv_state.astype(h.dtype), cfg)
    return x + y.astype(x.dtype), ssm_state, conv_state


# ================================================================= decode ==
def decode_step(params: Params, cfg: ModelConfig, cache: Cache,
                token: jax.Array, opts: RuntimeOptions = DEFAULT_OPTIONS
                ) -> Tuple[jax.Array, Cache]:
    """Generate logits for ONE new token per sequence.

    token: (B,) int32.  Returns (logits (B, vocab), updated cache).
    """
    from .layers import cast_params
    act_dt = dtype_of(cfg.activation_dtype)
    params = cast_params(params, act_dt)
    x = embed_lookup(params["embed"], token).astype(act_dt)  # (B, D)
    pos = cache["pos"]
    kinds, shared_after = _pattern_period(cfg)
    period = len(kinds)
    new_cache = dict(cache)

    if cfg.arch_type in ("ssm", "hybrid"):
        n = cfg.num_layers
        n_full = (n // period) * period

        has_shared = shared_after and "shared_attn" in params \
            and "shared_k" in cache

        def period_step(carry, xs):
            x = carry
            if has_shared:
                layer_pp, ssm_pp, conv_pp, sk, sv = xs
            else:
                layer_pp, ssm_pp, conv_pp = xs
                sk = sv = None
            new_ssm, new_conv = [], []
            for j in range(period):
                layer = jax.tree_util.tree_map(lambda a: a[j], layer_pp)
                x, s1, c1 = _mamba_decode(layer, x, ssm_pp[j], conv_pp[j], cfg)
                new_ssm.append(s1)
                new_conv.append(c1)
            ys = (jnp.stack(new_ssm), jnp.stack(new_conv))
            if has_shared:
                x, sk, sv = _attn_decode(params["shared_attn"], x, sk, sv,
                                         pos, cfg, opts, window=0)
                ys = ys + (sk, sv)
            return x, ys

        if n_full:
            grouped = jax.tree_util.tree_map(
                lambda a: a[:n_full].reshape(n_full // period, period,
                                             *a.shape[1:]), params["layers"])
            ssm_g = cache["ssm"][:n_full].reshape(n_full // period, period,
                                                  *cache["ssm"].shape[1:])
            conv_g = cache["conv"][:n_full].reshape(n_full // period, period,
                                                    *cache["conv"].shape[1:])
            xs = (grouped, ssm_g, conv_g)
            if has_shared:
                xs = xs + (cache["shared_k"], cache["shared_v"])
            x, ys = jax.lax.scan(period_step, x, xs)
            ssm_o, conv_o = ys[0], ys[1]
            new_cache["ssm"] = new_cache["ssm"].at[:n_full].set(
                ssm_o.reshape(n_full, *ssm_o.shape[2:]))
            new_cache["conv"] = new_cache["conv"].at[:n_full].set(
                conv_o.reshape(n_full, *conv_o.shape[2:])
                .astype(new_cache["conv"].dtype))
            if has_shared:
                new_cache["shared_k"], new_cache["shared_v"] = ys[2], ys[3]
        for j in range(n_full, n):
            layer = jax.tree_util.tree_map(lambda a: a[j], params["layers"])
            x, s1, c1 = _mamba_decode(layer, x, cache["ssm"][j],
                                      cache["conv"][j], cfg)
            new_cache["ssm"] = new_cache["ssm"].at[j].set(s1)
            new_cache["conv"] = new_cache["conv"].at[j].set(
                c1.astype(new_cache["conv"].dtype))
    else:
        # attention stacks (dense / moe / local-global / enc-dec / vlm)
        cross = None
        has_cross = cfg.is_encoder_decoder

        def layer_step(carry, xs):
            x = carry
            if has_cross:
                layer_pp, kc, vc, ck, cv = xs
            else:
                layer_pp, kc, vc = xs
                ck = cv = None
            new_k, new_v = [], []
            for j, kind in enumerate(kinds):
                layer = jax.tree_util.tree_map(lambda a: a[j], layer_pp)
                w = cfg.sliding_window if kind == LOCAL else 0
                ckv = (ck[j], cv[j]) if has_cross else None
                x, k1, v1 = _attn_decode(layer, x, kc[j], vc[j], pos, cfg,
                                         opts, window=w, cross_kv=ckv)
                new_k.append(k1)
                new_v.append(v1)
            return x, (jnp.stack(new_k), jnp.stack(new_v))

        n = cfg.num_layers
        n_full = (n // period) * period
        if n_full:
            grouped = jax.tree_util.tree_map(
                lambda a: a[:n_full].reshape(n_full // period, period,
                                             *a.shape[1:]), params["layers"])
            kg = cache["k"][:n_full].reshape(n_full // period, period,
                                             *cache["k"].shape[1:])
            vg = cache["v"][:n_full].reshape(n_full // period, period,
                                             *cache["v"].shape[1:])
            xs = (grouped, kg, vg)
            if has_cross:
                ckg = cache["cross_k"][:n_full].reshape(
                    n_full // period, period, *cache["cross_k"].shape[1:])
                cvg = cache["cross_v"][:n_full].reshape(
                    n_full // period, period, *cache["cross_v"].shape[1:])
                xs = (grouped, kg, vg, ckg, cvg)
            x, (k_o, v_o) = jax.lax.scan(layer_step, x, xs)
            new_cache["k"] = k_o.reshape(n_full, *k_o.shape[2:])
            new_cache["v"] = v_o.reshape(n_full, *v_o.shape[2:])
        for j in range(n_full, n):
            layer = jax.tree_util.tree_map(lambda a: a[j], params["layers"])
            kind = kinds[(j - n_full) % period]
            w = cfg.sliding_window if kind == LOCAL else 0
            ckv = ((cache["cross_k"][j], cache["cross_v"][j])
                   if has_cross else None)
            x, k1, v1 = _attn_decode(layer, x, cache["k"][j], cache["v"][j],
                                     pos, cfg, opts, window=w, cross_kv=ckv)
            new_cache["k"] = new_cache["k"].at[j].set(k1)
            new_cache["v"] = new_cache["v"].at[j].set(v1)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    from .layers import mask_padded_logits_raw
    logits = mask_padded_logits_raw(logits, cfg.vocab_size)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ================================================================ prefill ==
def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            cache: Cache, opts: RuntimeOptions = DEFAULT_OPTIONS, *,
            encoder_frames: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Cache]:
    """Process a prompt, filling the cache.  Returns (logits, cache).

    A single scanned walk over the stacked layers computes activations AND
    captures per-layer cache entries (attention K/V, SSM final state, conv
    tail, cross-attn K/V) as scan outputs.
    """
    from .layers import cast_params
    act_dt = dtype_of(cfg.activation_dtype)
    params = cast_params(params, act_dt)
    x = embed_lookup(params["embed"], tokens).astype(act_dt)
    if cfg.vision_embed_dim and vision_embeds is not None:
        v = (vision_embeds.astype(act_dt) @ params["vision_proj"]["w"]
             + params["vision_proj"]["b"]).astype(act_dt)
        # vision embeddings occupy the first n_vis positions; the token ids
        # at those positions are placeholders (paper: modality frontend stub)
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)
    new_cache = dict(cache)
    b, s = x.shape[0], x.shape[1]
    if "k" in cache:
        max_seq = cache["k"].shape[2]
    elif "shared_k" in cache:
        max_seq = cache["shared_k"].shape[2]
    else:
        max_seq = s
    hd = cfg.resolved_head_dim

    cross_src = None
    if cfg.is_encoder_decoder and encoder_frames is not None:
        enc = encoder_frames.astype(act_dt)
        enc, _ = apply_stack(params["encoder"], enc, cfg,
                             opts.replace(attn_impl="full"), causal=False)
        cross_src = rms_norm(enc, params["encoder_norm"], cfg.norm_eps)

    kinds, shared_after = _pattern_period(cfg)
    period = len(kinds)
    n = cfg.num_layers
    n_full = (n // period) * period
    kv_dt = dtype_of(opts.kv_cache_dtype)

    def pad_kv(kk):
        return jnp.pad(kk.astype(kv_dt),
                       ((0, 0), (0, max_seq - s), (0, 0), (0, 0)))

    if cfg.arch_type in ("ssm", "hybrid"):
        def period_body(x, layer_pp):
            sts, cvs = [], []
            for j in range(period):
                layer = jax.tree_util.tree_map(lambda a: a[j], layer_pp)
                h = rms_norm(x, layer["ln"], cfg.norm_eps)
                y, st, cv = _mamba_prefill_states(layer["mamba"], h, cfg)
                x = x + y.astype(x.dtype)
                sts.append(st)
                cvs.append(cv.astype(kv_dt))
            shared_kv = None
            if shared_after and "shared_attn" in params:
                x, kk, vv = _attn_prefill_kv(params["shared_attn"], x, cfg,
                                             opts, window=0)
                shared_kv = (pad_kv(kk), pad_kv(vv))
            return x, (jnp.stack(sts), jnp.stack(cvs), shared_kv)

        if n_full:
            grouped = jax.tree_util.tree_map(
                lambda a: a[:n_full].reshape(n_full // period, period,
                                             *a.shape[1:]), params["layers"])

            def scan_body(x, pp):
                x, (sts, cvs, skv) = period_body(x, pp)
                ys = (sts, cvs) + ((skv[0], skv[1]) if skv is not None else ())
                return x, ys

            x, ys = jax.lax.scan(scan_body, x, grouped)
            sts, cvs = ys[0], ys[1]
            new_cache["ssm"] = new_cache["ssm"].at[:n_full].set(
                sts.reshape(n_full, *sts.shape[2:]))
            new_cache["conv"] = new_cache["conv"].at[:n_full].set(
                cvs.reshape(n_full, *cvs.shape[2:]))
            if len(ys) > 2:
                new_cache["shared_k"], new_cache["shared_v"] = ys[2], ys[3]
        for j in range(n_full, n):
            layer = jax.tree_util.tree_map(lambda a: a[j], params["layers"])
            h = rms_norm(x, layer["ln"], cfg.norm_eps)
            y, st, cv = _mamba_prefill_states(layer["mamba"], h, cfg)
            x = x + y.astype(x.dtype)
            new_cache["ssm"] = new_cache["ssm"].at[j].set(st)
            new_cache["conv"] = new_cache["conv"].at[j].set(cv.astype(kv_dt))
    else:
        has_cross = cfg.is_encoder_decoder and cross_src is not None

        def period_body(x, layer_pp):
            kks, vvs, cks, cvs = [], [], [], []
            for j, kind in enumerate(kinds):
                layer = jax.tree_util.tree_map(lambda a: a[j], layer_pp)
                w = cfg.sliding_window if kind == LOCAL else 0
                x, kk, vv = _attn_prefill_kv(layer, x, cfg, opts, window=w,
                                             cross_src=cross_src)
                kks.append(pad_kv(kk))
                vvs.append(pad_kv(vv))
                if has_cross:
                    c = layer["cross"]
                    se = cross_src.shape[1]
                    cks.append((cross_src @ c["wk"]).reshape(
                        b, se, cfg.num_kv_heads, hd).astype(kv_dt))
                    cvs.append((cross_src @ c["wv"]).reshape(
                        b, se, cfg.num_kv_heads, hd).astype(kv_dt))
            ys = (jnp.stack(kks), jnp.stack(vvs))
            if has_cross:
                ys = ys + (jnp.stack(cks), jnp.stack(cvs))
            return x, ys

        if n_full:
            grouped = jax.tree_util.tree_map(
                lambda a: a[:n_full].reshape(n_full // period, period,
                                             *a.shape[1:]), params["layers"])
            x, ys = jax.lax.scan(period_body, x, grouped)
            new_cache["k"] = ys[0].reshape(n_full, *ys[0].shape[2:])
            new_cache["v"] = ys[1].reshape(n_full, *ys[1].shape[2:])
            if has_cross:
                new_cache["cross_k"] = ys[2].reshape(n_full, *ys[2].shape[2:])
                new_cache["cross_v"] = ys[3].reshape(n_full, *ys[3].shape[2:])
        for j in range(n_full, n):
            layer = jax.tree_util.tree_map(lambda a: a[j], params["layers"])
            kind = kinds[(j - n_full) % period]
            w = cfg.sliding_window if kind == LOCAL else 0
            x, kk, vv = _attn_prefill_kv(layer, x, cfg, opts, window=w,
                                         cross_src=cross_src)
            new_cache["k"] = new_cache["k"].at[j].set(pad_kv(kk))
            new_cache["v"] = new_cache["v"].at[j].set(pad_kv(vv))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)
    from .layers import mask_padded_logits_raw
    logits = mask_padded_logits_raw(logits, cfg.vocab_size)
    new_cache["pos"] = jnp.int32(s)
    return logits, new_cache


def _attn_prefill_kv(layer, x, cfg, opts, window: int = 0, cross_src=None):
    """Run a transformer block, returning (x, K, V) of the self-attention."""
    from .layers import apply_rotary, rotary_embedding
    from .transformer import transformer_block

    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    q, k, v = attn_mod.qkv_project(layer["attn"], h, cfg.num_heads,
                                   cfg.num_kv_heads, hd)
    sin, cos = rotary_embedding(jnp.arange(s)[None, :], hd, cfg.rope_theta)
    k_rot = apply_rotary(k, sin, cos)
    x, _ = transformer_block(layer, x, cfg, opts, window=window,
                             causal=True, cross_src=cross_src)
    return x, k_rot, v


def _mamba_prefill_states(mp, h, cfg):
    """Mamba block forward that also returns (final ssm state, conv state)."""
    bsz, s, _ = h.shape
    di, nh, hdim = cfg.ssm_d_inner, cfg.ssm_num_heads, cfg.ssm_head_dim
    gr, st = cfg.ssm_ngroups, cfg.ssm_state_dim
    from .layers import causal_conv1d, gated_rms_norm
    proj = h @ mp["in_proj"]
    z = proj[..., :di]
    xbc_pre = proj[..., di:di + cfg.ssm_conv_dim]
    dt = proj[..., di + cfg.ssm_conv_dim:]
    conv_state = xbc_pre[:, -(cfg.ssm_conv_width - 1):, :]
    xbc = jax.nn.silu(causal_conv1d(xbc_pre, mp["conv_w"], mp["conv_b"])
                      .astype(jnp.float32)).astype(h.dtype)
    xs = xbc[..., :di].reshape(bsz, s, nh, hdim)
    bmat = xbc[..., di:di + gr * st].reshape(bsz, s, gr, st)
    cmat = xbc[..., di + gr * st:].reshape(bsz, s, gr, st)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])
    a = -jnp.exp(mp["a_log"])
    y, final_state = ssm_mod.ssd_scan_ref(xs, dt, a, bmat, cmat,
                                          chunk=cfg.ssm_chunk)
    y = y + mp["d_skip"][None, None, :, None] * xs
    y = y.reshape(bsz, s, di)
    y = gated_rms_norm(y, z, mp["norm_scale"], cfg.norm_eps)
    return y @ mp["out_proj"], final_state, conv_state
