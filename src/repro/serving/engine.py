"""Batched serving runtime: request scheduler + uniform-step decode engine.

Requests arrive asynchronously; the scheduler packs them into fixed decode
slots (continuous batching with slot recycling).  Under the middleware, the
adaptation loop may swap the model variant or engine options between
decode steps — the engine re-jits lazily and keeps per-slot caches valid
only within a variant generation (the paper's "per-second adaptation
frequency" maps to a generation counter here).

Two decode paths share the scheduler:

* ``decode_mode="batched"`` (default) — ONE slot-stacked cache pytree of
  shape ``(slots, ...)`` and one jitted decode step per tick.  Per-slot
  sampling (temperature / top-k / PRNG key, living as leaves of the
  stacked cache) happens on device; slots with temperature 0 argmax
  exactly as the historical greedy engine did.  The tick does a single
  bulk device→host transfer of ``(slots,)`` tokens + positions, and the
  stacked cache is *donated* to the step so KV/SSM buffers update in
  place.  Inactive slots are masked (their outputs ignored), never
  skipped — the decode shape is constant, so one compiled program serves
  every occupancy.
* ``decode_mode="per_slot"`` — the original reference loop: one jit call
  and one host sync per active slot.  Kept for equivalence tests and as
  the benchmark baseline; token streams are bit-identical across modes.
* ``decode_mode="paged"`` — the slot-stacked step, but self-attention
  KV lives in a :class:`~repro.serving.paging.BlockPool` of fixed-size
  blocks instead of a dense ``max_seq`` row per slot.  Host-side block
  tables ride into the jitted step as runtime data (constant shape —
  occupancy, sharing and admission churn never recompile), prompt
  blocks are deduplicated by prefix chain hash (same-system-prompt
  admissions share prefill blocks, copy-on-write), and a full-prompt
  prefix cache re-admits an already-seen padded prompt without any
  prefill jit call.  Token streams are bit-identical to ``"batched"``.
  Two runtime options specialize this path (both live in
  ``RuntimeOptions``, hence in every CompileCache key and freeze/thaw
  fingerprint): ``paged_kernel=True`` decodes through the Pallas
  block-table attention kernel — attention reads KV straight from pool
  blocks, no gather-to-dense detour — and ``kv_dtype="int8"`` stores
  the pool int8 with per-row scales (~4x resident slots per device;
  greedy streams match the f32 pool on the differential corpus).

Any non-``per_slot`` engine can **freeze** an in-flight request into a
host-side :class:`~repro.serving.paging.FrozenRequest` blob (pages
densified + trimmed to ``pos``, sampling subtree, consumed count) and
**thaw** it later — on itself or on a fleet peer whose ``(cfg, opts,
params_version)`` fingerprint matches — with zero token loss and zero
re-prefill.  ``requeue_active`` and ``swap_model`` route through
freeze/thaw, so a same-weights swap no longer re-prefills; a
fingerprint mismatch falls back to the legacy requeue-with-re-prefill.

Admission is batched too (``prefill_mode="batched"``, the default on the
batched decode path): ``_admit`` drains every waiting request that shares
the head-of-line request's prompt bucket — the head is never skipped, so
a stream of same-bucket arrivals cannot starve an earlier waiter from
another bucket — and runs ONE ``(k, bucket)`` prefill jit call whose
results are scattered straight into their slots on device.  Burst sizes
are bucketed (powers of two capped at the slot count, short bursts padded
with throwaway rows), so mixed burst sizes reuse a handful of programs.
``prefill_mode="per_request"`` keeps the sequential reference admission
(one prefill jit per request), which the property suite pins the batched
path against.

Compiled programs come from a :class:`CompileCache` shared across engines
(process-global by default), so a fleet of same-platform engines compiles
each program once — ``ServeStats.recompiles`` counts only the programs
*this* engine's requests actually caused to be built.  Sampling options
never enter the cache key (they are runtime arrays), so engines with
heterogeneous per-slot policies still share every program.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Callable, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.act_quant import kv_dequant_rows
from repro.models.configs import ModelConfig
from repro.models.layers import Params, dtype_of
from repro.models.model import (init_cache, init_paged_pool,
                                init_paged_slot_cache, init_slot_cache)
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions
from repro.obs import NULL_RECORDER, MetricsRegistry

from .compile_cache import GLOBAL_COMPILE_CACHE, CompileCache, ServePrograms
from .paging import (DEFAULT_BLOCK_SIZE, TRASH_BLOCK, BlockPool,
                     FrozenRequest, PrefixCache, PrefixEntry,
                     block_hash_chain, blocks_needed)
from .sampling import DEFAULT_SAMPLING, SamplingOpts, request_key

DECODE_MODES = ("batched", "per_slot", "paged")
PREFILL_MODES = ("batched", "per_request")

# cache leaves whose sequence axis (axis 2 in batch=1 layout) is trimmed
# to ``pos`` when freezing — everything past pos is zero by construction
_SEQ_TRIM_LEAVES = ("k", "v", "shared_k", "shared_v")

# default observability pids: distinct per engine so two untagged
# engines sharing one TraceRecorder never interleave on one track
_ENGINE_SEQ = itertools.count()


@dataclass
class Request:
    """One generation request in the serving queue.  ``rid`` is the
    caller's identifier (echoed back, never interpreted — but folded into
    the request's PRNG key, so reuse rids deliberately); ``prompt`` is
    the int32 token array to prefill; ``max_new_tokens`` bounds the
    generated continuation (the prefill's first sampled token counts
    toward it).  ``sampling`` overrides the engine's default
    :class:`SamplingOpts` for this request (``None`` inherits it).  The
    engine fills the remaining fields: ``generated`` accumulates sampled
    tokens, ``done`` flips when the budget or ``max_seq`` is reached, and
    the ``*_s`` stamps record queue/latency milestones on the caller's
    clock (``arrived_s`` is stamped at :meth:`ServingEngine.submit` when
    the caller leaves it 0, ``first_token_s`` when the prefill's token
    lands on the host)."""
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    arrived_s: float = 0.0
    sampling: Optional[SamplingOpts] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    # set when the request carries serialized in-flight state (a requeue,
    # preemption or migration); a compatible engine thaws it with zero
    # re-prefill, an incompatible one falls back to re-prefilling
    # prompt+generated (the legacy requeue contract)
    frozen: Optional[FrozenRequest] = None


class ServeStats:
    """Counters for one engine's lifetime: decode ``steps`` taken,
    ``tokens_out`` emitted (prefill + decode), ``prefills`` — *requests*
    prefilled — and ``prefill_calls`` — prefill *jit invocations*; a
    burst of k same-bucket admissions is k prefills but 1 prefill call.
    ``sampled_tokens`` counts tokens drawn stochastically (from requests
    whose effective :class:`SamplingOpts` temperature is > 0; the rest
    are greedy).  ``recompiles`` is the number of jitted programs *this*
    engine's requests caused to be built (0 on an engine that found
    everything in a warm :class:`CompileCache`, which is how fleet-wide
    program sharing is asserted).

    Since the observability layer landed this is a **view** over the
    engine's :class:`~repro.obs.metrics.MetricsRegistry` — each
    attribute reads/writes the like-named ``engine.*`` counter, so the
    historical ``eng.stats.steps`` surface and the registry can never
    disagree.  A standalone ``ServeStats()`` owns a private registry."""

    _COUNTERS = {"steps": "engine.steps",
                 "tokens_out": "engine.tokens_out",
                 "prefills": "engine.prefills",
                 "prefill_calls": "engine.prefill_calls",
                 "sampled_tokens": "engine.sampled_tokens",
                 "recompiles": "engine.recompiles",
                 "oom_events": "engine.oom_events",
                 "requeues": "engine.requeues",
                 "freezes": "engine.freezes",
                 "thaws": "engine.thaws"}

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        for name in self._COUNTERS.values():
            self.metrics.counter(name)

    def _get(self, attr: str) -> int:
        return self.metrics.counter(self._COUNTERS[attr]).value

    def _set(self, attr: str, v: int) -> None:
        self.metrics.counter(self._COUNTERS[attr]).value = v

    steps = property(lambda s: s._get("steps"),
                     lambda s, v: s._set("steps", v))
    tokens_out = property(lambda s: s._get("tokens_out"),
                          lambda s, v: s._set("tokens_out", v))
    prefills = property(lambda s: s._get("prefills"),
                        lambda s, v: s._set("prefills", v))
    prefill_calls = property(lambda s: s._get("prefill_calls"),
                             lambda s, v: s._set("prefill_calls", v))
    sampled_tokens = property(lambda s: s._get("sampled_tokens"),
                              lambda s, v: s._set("sampled_tokens", v))
    recompiles = property(lambda s: s._get("recompiles"),
                          lambda s, v: s._set("recompiles", v))
    oom_events = property(lambda s: s._get("oom_events"),
                          lambda s, v: s._set("oom_events", v))
    requeues = property(lambda s: s._get("requeues"),
                        lambda s, v: s._set("requeues", v))
    freezes = property(lambda s: s._get("freezes"),
                       lambda s, v: s._set("freezes", v))
    thaws = property(lambda s: s._get("thaws"),
                     lambda s, v: s._set("thaws", v))

    @property
    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)

    def __repr__(self) -> str:
        fields = ", ".join(f"{a}={self._get(a)}" for a in self._COUNTERS)
        return f"ServeStats({fields})"


class ServingEngine:
    """Slot-based continuous batching over the unified decode API.

    ``slots`` fixes the decode batch width (requests beyond it queue);
    ``max_seq`` bounds prompt+generation length per slot.
    ``decode_mode`` selects the decode path: ``"batched"`` (default)
    advances every slot in one vmapped, cache-donating jit call with
    on-device per-slot sampling and a single bulk transfer per tick,
    while ``"per_slot"`` is the reference loop — one jit call and host
    sync per active slot — kept for equivalence tests and benchmarking
    (token streams are bit-identical across modes).  ``prefill_mode``
    selects the admission path: ``"batched"`` (default under batched
    decode) packs same-bucket waiting requests into one burst prefill
    call; ``"per_request"`` is the sequential reference (and the only
    path under ``decode_mode="per_slot"``, which has no stacked cache to
    scatter into).  ``sampling`` is the default :class:`SamplingOpts`
    for requests that don't carry their own — the zero default is greedy,
    bit-identical to the pre-sampling engine.  ``compile_cache`` /
    ``compile_domain`` wire the engine into cross-engine program
    sharing: programs are keyed on ``(cfg, opts, slots, max_seq,
    domain)``, and ``compile_domain`` namespaces the key by compile
    target (platform/ISA) since a pixel_6 cannot reuse a jetson's
    binaries — the fleet controller passes each device's
    :attr:`DeviceSpec.compile_domain` here."""

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int = 8,
                 max_seq: int = 512, opts: RuntimeOptions = DEFAULT_OPTIONS,
                 decode_mode: str = "batched",
                 prefill_mode: str = "batched",
                 sampling: SamplingOpts = DEFAULT_SAMPLING,
                 compile_cache: Optional[CompileCache] = None,
                 compile_domain: str = "",
                 recorder=NULL_RECORDER,
                 pid: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 pool_blocks: Optional[int] = None,
                 prefix_entries: int = 32,
                 params_version: Optional[int] = None):
        if decode_mode not in DECODE_MODES:
            raise ValueError(f"unknown decode_mode {decode_mode!r}; "
                             f"expected one of {DECODE_MODES}")
        if prefill_mode not in PREFILL_MODES:
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}; "
                             f"expected one of {PREFILL_MODES}")
        if decode_mode == "paged":
            # every prompt bucket (powers of two from 16, capped at
            # max_seq) must be block-aligned so prompts fill whole blocks
            # and decode always writes a private tail block
            if block_size < 1 or block_size & (block_size - 1) \
                    or block_size > 16:
                raise ValueError(f"block_size {block_size} must be a "
                                 "power of two <= 16")
            if max_seq % block_size:
                raise ValueError(f"block_size {block_size} must divide "
                                 f"max_seq {max_seq}")
            per_slot_blocks = max_seq // block_size
            if pool_blocks is None:
                # dense-equivalent capacity plus the trash block; prefix
                # sharing only ever *reduces* usage below this
                pool_blocks = slots * per_slot_blocks + 1
            if pool_blocks < per_slot_blocks + 1:
                raise ValueError(f"pool_blocks {pool_blocks} cannot hold "
                                 "one full-length request (need "
                                 f"{per_slot_blocks + 1})")
        elif opts.kv_dtype != "auto" or opts.paged_kernel:
            raise ValueError("kv_dtype/paged_kernel are paged-pool options; "
                             f"decode_mode={decode_mode!r} keeps its dense "
                             "cache in kv_cache_dtype")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.opts = opts
        self.decode_mode = decode_mode
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        self.prefix_entries = prefix_entries
        # the freeze/thaw compatibility fingerprint: thawing serialized KV
        # against different weights would silently resume a stale stream,
        # so blobs carry (cfg, opts, params_version) and only thaw when
        # all three match.  Engines sharing a params pytree share its id;
        # callers juggling transient params should pass one explicitly.
        self.params_version = (params_version if params_version is not None
                               else id(params))
        # the per-slot reference loop has no stacked cache to scatter a
        # burst into — it always admits per request; the paged path only
        # has burst admission (its per-request path is the k=1 burst)
        if decode_mode == "per_slot":
            self.prefill_mode = "per_request"
        elif decode_mode == "paged":
            self.prefill_mode = "batched"
        else:
            self.prefill_mode = prefill_mode
        self.sampling = sampling
        self.compile_cache = (compile_cache if compile_cache is not None
                              else GLOBAL_COMPILE_CACHE)
        self.compile_domain = compile_domain
        # observability: recorder defaults to the no-op singleton (hot
        # paths guard on ``recorder.enabled``); the pid names this
        # engine's track in exported traces (the fleet controller passes
        # the device id).  The metrics registry backs ``stats`` and the
        # step-time EWMA/histogram — a shared registry makes a fleet's
        # engines aggregate into one namespace.
        self.recorder = recorder
        self.pid = pid if pid is not None else f"engine{next(_ENGINE_SEQ)}"
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = ServeStats(self.metrics)
        self._ewma = self.metrics.ewma("engine.step_time_s", alpha=0.2)
        self._step_hist = self.metrics.histogram("engine.step_time_hist_s")
        self._queue: Deque[Request] = deque()
        self._active: List[Optional[Request]] = [None] * slots
        self.generation = 0
        self._programs: ServePrograms = self._bind_programs()
        self._reset_caches()
        # telemetry: wall-time of recent steps (bounded — engines are
        # long-lived); optional sink called with (step_seconds,
        # tokens_emitted, generation) — the back-end→front-end feedback
        # channel the fleet's TelemetryStore subscribes to.
        self.step_times: Deque[float] = deque(maxlen=2048)
        self.on_step: Optional[Callable[[float, int, int], None]] = None
        # SLO feed: when a tracker is installed (the fleet controller
        # shares its SLOTracker here), the engine reports TTFT at each
        # request's true first token and per-token decode time per step.
        # None (the default) keeps the hot path at one attribute load.
        self.slo = None
        # fault plane: injected OOM failures pending at admission, and
        # the exponential admission backoff they trigger (in steps).
        # All zeros on a healthy engine — the admission hot path is
        # untouched unless a fault is actually injected.
        self._oom_pending = 0
        self._admit_holdoff = 0
        self._oom_backoff = 0
        self.oom_backoff_cap = 8

    # ------------------------------------------------------------ programs --
    def _note_compile(self, what: str, **detail) -> None:
        self.stats.recompiles += 1
        if self.recorder.enabled:
            self.recorder.instant("engine.compile", pid=self.pid,
                                  tid="engine", cat="engine",
                                  args={"what": what, **detail})

    def _bind_programs(self) -> ServePrograms:
        entry, fresh = self.compile_cache.entry_for(
            self.cfg, self.opts, self.slots, self.max_seq,
            self.compile_domain)
        if fresh:
            self._note_compile("programs", generation=self.generation)
        return entry

    def _prefill_fn(self, bucket: int) -> Callable:
        fn, fresh = self._programs.prefill(bucket)
        if fresh:
            self._note_compile("prefill", bucket=bucket)
        return fn

    def _prefill_batch_fn(self, bucket: int, k: int) -> Callable:
        fn, fresh = self._programs.prefill_batch(bucket, k)
        if fresh:
            self._note_compile("prefill_batch", bucket=bucket, k=k)
        return fn

    def _paged_decode_fn(self) -> Callable:
        fn, fresh = self._programs.paged_decode(self.pool_blocks,
                                                self.block_size)
        if fresh:
            self._note_compile("paged_decode", pool_blocks=self.pool_blocks,
                               block_size=self.block_size)
        return fn

    def _paged_prefill_fn(self, bucket: int, k: int) -> Callable:
        fn, fresh = self._programs.paged_prefill_batch(
            bucket, k, self.pool_blocks, self.block_size)
        if fresh:
            self._note_compile("paged_prefill_batch", bucket=bucket, k=k)
        return fn

    def _paged_admit_fn(self) -> Callable:
        fn, fresh = self._programs.paged_admit()
        if fresh:
            self._note_compile("paged_admit")
        return fn

    def _thaw_scatter_fn(self, nblk: int) -> Callable:
        fn, fresh = self._programs.thaw_scatter(nblk, self.pool_blocks,
                                                self.block_size)
        if fresh:
            self._note_compile("thaw_scatter", nblk=nblk)
        return fn

    def _copy_block_fn(self) -> Callable:
        fn, fresh = self._programs.copy_block(self.pool_blocks,
                                              self.block_size)
        if fresh:
            self._note_compile("copy_block")
        return fn

    def _reset_caches(self) -> None:
        if self.decode_mode == "batched":
            self._cache = init_slot_cache(self.cfg, self.slots, self.max_seq,
                                          self.opts)
        elif self.decode_mode == "paged":
            self._cache = init_paged_slot_cache(self.cfg, self.slots,
                                                self.max_seq, self.opts)
            self._pool = init_paged_pool(self.cfg, self.pool_blocks,
                                         self.block_size, self.opts)
            self._blocks = BlockPool(self.slots, self.pool_blocks,
                                     self.block_size, self.max_seq)
            self._prefix = PrefixCache(self.prefix_entries)
            # host-authoritative next-write position per slot (mirrors the
            # device ``pos`` leaf; drives tail-block growth + freezing)
            self._slot_pos = [0] * self.slots
            # admission sequence per slot: preemption under pool pressure
            # evicts the youngest admission first
            self._slot_seq = [0] * self.slots
            self._admit_seq = itertools.count(1)
            self._update_block_gauges()
        else:
            self._caches = [init_cache(self.cfg, 1, self.max_seq, self.opts)
                            for _ in range(self.slots)]

    def _update_block_gauges(self) -> None:
        self.metrics.gauge("engine.blocks_used").set(self._blocks.used_blocks)
        self.metrics.gauge("engine.blocks_free").set(self._blocks.free_blocks)
        self.metrics.gauge("engine.blocks_shared").set(
            self._blocks.shared_blocks)

    @property
    def block_pool(self) -> Optional[BlockPool]:
        """The host-side block allocator (``None`` off the paged path) —
        exposed so tests and benches can assert refcounts/sharing."""
        return self._blocks if self.decode_mode == "paged" else None

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        if not req.arrived_s:
            req.arrived_s = time.perf_counter()
        if self.recorder.enabled:
            # stamped with the exact arrival float, so span-derived TTFT
            # (first_token − queued) equals the legacy subtraction bit
            # for bit
            self.recorder.instant("req.queued", pid=self.pid, tid="queue",
                                  cat="request", wall_s=req.arrived_s,
                                  args={"rid": req.rid,
                                        "prompt_len": len(req.prompt)})
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        """True while any request is in flight or waiting."""
        return any(r is not None for r in self._active) or bool(self._queue)

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _k_bucket(self, k: int) -> int:
        """Round a burst size up to its program bucket: powers of two,
        capped at the slot count (mixed burst sizes then share a handful
        of compiled admission programs)."""
        b = 1
        while b < k:
            b *= 2
        return min(b, self.slots)

    def _sampling_of(self, req: Request) -> SamplingOpts:
        return req.sampling if req.sampling is not None else self.sampling

    # ------------------------------------------------------------ stepping --
    def _gather_burst(self, limit: int):
        """Pop the head request plus every same-bucket waiter behind it
        (up to ``limit``) off the queue.  The head anchors the bucket, so
        an earlier waiter from another bucket is always admitted before
        anything behind it — later same-bucket arrivals can share its
        burst's free slots but never displace it.  Budget-spent requests
        encountered on the way complete inline; passed-over requests keep
        their relative order at the queue head.  Returns ``(bucket,
        requests)``."""
        head = self._queue.popleft()
        bucket = self._bucket(len(head.prompt))
        batch = [head]
        if limit > 1:
            kept: List[Request] = []
            while self._queue and len(batch) < limit:
                r = self._queue.popleft()
                if len(r.generated) >= r.max_new_tokens:
                    r.done = True
                    continue
                if r.frozen is not None:
                    # frozen state thaws (or falls back) only at the queue
                    # head — bursting it through prefill here would drop
                    # its generated suffix from the bucket computation
                    kept.append(r)
                    continue
                if self._bucket(len(r.prompt)) == bucket:
                    batch.append(r)
                else:
                    kept.append(r)
            for r in reversed(kept):
                self._queue.appendleft(r)
        return bucket, batch

    def _emit_first(self, req: Request, token: int, stamp: float,
                    free: List[int], slot: int) -> bool:
        """Book-keep a request's prefill token; returns True when the
        request stays active in ``slot`` (False = budget completed at
        prefill, slot returned to the free pool)."""
        req.generated.append(token)
        if req.first_token_s is None:
            # keep the original stamp across swap re-admissions: TTFT is
            # submit→first token, not submit→latest re-prefill
            req.first_token_s = stamp
            if self.slo is not None:
                self.slo.observe("ttft", stamp - req.arrived_s)
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        if self._sampling_of(req).temperature > 0:
            self.stats.sampled_tokens += 1
        rec = self.recorder
        if rec.enabled:
            # one first_token instant per *admission* (a swap re-admission
            # emits another, with the re-prefill's stamp — first_token_s
            # above keeps the original), one slot-occupancy span begin
            tid = f"slot{slot}"
            rec.instant("req.first_token", pid=self.pid, tid=tid,
                        cat="request", wall_s=stamp,
                        args={"rid": req.rid, "token": token})
            rec.begin("req.slot", pid=self.pid, tid=tid, cat="request",
                      wall_s=stamp, args={"rid": req.rid})
        if len(req.generated) >= req.max_new_tokens:
            req.done = True          # prefill token completed the budget
            if rec.enabled:
                rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                        cat="request", wall_s=stamp,
                        args={"rid": req.rid, "reason": "done_at_prefill",
                              "tokens": len(req.generated)})
            free.append(slot)
            return False
        self._active[slot] = req
        return True

    def _truncate(self, req: Request, bucket: int) -> None:
        if len(req.prompt) > bucket:
            # prompt exceeds max_seq (e.g. a swap re-queue whose prompt
            # grew by the generated prefix): keep the newest context
            req.prompt = req.prompt[-bucket:]

    def _admit_burst(self, batch: List[Request], bucket: int,
                     free: List[int]) -> None:
        """ONE jitted call admits the whole burst: stacked ``(k, bucket)``
        prompts are prefilled together and every row's cache + sampling
        state is scattered into its slot on device.  Bursts smaller than
        their k-bucket are padded with leading throwaway rows aimed at the
        first real slot — written first, overwritten by the real row."""
        k = len(batch)
        kb = self._k_bucket(k)
        pad = kb - k
        slots_for = [free.pop(0) for _ in range(k)]
        toks = np.zeros((kb, bucket), np.int32)
        keys = np.zeros((kb, 2), np.uint32)
        temps = np.zeros((kb,), np.float32)
        top_ks = np.zeros((kb,), np.int32)
        slot_ids = np.full((kb,), slots_for[0], np.int32)
        for i, req in enumerate(batch):
            self._truncate(req, bucket)
            row = pad + i
            toks[row, bucket - len(req.prompt):] = req.prompt  # left-pad
            s = self._sampling_of(req)
            keys[row] = request_key(s.seed, req.rid, len(req.generated))
            temps[row] = s.temperature
            top_ks[row] = s.top_k
            slot_ids[row] = slots_for[i]
        if self.recorder.enabled:
            self.recorder.begin("engine.prefill", pid=self.pid,
                                tid="engine", cat="engine",
                                args={"bucket": bucket, "k": k,
                                      "k_bucket": kb,
                                      "rids": [r.rid for r in batch]})
        if self.decode_mode == "paged":
            nblk = bucket // self.block_size
            # pad rows scatter into the trash block; real rows into fresh
            # private blocks (the pool cap in _admit_paged_head guarantees
            # the allocation succeeds)
            dest = np.zeros((kb, nblk), np.int32)
            for i, req in enumerate(batch):
                ids = self._blocks.alloc(nblk)
                dest[pad + i] = ids
                for j, b in enumerate(ids):
                    self._blocks.assign(slots_for[i], j, b)
            fn = self._paged_prefill_fn(bucket, kb)
            first, last, self._cache, self._pool = fn(
                self.params, self._cache, self._pool, jnp.asarray(toks),
                jnp.asarray(slot_ids), jnp.asarray(keys),
                jnp.asarray(temps), jnp.asarray(top_ks),
                jnp.asarray(dest))
        else:
            last = None
            fn = self._prefill_batch_fn(bucket, kb)
            first, self._cache = fn(self.params, self._cache,
                                    jnp.asarray(toks), jnp.asarray(slot_ids),
                                    jnp.asarray(keys), jnp.asarray(temps),
                                    jnp.asarray(top_ks))
        first = jax.device_get(first)
        self.stats.prefill_calls += 1
        stamp = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.end("engine.prefill", pid=self.pid, tid="engine",
                              cat="engine", wall_s=stamp)
        for i, req in enumerate(batch):
            slot = slots_for[i]
            if self.decode_mode == "paged":
                # dedup freshly written prompt blocks against live blocks
                # holding the same padded-prefix chain hash, then cache
                # the whole prefill for prefix-skip re-admission
                padded = toks[pad + i]
                self._blocks.dedup_slot_prefix(
                    slot, block_hash_chain(padded, self.block_size,
                                           salt=self.params_version))
                self._slot_pos[slot] = bucket
                self._slot_seq[slot] = next(self._admit_seq)
                if self.prefix_entries > 0:
                    self._prefix.insert(
                        self._prefix.key_of(padded, self.params_version),
                        PrefixEntry(
                            block_ids=tuple(
                                int(b) for b in
                                self._blocks.tables[slot, :nblk]),
                            logits_row=last[pad + i],
                            leaves=self._snapshot_slot_leaves(slot),
                            pos=bucket),
                        self._blocks)
            alive = self._emit_first(req, int(first[pad + i]), stamp, free,
                                     slot)
            if self.decode_mode == "paged":
                if not alive:
                    # budget completed at prefill: the slot's references
                    # go, but a cached prefix entry keeps the blocks live
                    self._blocks.release_slot(slot)
                self._update_block_gauges()

    def _snapshot_slot_leaves(self, slot: int) -> dict:
        """Host copies of one slot's non-KV, non-sampling cache leaves
        (batch=1 layout) — the state a prefix-cache re-admission must
        restore alongside the shared blocks."""
        return {name: np.asarray(jax.device_get(leaf[slot]))
                for name, leaf in self._cache.items() if name != "sample"}

    def _admit_from_prefix(self, req: Request, entry: PrefixEntry,
                           free: List[int]) -> None:
        """Admit a request whose padded prompt hit the prefix cache: no
        prefill jit call at all.  Shared blocks are increfed into the
        slot's table, the cached non-KV leaves and the request's own
        sampling state are written to its slot, and the first token is
        sampled from the cached last-position logits row — bit-identical
        to what a real prefill would have produced."""
        slot = free.pop(0)
        for j, bid in enumerate(entry.block_ids):
            self._blocks.incref(bid)
            self._blocks.assign(slot, j, bid)
        s = self._sampling_of(req)
        key = jnp.asarray(request_key(s.seed, req.rid, len(req.generated)))
        temp = jnp.float32(s.temperature)
        top_k = jnp.int32(s.top_k)
        tok, key = self._programs.sample_first(entry.logits_row, key, temp,
                                               top_k)
        row = {name: jnp.asarray(arr) for name, arr in entry.leaves.items()}
        self._cache = self._paged_admit_fn()(self._cache, row,
                                             jnp.int32(slot), key, temp,
                                             top_k)
        self._slot_pos[slot] = entry.pos
        self._slot_seq[slot] = next(self._admit_seq)
        stamp = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.instant("engine.prefix_hit", pid=self.pid,
                                  tid="engine", cat="engine", wall_s=stamp,
                                  args={"rid": req.rid,
                                        "blocks": len(entry.block_ids)})
        if not self._emit_first(req, int(tok), stamp, free, slot):
            self._blocks.release_slot(slot)
        self._update_block_gauges()

    def _admit_one(self, req: Request, free: List[int]) -> None:
        """Sequential reference admission: one prefill jit call for this
        request, its first token drawn by the same ``sample_logits`` the
        batched paths use."""
        slot = free.pop(0)
        bucket = self._bucket(len(req.prompt))
        self._truncate(req, bucket)
        if self.recorder.enabled:
            self.recorder.begin("engine.prefill", pid=self.pid,
                                tid="engine", cat="engine",
                                args={"bucket": bucket, "k": 1,
                                      "rids": [req.rid]})
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - len(req.prompt):] = req.prompt  # left-pad
        cache = init_cache(self.cfg, 1, self.max_seq, self.opts)
        logits, cache = self._prefill_fn(bucket)(
            self.params, cache, jnp.asarray(toks))
        self.stats.prefill_calls += 1
        s = self._sampling_of(req)
        key = jnp.asarray(request_key(s.seed, req.rid, len(req.generated)))
        temp = jnp.float32(s.temperature)
        top_k = jnp.int32(s.top_k)
        tok, key = self._programs.sample_first(logits[0, -1], key, temp,
                                               top_k)
        nxt = int(tok)
        stamp = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.end("engine.prefill", pid=self.pid, tid="engine",
                              cat="engine", wall_s=stamp)
        if not self._emit_first(req, nxt, stamp, free, slot):
            return
        if self.decode_mode == "batched":
            # the stacked side is donated: the slot write is in place
            self._cache = self._programs.admit_slot(
                self._cache, cache, jnp.int32(slot), key, temp, top_k)
        else:
            cache["sample"] = {"key": key, "temp": temp, "top_k": top_k}
            self._caches[slot] = cache

    def inject_oom(self, n: int = 1) -> None:
        """Fault injection: the next ``n`` admission attempts fail as if
        cache allocation OOMed.  The engine responds the way a real
        admission controller would — the request stays queued (zero
        token loss) and admission backs off exponentially (doubling
        hold-off steps, capped at ``oom_backoff_cap``) before retrying,
        so a memory-pressured engine stops hammering the allocator."""
        self._oom_pending += max(int(n), 0)

    def _admit(self) -> None:
        if self._admit_holdoff > 0:
            self._admit_holdoff -= 1
            return
        free = [s for s in range(self.slots) if self._active[s] is None]
        if self._oom_pending > 0 and free and self._queue:
            # injected OOM: this admission attempt fails, the head stays
            # queued untouched, and we back off before trying again
            self._oom_pending -= 1
            self.stats.oom_events += 1
            self._oom_backoff = min(max(2 * self._oom_backoff, 1),
                                    self.oom_backoff_cap)
            self._admit_holdoff = self._oom_backoff
            if self.recorder.enabled:
                self.recorder.instant(
                    "engine.oom", pid=self.pid, tid="engine",
                    cat="engine",
                    args={"backoff_steps": self._admit_holdoff,
                          "queued": len(self._queue)})
            return
        admitted = False
        while free and self._queue:
            head = self._queue[0]
            if len(head.generated) >= head.max_new_tokens:
                # re-queued after a swap with its budget already spent (or
                # submitted with max_new_tokens=0): emitting another prefill
                # token would overshoot the budget and double-count it.
                self._queue.popleft()
                head.done = True
                continue
            if head.frozen is not None:
                if self.can_thaw(head.frozen):
                    if not self._thaw_capacity_ok(head.frozen):
                        # pool backpressure: decode frees blocks.  A thaw
                        # must never *preempt* to fit — a preempted
                        # victim at the head would thaw by preempting
                        # right back, an admission livelock
                        break
                    self._queue.popleft()
                    self._thaw_into_slot(head, free.pop(0))
                    admitted = True
                    continue
                # fingerprint mismatch: drop the blob and re-prefill
                # prompt+generated (the legacy zero-token-loss requeue)
                self._discard_frozen(head)
            if self.decode_mode == "paged":
                if self._admit_paged_head(head, free):
                    admitted = True
                    continue
                break               # pool exhausted: wait for decode frees
            if self.prefill_mode == "batched":
                bucket, batch = self._gather_burst(len(free))
                self._admit_burst(batch, bucket, free)
            else:
                self._queue.popleft()
                self._admit_one(head, free)
            admitted = True
        if admitted:
            self._oom_backoff = 0     # a successful admission heals

    def _admit_paged_head(self, head: Request, free: List[int]) -> bool:
        """Admit the head request (plus any same-bucket burst) into the
        paged cache.  Returns False when the pool cannot cover the head's
        prompt blocks even after evicting cached prefixes — admission
        then waits for decode to free blocks (backpressure, not loss)."""
        bucket = self._bucket(len(head.prompt))
        nblk = bucket // self.block_size
        entry = self._prefix.lookup(
            self._prefix.key_of(self._padded_prompt(head, bucket),
                                self.params_version))
        if entry is not None:
            self._queue.popleft()
            self._admit_from_prefix(head, entry, free)
            return True
        if self._blocks.free_blocks < nblk:
            self._prefix.evict_for_blocks(nblk, self._blocks)
        max_k = self._blocks.free_blocks // nblk
        if max_k == 0:
            return False
        bucket, batch = self._gather_burst(min(len(free), max_k))
        self._admit_burst(batch, bucket, free)
        return True

    def _padded_prompt(self, req: Request, bucket: int) -> np.ndarray:
        """The left-padded prompt row exactly as prefill sees it — the
        prefix-sharing unit (KV content is a pure function of it)."""
        row = np.zeros(bucket, np.int32)
        prompt = req.prompt[-bucket:] if len(req.prompt) > bucket \
            else req.prompt
        row[bucket - len(prompt):] = prompt
        return row

    def _decode_batched(self) -> int:
        if not any(r is not None for r in self._active):
            return 0
        tokens = np.zeros(self.slots, np.int32)
        sampling = False
        for slot, req in enumerate(self._active):
            if req is not None:
                tokens[slot] = req.generated[-1]
                sampling = sampling or \
                    self._sampling_of(req).temperature > 0
        # all-greedy ticks take the pure-argmax program: no per-slot
        # argsort/categorical work selected away by a where — the default
        # greedy engine keeps its historical hot-path cost.  Outputs are
        # bit-identical either way, so mixed workloads can alternate.
        step_fn = (self._programs.decode if sampling
                   else self._programs.decode_greedy)
        nxt, pos, self._cache = step_fn(
            self.params, self._cache, jnp.asarray(tokens))
        return self._bookkeep_decode(nxt, pos)

    def _bookkeep_decode(self, nxt, pos) -> int:
        """Shared post-step bookkeeping for the batched and paged decode
        paths: one bulk device→host transfer, per-slot token append,
        finish detection and trace emission."""
        nxt, pos = jax.device_get((nxt, pos))   # one bulk transfer per tick
        paged = self.decode_mode == "paged"
        emitted = 0
        freed_blocks = False
        rec = self.recorder
        stamp = time.perf_counter() if rec.enabled else 0.0
        for slot, req in enumerate(self._active):
            if req is None:      # masked slot: decoded, output ignored
                continue
            req.generated.append(int(nxt[slot]))
            emitted += 1
            if paged:
                self._slot_pos[slot] = int(pos[slot])
            if self._sampling_of(req).temperature > 0:
                self.stats.sampled_tokens += 1
            if rec.enabled:
                rec.instant("req.decode", pid=self.pid, tid=f"slot{slot}",
                            cat="request", wall_s=stamp,
                            args={"rid": req.rid, "token": int(nxt[slot])})
            if len(req.generated) >= req.max_new_tokens \
                    or int(pos[slot]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
                if paged:
                    self._blocks.release_slot(slot)
                    freed_blocks = True
                if rec.enabled:
                    rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                            cat="request", wall_s=stamp,
                            args={"rid": req.rid, "reason": "finished",
                                  "tokens": len(req.generated)})
        if freed_blocks:
            self._update_block_gauges()
        return emitted

    # ------------------------------------------------------ paged decode --
    def _alloc_blocks_reclaiming(self, n: int,
                                 keep_slot: Optional[int] = None
                                 ) -> Optional[List[int]]:
        """Allocate ``n`` blocks, reclaiming under pressure: first evict
        cached prefix entries (LRU), then preempt the youngest-admitted
        active slot (freeze → requeue head, zero token loss) — never
        ``keep_slot``, the slot the allocation is for."""
        ids = self._blocks.alloc(n)
        while ids is None:
            if self._prefix.evict_for_blocks(n, self._blocks) == 0:
                victims = [s for s, r in enumerate(self._active)
                           if r is not None and s != keep_slot]
                if not victims:
                    return None
                victim = max(victims, key=lambda s: self._slot_seq[s])
                req = self._active[victim]
                req.frozen = self._freeze_slot(victim, reason="preempt")
                self._queue.appendleft(req)
                self.stats.requeues += 1
            ids = self._blocks.alloc(n)
        return ids

    def _ensure_tail_blocks(self) -> None:
        """Pre-decode growth pass: every active slot must own a private
        block for the row this step writes.  Buckets are block-aligned,
        so growth happens exactly at block boundaries; the copy-on-write
        branch guards the shared-block invariant (a shared block is
        never written in place)."""
        bs = self.block_size
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            idx = self._slot_pos[slot] // bs
            if idx >= self._blocks.blocks_per_slot:
                continue             # finishes at the max_seq bound
            bid = int(self._blocks.tables[slot, idx])
            if bid != TRASH_BLOCK and self._blocks.refs[bid] <= 1:
                continue             # private tail already in place
            ids = self._alloc_blocks_reclaiming(1, keep_slot=slot)
            if ids is None:          # only this slot is active and the
                continue             # pool is drained; write lands in
                                     # trash and the request requeues
            if bid != TRASH_BLOCK:   # copy-on-write off a shared block
                self._pool = self._copy_block_fn()(
                    self._pool, jnp.int32(bid), jnp.int32(ids[0]))
                self._blocks.decref(bid)
            self._blocks.assign(slot, idx, ids[0])
            self._update_block_gauges()

    def _decode_paged(self) -> int:
        if not any(r is not None for r in self._active):
            return 0
        self._ensure_tail_blocks()
        tokens = np.zeros(self.slots, np.int32)
        for slot, req in enumerate(self._active):
            if req is not None:
                tokens[slot] = req.generated[-1]
        # block tables are runtime data: constant (slots, max_seq/bs)
        # shape, so occupancy/sharing churn reuses one compiled program
        nxt, pos, self._cache, self._pool = self._paged_decode_fn()(
            self.params, self._cache, self._pool, jnp.asarray(tokens),
            jnp.asarray(self._blocks.tables))
        return self._bookkeep_decode(nxt, pos)

    def _decode_per_slot(self) -> int:
        emitted = 0
        rec = self.recorder
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            tok = jnp.asarray(req.generated[-1], jnp.int32)
            nxt, cache = self._programs.sample_ref(
                self.params, self._caches[slot], tok)
            self._caches[slot] = cache
            req.generated.append(int(nxt))
            emitted += 1
            if self._sampling_of(req).temperature > 0:
                self.stats.sampled_tokens += 1
            if rec.enabled:
                rec.instant("req.decode", pid=self.pid, tid=f"slot{slot}",
                            cat="request",
                            args={"rid": req.rid, "token": int(nxt)})
            if len(req.generated) >= req.max_new_tokens \
                    or int(cache["pos"]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
                if rec.enabled:
                    rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                            cat="request",
                            args={"rid": req.rid, "reason": "finished",
                                  "tokens": len(req.generated)})
        return emitted

    def step(self) -> int:
        """One engine tick: admit waiting requests, decode one token for
        every active slot.  Returns number of tokens emitted."""
        self._admit()
        # time only the decode sweep: prefill/compile costs would otherwise
        # masquerade as decode-step latency in the telemetry channel
        rec = self.recorder
        t0 = time.perf_counter()
        if rec.enabled:
            rec.begin("engine.step", pid=self.pid, tid="engine",
                      cat="engine", wall_s=t0,
                      args={"generation": self.generation})
        if self.decode_mode == "batched":
            emitted = self._decode_batched()
        elif self.decode_mode == "paged":
            emitted = self._decode_paged()
        else:
            emitted = self._decode_per_slot()
        self.stats.steps += 1
        self.stats.tokens_out += emitted
        t1 = time.perf_counter()
        dt = t1 - t0
        self.step_times.append(dt)
        self._ewma.update(dt)
        self._step_hist.observe(dt)
        if rec.enabled:
            rec.end("engine.step", pid=self.pid, tid="engine",
                    cat="engine", wall_s=t1, args={"emitted": emitted})
        if self.slo is not None and emitted:
            # every active slot advanced one token this step, so the
            # step wall time is each of those tokens' inter-token time
            self.slo.observe("tpot", dt, n=emitted)
        if self.on_step is not None:
            self.on_step(dt, emitted, self.generation)
        return emitted

    @property
    def step_time_ewma_s(self) -> Optional[float]:
        """Smoothed recent decode-step wall time (seconds), or ``None``
        before the first step.  This is the step-timing hook the fleet's
        event scheduler consults: an engine-backed device's next wake is
        its envelope period *plus* ``steps_per_tick × step_time_ewma_s``,
        so devices whose engines slow down under load automatically tick
        less often.  A view over the registry's ``engine.step_time_s``
        EWMA gauge (``alpha=0.2`` reproduces the historical
        ``0.8·prev + 0.2·dt`` update bit for bit)."""
        return self._ewma.value

    def drain(self, max_steps: int = 10_000) -> None:
        while self.has_work and max_steps:
            self.step()
            max_steps -= 1

    # ---------------------------------------------------------- freeze/thaw --
    @property
    def fingerprint(self) -> tuple:
        """The freeze/thaw compatibility fingerprint: a
        :class:`FrozenRequest` thaws here iff its fingerprint equals
        this (same config, same runtime options, same weights).

        Pool-*storage* options are normalized out: blobs are densified
        in ``kv_cache_dtype`` regardless of how the pool stores them, so
        an ``kv_dtype="int8"`` engine's blob thaws on a bf16-pool peer
        (and vice versa — thaw re-quantizes), and ``paged_kernel`` never
        touches blob layout at all.  Cross-``kv_dtype`` continuations
        are token-loss-free and re-prefill-free but decode with the
        destination's numerics, so they are not bit-identical to an
        uninterrupted source run."""
        opts = replace(self.opts, kv_dtype="auto", paged_kernel=False)
        return (self.cfg, opts, self.params_version)

    def can_thaw(self, frozen: Optional[FrozenRequest]) -> bool:
        """Whether a frozen blob can resume on this engine without
        re-prefill.  A blob frozen at the sequence bound has nowhere
        left to write, so it falls back to the requeue path (which
        truncates to the newest context)."""
        return (frozen is not None
                and frozen.fingerprint == self.fingerprint
                and frozen.pos < self.max_seq - 1)

    def _freeze_slot(self, slot: int, reason: str = "freeze"
                     ) -> FrozenRequest:
        """Serialize ``slot``'s in-flight state into a host-side
        :class:`FrozenRequest` and vacate the slot.  KV is *densified*
        (paged blocks gathered, rows trimmed to ``pos``) so the blob is
        portable across block sizes and into dense or per-slot engines.
        The sampling subtree carries the slot's **advanced** PRNG key, so
        the thawed stream continues bit for bit."""
        req = self._active[slot]
        if self.decode_mode == "per_slot":
            cache = self._caches[slot]
            pos = int(jax.device_get(cache["pos"]))
            leaves = {name: np.asarray(jax.device_get(leaf))
                      for name, leaf in cache.items() if name != "sample"}
            sample = {name: np.asarray(jax.device_get(v))
                      for name, v in cache["sample"].items()}
        else:
            pos = (self._slot_pos[slot] if self.decode_mode == "paged"
                   else int(jax.device_get(self._cache["pos"][slot])))
            leaves = {name: np.asarray(jax.device_get(leaf[slot]))
                      for name, leaf in self._cache.items()
                      if name != "sample"}
            sample = {name: np.asarray(jax.device_get(arr[slot]))
                      for name, arr in self._cache["sample"].items()}
        for name in _SEQ_TRIM_LEAVES:
            if name in leaves:
                leaves[name] = leaves[name][:, :, :pos]
        if self.decode_mode == "paged":
            # gather this slot's blocks into dense (n_attn, 1, pos, ...) KV;
            # int8 pools dequantize first so the blob stays portable in
            # kv_cache_dtype (any engine can thaw it, re-quantizing or not)
            bs = self.block_size
            nblk = blocks_needed(pos, bs)
            ids = self._blocks.tables[slot, :nblk]
            for name in ("k", "v"):
                blocks = self._pool[name][jnp.asarray(ids)]
                if name + "_scale" in self._pool:
                    blocks = kv_dequant_rows(
                        blocks, self._pool[name + "_scale"][jnp.asarray(ids)],
                        dtype_of(self.opts.kv_cache_dtype))
                g = np.asarray(jax.device_get(blocks))
                n_attn, kvh, hd = g.shape[1], g.shape[3], g.shape[4]
                dense = g.transpose(1, 0, 2, 3, 4).reshape(
                    n_attn, nblk * bs, kvh, hd)[:, :pos]
                leaves[name] = dense[:, None]
        frozen = FrozenRequest(rid=req.rid, pos=pos,
                               consumed=len(req.generated), leaves=leaves,
                               sample=sample, fingerprint=self.fingerprint,
                               reason=reason)
        self.stats.freezes += 1
        rec = self.recorder
        if rec.enabled:
            stamp = time.perf_counter()
            rec.instant("req.freeze", pid=self.pid, tid=f"slot{slot}",
                        cat="request", wall_s=stamp,
                        args={"rid": req.rid, "reason": reason, "pos": pos})
            rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                    cat="request", wall_s=stamp,
                    args={"rid": req.rid, "reason": reason,
                          "tokens": len(req.generated)})
        self._active[slot] = None
        if self.decode_mode == "paged":
            self._blocks.release_slot(slot)
            self._update_block_gauges()
        return frozen

    def freeze(self, rid: int) -> Optional[Request]:
        """Freeze the active request with id ``rid`` and hand it back
        (blob attached as ``req.frozen``); the caller owns it — submit
        it to a compatible engine via :meth:`thaw`.  Returns ``None``
        when ``rid`` is not currently decoding here."""
        for slot, r in enumerate(self._active):
            if r is not None and r.rid == rid:
                r.frozen = self._freeze_slot(slot, reason="freeze")
                return r
        return None

    def freeze_all(self, reason: str = "freeze") -> List[Request]:
        """Freeze every in-flight request (slot order) and hand the
        detached requests back — the fleet's migration primitive."""
        out: List[Request] = []
        for slot, r in enumerate(self._active):
            if r is not None:
                r.frozen = self._freeze_slot(slot, reason=reason)
                out.append(r)
        return out

    def thaw(self, req: Request) -> bool:
        """Accept a frozen request: queued at the *head*, it resumes with
        zero re-prefill on the next admission sweep if its blob matches
        this engine's fingerprint.  Returns False when the blob is
        incompatible — it is dropped and the request re-admits through
        the legacy prompt+generated re-prefill path (still zero token
        loss, but a prefill call)."""
        ok = self.can_thaw(req.frozen)
        if not ok and req.frozen is not None:
            self._discard_frozen(req)
        self._queue.appendleft(req)
        return ok

    def _discard_frozen(self, req: Request) -> None:
        """Fingerprint-mismatch fallback: fold the generated suffix into
        the prompt (the legacy zero-token-loss requeue contract) and drop
        the blob — the request re-admits via ordinary prefill, its PRNG
        key folded with its consumed count so the stream advances
        deterministically instead of replaying."""
        req.prompt = np.concatenate([np.asarray(req.prompt, np.int32),
                                     np.asarray(req.generated, np.int32)])
        req.frozen = None

    def _padded_to(self, src: np.ndarray, shape, dtype) -> jnp.ndarray:
        """Zero-pad a trimmed blob leaf back to a full cache leaf."""
        if tuple(src.shape) == tuple(shape):
            return jnp.asarray(src, dtype)
        buf = np.zeros(shape, dtype)
        buf[tuple(slice(0, d) for d in src.shape)] = src
        return jnp.asarray(buf)

    def _thaw_capacity_ok(self, frozen: FrozenRequest) -> bool:
        """Paged-mode admission guard: can the pool cover this blob's
        blocks right now (after evicting cached prefixes if needed)?
        Off the paged path there is nothing to allocate."""
        if self.decode_mode != "paged":
            return True
        need = blocks_needed(frozen.pos, self.block_size)
        if self._blocks.free_blocks < need:
            self._prefix.evict_for_blocks(need, self._blocks)
        return self._blocks.free_blocks >= need

    def _thaw_into_slot(self, req: Request, slot: int) -> None:
        """Re-materialize a frozen request in ``slot`` with **zero
        re-prefill**: blob leaves are zero-padded back to full cache
        shape (padding beyond ``pos`` is never read unmasked) and the
        slot resumes decoding from the blob's advanced sampling key."""
        fz = req.frozen
        key = jnp.asarray(fz.sample["key"])
        temp = jnp.asarray(fz.sample["temp"], jnp.float32)
        top_k = jnp.asarray(fz.sample["top_k"], jnp.int32)
        if self.decode_mode == "per_slot":
            cache = init_cache(self.cfg, 1, self.max_seq, self.opts)
            cache = {name: self._padded_to(fz.leaves[name], leaf.shape,
                                           leaf.dtype)
                     for name, leaf in cache.items()}
            cache["sample"] = {"key": key, "temp": temp, "top_k": top_k}
            self._caches[slot] = cache
        elif self.decode_mode == "batched":
            row = {name: self._padded_to(fz.leaves[name], leaf.shape[1:],
                                         leaf.dtype)
                   for name, leaf in self._cache.items() if name != "sample"}
            self._cache = self._programs.admit_slot(
                self._cache, row, jnp.int32(slot), key, temp, top_k)
        else:
            bs = self.block_size
            nblk = blocks_needed(fz.pos, bs)
            # program count stays bounded: the scatter is keyed on the
            # *bucketed* block count, trailing ids aimed at trash
            nblk_prog = self._bucket(fz.pos) // bs
            ids = self._alloc_blocks_reclaiming(nblk, keep_slot=slot)
            if ids is None:
                raise RuntimeError("paged pool cannot hold one thawed "
                                   "request — pool_blocks misconfigured")
            for j, b in enumerate(ids):
                self._blocks.assign(slot, j, b)
            rows = {}
            for name in ("k", "v"):
                src = fz.leaves[name][:, 0]          # (n_attn, pos, kvh, hd)
                n_attn, _, kvh, hd = src.shape
                buf = np.zeros((n_attn, nblk_prog * bs, kvh, hd), src.dtype)
                buf[:, :fz.pos] = src
                rows[name] = jnp.asarray(
                    buf.reshape(n_attn, nblk_prog, bs, kvh, hd)
                    .transpose(1, 0, 2, 3, 4))
            ids_arr = np.full(nblk_prog, TRASH_BLOCK, np.int32)
            ids_arr[:nblk] = ids
            self._pool = self._thaw_scatter_fn(nblk_prog)(
                self._pool, rows["k"], rows["v"], jnp.asarray(ids_arr))
            row = {name: self._padded_to(fz.leaves[name], leaf.shape[1:],
                                         leaf.dtype)
                   for name, leaf in self._cache.items() if name != "sample"}
            self._cache = self._paged_admit_fn()(self._cache, row,
                                                 jnp.int32(slot), key, temp,
                                                 top_k)
            self._slot_pos[slot] = fz.pos
            self._slot_seq[slot] = next(self._admit_seq)
            self._update_block_gauges()
        req.frozen = None
        self._active[slot] = req
        self.stats.thaws += 1
        if self.recorder.enabled:
            stamp = time.perf_counter()
            self.recorder.instant("req.thaw", pid=self.pid,
                                  tid=f"slot{slot}", cat="request",
                                  wall_s=stamp,
                                  args={"rid": req.rid, "pos": fz.pos,
                                        "consumed": fz.consumed})
            self.recorder.begin("req.slot", pid=self.pid, tid=f"slot{slot}",
                                cat="request", wall_s=stamp,
                                args={"rid": req.rid})

    def drain_waiting(self) -> List[Request]:
        """Detach every *waiting* (queued, not yet admitted) request in
        FIFO order — the migration caller re-submits them on the
        destination engine alongside the frozen in-flight ones."""
        out = list(self._queue)
        self._queue.clear()
        return out

    # ----------------------------------------------------------- adaptation --
    def requeue_active(self, reason: str = "requeue") -> int:
        """Re-queue every in-flight request at the head of the queue
        with **zero token loss** — and, since the paging PR, zero
        re-prefill: each request is frozen (KV + sampling state
        serialized host-side) and thaws straight back into a slot when
        its blob matches the engine's fingerprint.  Incompatible blobs
        (e.g. after a variant swap) fall back to the legacy
        prompt+generated re-prefill, whose PRNG key folds the consumed
        count so the stream advances deterministically instead of
        replaying.  Returns the number of requests re-queued."""
        pending: List[Request] = []
        for slot, r in enumerate(self._active):
            if r is not None:
                r.frozen = self._freeze_slot(slot, reason=reason)
                pending.append(r)
        for r in reversed(pending):
            self._queue.appendleft(r)
        self.stats.requeues += len(pending)
        return len(pending)

    def swap_model(self, cfg: ModelConfig, params: Params,
                   opts: RuntimeOptions,
                   params_version: Optional[int] = None) -> None:
        """Middleware hook: switch the serving variant.  Active requests
        are frozen and re-queued; after the caches rebuild they thaw
        with **zero re-prefill** when the new binding matches their blob
        (same cfg/opts/weights — e.g. a placement-driven engine restart),
        and fall back to re-prefilling their generated prefix when the
        variant really changed (retraining-free variant switching).
        Programs come from the compile cache, so swapping back to an
        already-served variant costs zero compiles."""
        requeued = self.requeue_active(reason="swap_requeue")
        if self.recorder.enabled:
            self.recorder.instant(
                "engine.swap", pid=self.pid, tid="engine", cat="engine",
                args={"generation": self.generation + 1,
                      "requeued": requeued})
        self.cfg, self.params, self.opts = cfg, params, opts
        self.params_version = (params_version if params_version is not None
                               else id(params))
        self.generation += 1
        self._programs = self._bind_programs()
        self._reset_caches()
        # blobs that can't thaw against the new binding re-admit via the
        # legacy path; dropping them up front lets the whole requeue
        # merge into one admission burst instead of k head-of-line
        # fragments (pinned by the swap prefill_calls tests)
        for r in self._queue:
            if r.frozen is not None and not self.can_thaw(r.frozen):
                self._discard_frozen(r)
