"""Batched serving runtime: request scheduler + uniform-step decode engine.

Requests arrive asynchronously; the scheduler packs them into fixed decode
slots (continuous batching with slot recycling).  Under the middleware, the
adaptation loop may swap the model variant or engine options between
decode steps — the engine re-jits lazily and keeps per-slot caches valid
only within a variant generation (the paper's "per-second adaptation
frequency" maps to a generation counter here).

Two decode paths share the scheduler:

* ``decode_mode="batched"`` (default) — ONE slot-stacked cache pytree of
  shape ``(slots, ...)`` and one jitted decode step per tick.  Per-slot
  sampling (temperature / top-k / PRNG key, living as leaves of the
  stacked cache) happens on device; slots with temperature 0 argmax
  exactly as the historical greedy engine did.  The tick does a single
  bulk device→host transfer of ``(slots,)`` tokens + positions, and the
  stacked cache is *donated* to the step so KV/SSM buffers update in
  place.  Inactive slots are masked (their outputs ignored), never
  skipped — the decode shape is constant, so one compiled program serves
  every occupancy.
* ``decode_mode="per_slot"`` — the original reference loop: one jit call
  and one host sync per active slot.  Kept for equivalence tests and as
  the benchmark baseline; token streams are bit-identical across modes.

Admission is batched too (``prefill_mode="batched"``, the default on the
batched decode path): ``_admit`` drains every waiting request that shares
the head-of-line request's prompt bucket — the head is never skipped, so
a stream of same-bucket arrivals cannot starve an earlier waiter from
another bucket — and runs ONE ``(k, bucket)`` prefill jit call whose
results are scattered straight into their slots on device.  Burst sizes
are bucketed (powers of two capped at the slot count, short bursts padded
with throwaway rows), so mixed burst sizes reuse a handful of programs.
``prefill_mode="per_request"`` keeps the sequential reference admission
(one prefill jit per request), which the property suite pins the batched
path against.

Compiled programs come from a :class:`CompileCache` shared across engines
(process-global by default), so a fleet of same-platform engines compiles
each program once — ``ServeStats.recompiles`` counts only the programs
*this* engine's requests actually caused to be built.  Sampling options
never enter the cache key (they are runtime arrays), so engines with
heterogeneous per-slot policies still share every program.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.layers import Params
from repro.models.model import init_cache, init_slot_cache
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions
from repro.obs import NULL_RECORDER, MetricsRegistry

from .compile_cache import GLOBAL_COMPILE_CACHE, CompileCache, ServePrograms
from .sampling import DEFAULT_SAMPLING, SamplingOpts, request_key

PREFILL_MODES = ("batched", "per_request")

# default observability pids: distinct per engine so two untagged
# engines sharing one TraceRecorder never interleave on one track
_ENGINE_SEQ = itertools.count()


@dataclass
class Request:
    """One generation request in the serving queue.  ``rid`` is the
    caller's identifier (echoed back, never interpreted — but folded into
    the request's PRNG key, so reuse rids deliberately); ``prompt`` is
    the int32 token array to prefill; ``max_new_tokens`` bounds the
    generated continuation (the prefill's first sampled token counts
    toward it).  ``sampling`` overrides the engine's default
    :class:`SamplingOpts` for this request (``None`` inherits it).  The
    engine fills the remaining fields: ``generated`` accumulates sampled
    tokens, ``done`` flips when the budget or ``max_seq`` is reached, and
    the ``*_s`` stamps record queue/latency milestones on the caller's
    clock (``arrived_s`` is stamped at :meth:`ServingEngine.submit` when
    the caller leaves it 0, ``first_token_s`` when the prefill's token
    lands on the host)."""
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    arrived_s: float = 0.0
    sampling: Optional[SamplingOpts] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


class ServeStats:
    """Counters for one engine's lifetime: decode ``steps`` taken,
    ``tokens_out`` emitted (prefill + decode), ``prefills`` — *requests*
    prefilled — and ``prefill_calls`` — prefill *jit invocations*; a
    burst of k same-bucket admissions is k prefills but 1 prefill call.
    ``sampled_tokens`` counts tokens drawn stochastically (from requests
    whose effective :class:`SamplingOpts` temperature is > 0; the rest
    are greedy).  ``recompiles`` is the number of jitted programs *this*
    engine's requests caused to be built (0 on an engine that found
    everything in a warm :class:`CompileCache`, which is how fleet-wide
    program sharing is asserted).

    Since the observability layer landed this is a **view** over the
    engine's :class:`~repro.obs.metrics.MetricsRegistry` — each
    attribute reads/writes the like-named ``engine.*`` counter, so the
    historical ``eng.stats.steps`` surface and the registry can never
    disagree.  A standalone ``ServeStats()`` owns a private registry."""

    _COUNTERS = {"steps": "engine.steps",
                 "tokens_out": "engine.tokens_out",
                 "prefills": "engine.prefills",
                 "prefill_calls": "engine.prefill_calls",
                 "sampled_tokens": "engine.sampled_tokens",
                 "recompiles": "engine.recompiles",
                 "oom_events": "engine.oom_events",
                 "requeues": "engine.requeues"}

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        for name in self._COUNTERS.values():
            self.metrics.counter(name)

    def _get(self, attr: str) -> int:
        return self.metrics.counter(self._COUNTERS[attr]).value

    def _set(self, attr: str, v: int) -> None:
        self.metrics.counter(self._COUNTERS[attr]).value = v

    steps = property(lambda s: s._get("steps"),
                     lambda s, v: s._set("steps", v))
    tokens_out = property(lambda s: s._get("tokens_out"),
                          lambda s, v: s._set("tokens_out", v))
    prefills = property(lambda s: s._get("prefills"),
                        lambda s, v: s._set("prefills", v))
    prefill_calls = property(lambda s: s._get("prefill_calls"),
                             lambda s, v: s._set("prefill_calls", v))
    sampled_tokens = property(lambda s: s._get("sampled_tokens"),
                              lambda s, v: s._set("sampled_tokens", v))
    recompiles = property(lambda s: s._get("recompiles"),
                          lambda s, v: s._set("recompiles", v))
    oom_events = property(lambda s: s._get("oom_events"),
                          lambda s, v: s._set("oom_events", v))
    requeues = property(lambda s: s._get("requeues"),
                        lambda s, v: s._set("requeues", v))

    @property
    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)

    def __repr__(self) -> str:
        fields = ", ".join(f"{a}={self._get(a)}" for a in self._COUNTERS)
        return f"ServeStats({fields})"


class ServingEngine:
    """Slot-based continuous batching over the unified decode API.

    ``slots`` fixes the decode batch width (requests beyond it queue);
    ``max_seq`` bounds prompt+generation length per slot.
    ``decode_mode`` selects the decode path: ``"batched"`` (default)
    advances every slot in one vmapped, cache-donating jit call with
    on-device per-slot sampling and a single bulk transfer per tick,
    while ``"per_slot"`` is the reference loop — one jit call and host
    sync per active slot — kept for equivalence tests and benchmarking
    (token streams are bit-identical across modes).  ``prefill_mode``
    selects the admission path: ``"batched"`` (default under batched
    decode) packs same-bucket waiting requests into one burst prefill
    call; ``"per_request"`` is the sequential reference (and the only
    path under ``decode_mode="per_slot"``, which has no stacked cache to
    scatter into).  ``sampling`` is the default :class:`SamplingOpts`
    for requests that don't carry their own — the zero default is greedy,
    bit-identical to the pre-sampling engine.  ``compile_cache`` /
    ``compile_domain`` wire the engine into cross-engine program
    sharing: programs are keyed on ``(cfg, opts, slots, max_seq,
    domain)``, and ``compile_domain`` namespaces the key by compile
    target (platform/ISA) since a pixel_6 cannot reuse a jetson's
    binaries — the fleet controller passes each device's
    :attr:`DeviceSpec.compile_domain` here."""

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int = 8,
                 max_seq: int = 512, opts: RuntimeOptions = DEFAULT_OPTIONS,
                 decode_mode: str = "batched",
                 prefill_mode: str = "batched",
                 sampling: SamplingOpts = DEFAULT_SAMPLING,
                 compile_cache: Optional[CompileCache] = None,
                 compile_domain: str = "",
                 recorder=NULL_RECORDER,
                 pid: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if decode_mode not in ("batched", "per_slot"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        if prefill_mode not in PREFILL_MODES:
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}; "
                             f"expected one of {PREFILL_MODES}")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.opts = opts
        self.decode_mode = decode_mode
        # the per-slot reference loop has no stacked cache to scatter a
        # burst into — it always admits per request
        self.prefill_mode = ("per_request" if decode_mode == "per_slot"
                             else prefill_mode)
        self.sampling = sampling
        self.compile_cache = (compile_cache if compile_cache is not None
                              else GLOBAL_COMPILE_CACHE)
        self.compile_domain = compile_domain
        # observability: recorder defaults to the no-op singleton (hot
        # paths guard on ``recorder.enabled``); the pid names this
        # engine's track in exported traces (the fleet controller passes
        # the device id).  The metrics registry backs ``stats`` and the
        # step-time EWMA/histogram — a shared registry makes a fleet's
        # engines aggregate into one namespace.
        self.recorder = recorder
        self.pid = pid if pid is not None else f"engine{next(_ENGINE_SEQ)}"
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = ServeStats(self.metrics)
        self._ewma = self.metrics.ewma("engine.step_time_s", alpha=0.2)
        self._step_hist = self.metrics.histogram("engine.step_time_hist_s")
        self._queue: Deque[Request] = deque()
        self._active: List[Optional[Request]] = [None] * slots
        self.generation = 0
        self._programs: ServePrograms = self._bind_programs()
        self._reset_caches()
        # telemetry: wall-time of recent steps (bounded — engines are
        # long-lived); optional sink called with (step_seconds,
        # tokens_emitted, generation) — the back-end→front-end feedback
        # channel the fleet's TelemetryStore subscribes to.
        self.step_times: Deque[float] = deque(maxlen=2048)
        self.on_step: Optional[Callable[[float, int, int], None]] = None
        # fault plane: injected OOM failures pending at admission, and
        # the exponential admission backoff they trigger (in steps).
        # All zeros on a healthy engine — the admission hot path is
        # untouched unless a fault is actually injected.
        self._oom_pending = 0
        self._admit_holdoff = 0
        self._oom_backoff = 0
        self.oom_backoff_cap = 8

    # ------------------------------------------------------------ programs --
    def _note_compile(self, what: str, **detail) -> None:
        self.stats.recompiles += 1
        if self.recorder.enabled:
            self.recorder.instant("engine.compile", pid=self.pid,
                                  tid="engine", cat="engine",
                                  args={"what": what, **detail})

    def _bind_programs(self) -> ServePrograms:
        entry, fresh = self.compile_cache.entry_for(
            self.cfg, self.opts, self.slots, self.max_seq,
            self.compile_domain)
        if fresh:
            self._note_compile("programs", generation=self.generation)
        return entry

    def _prefill_fn(self, bucket: int) -> Callable:
        fn, fresh = self._programs.prefill(bucket)
        if fresh:
            self._note_compile("prefill", bucket=bucket)
        return fn

    def _prefill_batch_fn(self, bucket: int, k: int) -> Callable:
        fn, fresh = self._programs.prefill_batch(bucket, k)
        if fresh:
            self._note_compile("prefill_batch", bucket=bucket, k=k)
        return fn

    def _reset_caches(self) -> None:
        if self.decode_mode == "batched":
            self._cache = init_slot_cache(self.cfg, self.slots, self.max_seq,
                                          self.opts)
        else:
            self._caches = [init_cache(self.cfg, 1, self.max_seq, self.opts)
                            for _ in range(self.slots)]

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        if not req.arrived_s:
            req.arrived_s = time.perf_counter()
        if self.recorder.enabled:
            # stamped with the exact arrival float, so span-derived TTFT
            # (first_token − queued) equals the legacy subtraction bit
            # for bit
            self.recorder.instant("req.queued", pid=self.pid, tid="queue",
                                  cat="request", wall_s=req.arrived_s,
                                  args={"rid": req.rid,
                                        "prompt_len": len(req.prompt)})
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        """True while any request is in flight or waiting."""
        return any(r is not None for r in self._active) or bool(self._queue)

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _k_bucket(self, k: int) -> int:
        """Round a burst size up to its program bucket: powers of two,
        capped at the slot count (mixed burst sizes then share a handful
        of compiled admission programs)."""
        b = 1
        while b < k:
            b *= 2
        return min(b, self.slots)

    def _sampling_of(self, req: Request) -> SamplingOpts:
        return req.sampling if req.sampling is not None else self.sampling

    # ------------------------------------------------------------ stepping --
    def _gather_burst(self, limit: int):
        """Pop the head request plus every same-bucket waiter behind it
        (up to ``limit``) off the queue.  The head anchors the bucket, so
        an earlier waiter from another bucket is always admitted before
        anything behind it — later same-bucket arrivals can share its
        burst's free slots but never displace it.  Budget-spent requests
        encountered on the way complete inline; passed-over requests keep
        their relative order at the queue head.  Returns ``(bucket,
        requests)``."""
        head = self._queue.popleft()
        bucket = self._bucket(len(head.prompt))
        batch = [head]
        if limit > 1:
            kept: List[Request] = []
            while self._queue and len(batch) < limit:
                r = self._queue.popleft()
                if len(r.generated) >= r.max_new_tokens:
                    r.done = True
                    continue
                if self._bucket(len(r.prompt)) == bucket:
                    batch.append(r)
                else:
                    kept.append(r)
            for r in reversed(kept):
                self._queue.appendleft(r)
        return bucket, batch

    def _emit_first(self, req: Request, token: int, stamp: float,
                    free: List[int], slot: int) -> bool:
        """Book-keep a request's prefill token; returns True when the
        request stays active in ``slot`` (False = budget completed at
        prefill, slot returned to the free pool)."""
        req.generated.append(token)
        if req.first_token_s is None:
            # keep the original stamp across swap re-admissions: TTFT is
            # submit→first token, not submit→latest re-prefill
            req.first_token_s = stamp
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        if self._sampling_of(req).temperature > 0:
            self.stats.sampled_tokens += 1
        rec = self.recorder
        if rec.enabled:
            # one first_token instant per *admission* (a swap re-admission
            # emits another, with the re-prefill's stamp — first_token_s
            # above keeps the original), one slot-occupancy span begin
            tid = f"slot{slot}"
            rec.instant("req.first_token", pid=self.pid, tid=tid,
                        cat="request", wall_s=stamp,
                        args={"rid": req.rid, "token": token})
            rec.begin("req.slot", pid=self.pid, tid=tid, cat="request",
                      wall_s=stamp, args={"rid": req.rid})
        if len(req.generated) >= req.max_new_tokens:
            req.done = True          # prefill token completed the budget
            if rec.enabled:
                rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                        cat="request", wall_s=stamp,
                        args={"rid": req.rid, "reason": "done_at_prefill",
                              "tokens": len(req.generated)})
            free.append(slot)
            return False
        self._active[slot] = req
        return True

    def _truncate(self, req: Request, bucket: int) -> None:
        if len(req.prompt) > bucket:
            # prompt exceeds max_seq (e.g. a swap re-queue whose prompt
            # grew by the generated prefix): keep the newest context
            req.prompt = req.prompt[-bucket:]

    def _admit_burst(self, batch: List[Request], bucket: int,
                     free: List[int]) -> None:
        """ONE jitted call admits the whole burst: stacked ``(k, bucket)``
        prompts are prefilled together and every row's cache + sampling
        state is scattered into its slot on device.  Bursts smaller than
        their k-bucket are padded with leading throwaway rows aimed at the
        first real slot — written first, overwritten by the real row."""
        k = len(batch)
        kb = self._k_bucket(k)
        pad = kb - k
        slots_for = [free.pop(0) for _ in range(k)]
        toks = np.zeros((kb, bucket), np.int32)
        keys = np.zeros((kb, 2), np.uint32)
        temps = np.zeros((kb,), np.float32)
        top_ks = np.zeros((kb,), np.int32)
        slot_ids = np.full((kb,), slots_for[0], np.int32)
        for i, req in enumerate(batch):
            self._truncate(req, bucket)
            row = pad + i
            toks[row, bucket - len(req.prompt):] = req.prompt  # left-pad
            s = self._sampling_of(req)
            keys[row] = request_key(s.seed, req.rid, len(req.generated))
            temps[row] = s.temperature
            top_ks[row] = s.top_k
            slot_ids[row] = slots_for[i]
        if self.recorder.enabled:
            self.recorder.begin("engine.prefill", pid=self.pid,
                                tid="engine", cat="engine",
                                args={"bucket": bucket, "k": k,
                                      "k_bucket": kb,
                                      "rids": [r.rid for r in batch]})
        fn = self._prefill_batch_fn(bucket, kb)
        first, self._cache = fn(self.params, self._cache, jnp.asarray(toks),
                                jnp.asarray(slot_ids), jnp.asarray(keys),
                                jnp.asarray(temps), jnp.asarray(top_ks))
        first = jax.device_get(first)
        self.stats.prefill_calls += 1
        stamp = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.end("engine.prefill", pid=self.pid, tid="engine",
                              cat="engine", wall_s=stamp)
        for i, req in enumerate(batch):
            self._emit_first(req, int(first[pad + i]), stamp, free,
                             slots_for[i])

    def _admit_one(self, req: Request, free: List[int]) -> None:
        """Sequential reference admission: one prefill jit call for this
        request, its first token drawn by the same ``sample_logits`` the
        batched paths use."""
        slot = free.pop(0)
        bucket = self._bucket(len(req.prompt))
        self._truncate(req, bucket)
        if self.recorder.enabled:
            self.recorder.begin("engine.prefill", pid=self.pid,
                                tid="engine", cat="engine",
                                args={"bucket": bucket, "k": 1,
                                      "rids": [req.rid]})
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - len(req.prompt):] = req.prompt  # left-pad
        cache = init_cache(self.cfg, 1, self.max_seq, self.opts)
        logits, cache = self._prefill_fn(bucket)(
            self.params, cache, jnp.asarray(toks))
        self.stats.prefill_calls += 1
        s = self._sampling_of(req)
        key = jnp.asarray(request_key(s.seed, req.rid, len(req.generated)))
        temp = jnp.float32(s.temperature)
        top_k = jnp.int32(s.top_k)
        tok, key = self._programs.sample_first(logits[0, -1], key, temp,
                                               top_k)
        nxt = int(tok)
        stamp = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.end("engine.prefill", pid=self.pid, tid="engine",
                              cat="engine", wall_s=stamp)
        if not self._emit_first(req, nxt, stamp, free, slot):
            return
        if self.decode_mode == "batched":
            # the stacked side is donated: the slot write is in place
            self._cache = self._programs.admit_slot(
                self._cache, cache, jnp.int32(slot), key, temp, top_k)
        else:
            cache["sample"] = {"key": key, "temp": temp, "top_k": top_k}
            self._caches[slot] = cache

    def inject_oom(self, n: int = 1) -> None:
        """Fault injection: the next ``n`` admission attempts fail as if
        cache allocation OOMed.  The engine responds the way a real
        admission controller would — the request stays queued (zero
        token loss) and admission backs off exponentially (doubling
        hold-off steps, capped at ``oom_backoff_cap``) before retrying,
        so a memory-pressured engine stops hammering the allocator."""
        self._oom_pending += max(int(n), 0)

    def _admit(self) -> None:
        if self._admit_holdoff > 0:
            self._admit_holdoff -= 1
            return
        free = [s for s in range(self.slots) if self._active[s] is None]
        if self._oom_pending > 0 and free and self._queue:
            # injected OOM: this admission attempt fails, the head stays
            # queued untouched, and we back off before trying again
            self._oom_pending -= 1
            self.stats.oom_events += 1
            self._oom_backoff = min(max(2 * self._oom_backoff, 1),
                                    self.oom_backoff_cap)
            self._admit_holdoff = self._oom_backoff
            if self.recorder.enabled:
                self.recorder.instant(
                    "engine.oom", pid=self.pid, tid="engine",
                    cat="engine",
                    args={"backoff_steps": self._admit_holdoff,
                          "queued": len(self._queue)})
            return
        admitted = False
        while free and self._queue:
            head = self._queue[0]
            if len(head.generated) >= head.max_new_tokens:
                # re-queued after a swap with its budget already spent (or
                # submitted with max_new_tokens=0): emitting another prefill
                # token would overshoot the budget and double-count it.
                self._queue.popleft()
                head.done = True
                continue
            if self.prefill_mode == "batched":
                bucket, batch = self._gather_burst(len(free))
                self._admit_burst(batch, bucket, free)
            else:
                self._queue.popleft()
                self._admit_one(head, free)
            admitted = True
        if admitted:
            self._oom_backoff = 0     # a successful admission heals

    def _decode_batched(self) -> int:
        if not any(r is not None for r in self._active):
            return 0
        tokens = np.zeros(self.slots, np.int32)
        sampling = False
        for slot, req in enumerate(self._active):
            if req is not None:
                tokens[slot] = req.generated[-1]
                sampling = sampling or \
                    self._sampling_of(req).temperature > 0
        # all-greedy ticks take the pure-argmax program: no per-slot
        # argsort/categorical work selected away by a where — the default
        # greedy engine keeps its historical hot-path cost.  Outputs are
        # bit-identical either way, so mixed workloads can alternate.
        step_fn = (self._programs.decode if sampling
                   else self._programs.decode_greedy)
        nxt, pos, self._cache = step_fn(
            self.params, self._cache, jnp.asarray(tokens))
        nxt, pos = jax.device_get((nxt, pos))   # one bulk transfer per tick
        emitted = 0
        rec = self.recorder
        stamp = time.perf_counter() if rec.enabled else 0.0
        for slot, req in enumerate(self._active):
            if req is None:      # masked slot: decoded, output ignored
                continue
            req.generated.append(int(nxt[slot]))
            emitted += 1
            if self._sampling_of(req).temperature > 0:
                self.stats.sampled_tokens += 1
            if rec.enabled:
                rec.instant("req.decode", pid=self.pid, tid=f"slot{slot}",
                            cat="request", wall_s=stamp,
                            args={"rid": req.rid, "token": int(nxt[slot])})
            if len(req.generated) >= req.max_new_tokens \
                    or int(pos[slot]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
                if rec.enabled:
                    rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                            cat="request", wall_s=stamp,
                            args={"rid": req.rid, "reason": "finished",
                                  "tokens": len(req.generated)})
        return emitted

    def _decode_per_slot(self) -> int:
        emitted = 0
        rec = self.recorder
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            tok = jnp.asarray(req.generated[-1], jnp.int32)
            nxt, cache = self._programs.sample_ref(
                self.params, self._caches[slot], tok)
            self._caches[slot] = cache
            req.generated.append(int(nxt))
            emitted += 1
            if self._sampling_of(req).temperature > 0:
                self.stats.sampled_tokens += 1
            if rec.enabled:
                rec.instant("req.decode", pid=self.pid, tid=f"slot{slot}",
                            cat="request",
                            args={"rid": req.rid, "token": int(nxt)})
            if len(req.generated) >= req.max_new_tokens \
                    or int(cache["pos"]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
                if rec.enabled:
                    rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                            cat="request",
                            args={"rid": req.rid, "reason": "finished",
                                  "tokens": len(req.generated)})
        return emitted

    def step(self) -> int:
        """One engine tick: admit waiting requests, decode one token for
        every active slot.  Returns number of tokens emitted."""
        self._admit()
        # time only the decode sweep: prefill/compile costs would otherwise
        # masquerade as decode-step latency in the telemetry channel
        rec = self.recorder
        t0 = time.perf_counter()
        if rec.enabled:
            rec.begin("engine.step", pid=self.pid, tid="engine",
                      cat="engine", wall_s=t0,
                      args={"generation": self.generation})
        if self.decode_mode == "batched":
            emitted = self._decode_batched()
        else:
            emitted = self._decode_per_slot()
        self.stats.steps += 1
        self.stats.tokens_out += emitted
        t1 = time.perf_counter()
        dt = t1 - t0
        self.step_times.append(dt)
        self._ewma.update(dt)
        self._step_hist.observe(dt)
        if rec.enabled:
            rec.end("engine.step", pid=self.pid, tid="engine",
                    cat="engine", wall_s=t1, args={"emitted": emitted})
        if self.on_step is not None:
            self.on_step(dt, emitted, self.generation)
        return emitted

    @property
    def step_time_ewma_s(self) -> Optional[float]:
        """Smoothed recent decode-step wall time (seconds), or ``None``
        before the first step.  This is the step-timing hook the fleet's
        event scheduler consults: an engine-backed device's next wake is
        its envelope period *plus* ``steps_per_tick × step_time_ewma_s``,
        so devices whose engines slow down under load automatically tick
        less often.  A view over the registry's ``engine.step_time_s``
        EWMA gauge (``alpha=0.2`` reproduces the historical
        ``0.8·prev + 0.2·dt`` update bit for bit)."""
        return self._ewma.value

    def drain(self, max_steps: int = 10_000) -> None:
        while self.has_work and max_steps:
            self.step()
            max_steps -= 1

    # ----------------------------------------------------------- adaptation --
    def requeue_active(self, reason: str = "requeue") -> int:
        """Re-queue every in-flight request at the head of the queue
        with **zero token loss**: the prompt becomes prompt+generated
        and ``generated`` is preserved, so the re-admitted request's
        PRNG key (folded with its consumed-token count) advances its
        stream deterministically instead of replaying.  This is the
        swap-requeue contract, factored out so failover paths (a device
        evicted mid-decode, an OOMed admission sweep) reuse it verbatim.
        Returns the number of requests re-queued."""
        pending = [r for r in self._active if r is not None]
        rec = self.recorder
        if rec.enabled:
            stamp = time.perf_counter()
            for slot, r in enumerate(self._active):
                if r is not None:   # close its occupancy span: the copy
                    rec.end("req.slot", pid=self.pid, tid=f"slot{slot}",
                            cat="request", wall_s=stamp,
                            args={"rid": r.rid, "reason": reason,
                                  "tokens": len(r.generated)})
        for r in pending:
            r_prompt = np.concatenate([r.prompt, np.asarray(r.generated,
                                                            np.int32)])
            self._queue.appendleft(dataclasses.replace(
                r, prompt=r_prompt, generated=list(r.generated)))
        self._active = [None] * self.slots
        self.stats.requeues += len(pending)
        return len(pending)

    def swap_model(self, cfg: ModelConfig, params: Params,
                   opts: RuntimeOptions) -> None:
        """Middleware hook: switch the serving variant.  Active requests
        finish their decode on fresh caches via re-prefill of their
        generated prefix (retraining-free variant switching).  The stacked
        cache is rebuilt once per generation; programs come from the
        compile cache, so swapping back to an already-served variant
        costs zero compiles.  A re-admitted request's PRNG key is folded
        with its consumed-token count, so its resumed stream advances
        deterministically instead of replaying."""
        requeued = self.requeue_active(reason="swap_requeue")
        if self.recorder.enabled:
            self.recorder.instant(
                "engine.swap", pid=self.pid, tid="engine", cat="engine",
                args={"generation": self.generation + 1,
                      "requeued": requeued})
        self.cfg, self.params, self.opts = cfg, params, opts
        self.generation += 1
        self._programs = self._bind_programs()
        self._reset_caches()
