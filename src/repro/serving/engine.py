"""Batched serving runtime: request scheduler + uniform-step decode engine.

Requests arrive asynchronously; the scheduler packs them into fixed decode
slots (continuous batching with slot recycling).  Under the middleware, the
adaptation loop may swap the model variant or engine options between
decode steps — the engine re-jits lazily and keeps per-slot caches valid
only within a variant generation (the paper's "per-second adaptation
frequency" maps to a generation counter here).

Two decode paths share the scheduler:

* ``decode_mode="batched"`` (default) — ONE slot-stacked cache pytree of
  shape ``(slots, ...)`` and one jitted decode step per tick.  Greedy
  argmax happens on device; the tick does a single bulk device→host
  transfer of ``(slots,)`` tokens + positions, and the stacked cache is
  *donated* to the step so KV/SSM buffers update in place.  Inactive
  slots are masked (their outputs ignored), never skipped — the decode
  shape is constant, so one compiled program serves every occupancy.
* ``decode_mode="per_slot"`` — the original reference loop: one jit call
  and one host sync per active slot.  Kept for equivalence tests and as
  the benchmark baseline; token streams are bit-identical across modes.

Compiled programs come from a :class:`CompileCache` shared across engines
(process-global by default), so a fleet of same-platform engines compiles
each program once — ``ServeStats.recompiles`` counts only the programs
*this* engine's requests actually caused to be built.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.layers import Params
from repro.models.model import init_cache, init_slot_cache
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions

from .compile_cache import GLOBAL_COMPILE_CACHE, CompileCache, ServePrograms


@dataclass
class Request:
    """One generation request in the serving queue.  ``rid`` is the
    caller's identifier (echoed back, never interpreted); ``prompt`` is
    the int32 token array to prefill; ``max_new_tokens`` bounds the
    generated continuation (the prefill's first sampled token counts
    toward it).  The engine fills the remaining fields: ``generated``
    accumulates sampled tokens, ``done`` flips when the budget or
    ``max_seq`` is reached, and the ``*_s`` stamps record queue/latency
    milestones on the caller's clock."""
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    arrived_s: float = 0.0
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


@dataclass
class ServeStats:
    """Counters for one engine's lifetime: decode ``steps`` taken,
    ``tokens_out`` emitted (prefill + decode), ``prefills`` run, and
    ``recompiles`` — the number of jitted programs *this* engine's
    requests caused to be built (0 on an engine that found everything in
    a warm :class:`CompileCache`, which is how fleet-wide program
    sharing is asserted)."""
    steps: int = 0
    tokens_out: int = 0
    prefills: int = 0
    recompiles: int = 0

    @property
    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)


class ServingEngine:
    """Slot-based continuous batching over the unified decode API.

    ``slots`` fixes the decode batch width (requests beyond it queue);
    ``max_seq`` bounds prompt+generation length per slot.
    ``decode_mode`` selects the decode path: ``"batched"`` (default)
    advances every slot in one vmapped, cache-donating jit call with
    on-device argmax and a single bulk transfer per tick, while
    ``"per_slot"`` is the reference loop — one jit call and host sync
    per active slot — kept for equivalence tests and benchmarking (token
    streams are bit-identical across modes).  ``compile_cache`` /
    ``compile_domain`` wire the engine into cross-engine program
    sharing: programs are keyed on ``(cfg, opts, slots, max_seq,
    domain)``, and ``compile_domain`` namespaces the key by compile
    target (platform/ISA) since a pixel_6 cannot reuse a jetson's
    binaries — the fleet controller passes each device's
    :attr:`DeviceSpec.compile_domain` here."""

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int = 8,
                 max_seq: int = 512, opts: RuntimeOptions = DEFAULT_OPTIONS,
                 decode_mode: str = "batched",
                 compile_cache: Optional[CompileCache] = None,
                 compile_domain: str = ""):
        if decode_mode not in ("batched", "per_slot"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.opts = opts
        self.decode_mode = decode_mode
        self.compile_cache = (compile_cache if compile_cache is not None
                              else GLOBAL_COMPILE_CACHE)
        self.compile_domain = compile_domain
        self.stats = ServeStats()
        self._queue: Deque[Request] = deque()
        self._active: List[Optional[Request]] = [None] * slots
        self._programs: ServePrograms = self._bind_programs()
        self._reset_caches()
        self.generation = 0
        # telemetry: wall-time of recent steps (bounded — engines are
        # long-lived); optional sink called with (step_seconds,
        # tokens_emitted, generation) — the back-end→front-end feedback
        # channel the fleet's TelemetryStore subscribes to.
        self.step_times: Deque[float] = deque(maxlen=2048)
        self.on_step: Optional[Callable[[float, int, int], None]] = None
        self._step_ewma: Optional[float] = None

    # ------------------------------------------------------------ programs --
    def _bind_programs(self) -> ServePrograms:
        entry, fresh = self.compile_cache.entry_for(
            self.cfg, self.opts, self.slots, self.max_seq,
            self.compile_domain)
        if fresh:
            self.stats.recompiles += 1
        return entry

    def _prefill_fn(self, bucket: int) -> Callable:
        fn, fresh = self._programs.prefill(bucket)
        if fresh:
            self.stats.recompiles += 1
        return fn

    def _reset_caches(self) -> None:
        if self.decode_mode == "batched":
            self._cache = init_slot_cache(self.cfg, self.slots, self.max_seq,
                                          self.opts)
        else:
            self._caches = [init_cache(self.cfg, 1, self.max_seq, self.opts)
                            for _ in range(self.slots)]

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    @property
    def has_work(self) -> bool:
        """True while any request is in flight or waiting."""
        return any(r is not None for r in self._active) or bool(self._queue)

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    # ------------------------------------------------------------ stepping --
    def _admit(self) -> None:
        for slot in range(self.slots):
            if self._active[slot] is not None or not self._queue:
                continue
            req = self._queue.popleft()
            if len(req.generated) >= req.max_new_tokens:
                # re-queued after a swap with its budget already spent (or
                # submitted with max_new_tokens=0): emitting another prefill
                # token would overshoot the budget and double-count it.
                req.done = True
                continue
            bucket = self._bucket(len(req.prompt))
            if len(req.prompt) > bucket:
                # prompt exceeds max_seq (e.g. a swap re-queue whose prompt
                # grew by the generated prefix): keep the newest context
                req.prompt = req.prompt[-bucket:]
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - len(req.prompt):] = req.prompt  # left-pad
            cache = init_cache(self.cfg, 1, self.max_seq, self.opts)
            logits, cache = self._prefill_fn(bucket)(
                self.params, cache, jnp.asarray(toks))
            nxt = int(jnp.argmax(logits[0, -1, : self.cfg.vocab_size]))
            req.generated.append(nxt)
            self.stats.prefills += 1
            self.stats.tokens_out += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True      # prefill token completed the budget
            elif self.decode_mode == "batched":
                # the stacked side is donated: the slot write is in place
                self._cache = self._programs.write_slot(
                    self._cache, cache, jnp.int32(slot))
                self._active[slot] = req
            else:
                self._caches[slot] = cache
                self._active[slot] = req

    def _decode_batched(self) -> int:
        if not any(r is not None for r in self._active):
            return 0
        tokens = np.zeros(self.slots, np.int32)
        for slot, req in enumerate(self._active):
            if req is not None:
                tokens[slot] = req.generated[-1]
        nxt, pos, self._cache = self._programs.decode(
            self.params, self._cache, jnp.asarray(tokens))
        nxt, pos = jax.device_get((nxt, pos))   # one bulk transfer per tick
        emitted = 0
        for slot, req in enumerate(self._active):
            if req is None:      # masked slot: decoded, output ignored
                continue
            req.generated.append(int(nxt[slot]))
            emitted += 1
            if len(req.generated) >= req.max_new_tokens \
                    or int(pos[slot]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
        return emitted

    def _decode_per_slot(self) -> int:
        emitted = 0
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            tok = jnp.asarray([req.generated[-1]], jnp.int32)
            logits, cache = self._programs.decode_ref(
                self.params, self._caches[slot], tok)
            self._caches[slot] = cache
            nxt = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
            req.generated.append(nxt)
            emitted += 1
            if len(req.generated) >= req.max_new_tokens \
                    or int(cache["pos"]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
        return emitted

    def step(self) -> int:
        """One engine tick: admit waiting requests, decode one token for
        every active slot.  Returns number of tokens emitted."""
        self._admit()
        # time only the decode sweep: prefill/compile costs would otherwise
        # masquerade as decode-step latency in the telemetry channel
        t0 = time.perf_counter()
        if self.decode_mode == "batched":
            emitted = self._decode_batched()
        else:
            emitted = self._decode_per_slot()
        self.stats.steps += 1
        self.stats.tokens_out += emitted
        dt = time.perf_counter() - t0
        self.step_times.append(dt)
        self._step_ewma = (dt if self._step_ewma is None
                           else 0.8 * self._step_ewma + 0.2 * dt)
        if self.on_step is not None:
            self.on_step(dt, emitted, self.generation)
        return emitted

    @property
    def step_time_ewma_s(self) -> Optional[float]:
        """Smoothed recent decode-step wall time (seconds), or ``None``
        before the first step.  This is the step-timing hook the fleet's
        event scheduler consults: an engine-backed device's next wake is
        its envelope period *plus* ``steps_per_tick × step_time_ewma_s``,
        so devices whose engines slow down under load automatically tick
        less often."""
        return self._step_ewma

    def drain(self, max_steps: int = 10_000) -> None:
        while self.has_work and max_steps:
            self.step()
            max_steps -= 1

    # ----------------------------------------------------------- adaptation --
    def swap_model(self, cfg: ModelConfig, params: Params,
                   opts: RuntimeOptions) -> None:
        """Middleware hook: switch the serving variant.  Active requests
        finish their decode on fresh caches via re-prefill of their
        generated prefix (retraining-free variant switching).  The stacked
        cache is rebuilt once per generation; programs come from the
        compile cache, so swapping back to an already-served variant
        costs zero compiles."""
        pending = [r for r in self._active if r is not None]
        for r in pending:
            r_prompt = np.concatenate([r.prompt, np.asarray(r.generated,
                                                            np.int32)])
            self._queue.appendleft(dataclasses.replace(
                r, prompt=r_prompt, generated=list(r.generated)))
        self.cfg, self.params, self.opts = cfg, params, opts
        self._active = [None] * self.slots
        self._programs = self._bind_programs()
        self._reset_caches()
        self.generation += 1
