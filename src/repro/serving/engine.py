"""Batched serving runtime: request scheduler + uniform-step decode engine.

Requests arrive asynchronously; the scheduler packs them into fixed decode
slots (continuous batching with slot recycling).  Under the middleware, the
adaptation loop may swap the model variant or engine options between
decode steps — the engine re-jits lazily and keeps per-slot caches valid
only within a variant generation (the paper's "per-second adaptation
frequency" maps to a generation counter here).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.layers import Params
from repro.models.model import decode_step, init_cache, prefill
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    arrived_s: float = 0.0
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


@dataclass
class ServeStats:
    steps: int = 0
    tokens_out: int = 0
    prefills: int = 0
    recompiles: int = 0

    @property
    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)


class ServingEngine:
    """Slot-based continuous batching over the unified decode API."""

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int = 8,
                 max_seq: int = 512, opts: RuntimeOptions = DEFAULT_OPTIONS):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.opts = opts
        self.stats = ServeStats()
        self._queue: List[Request] = []
        self._active: List[Optional[Request]] = [None] * slots
        self._caches = [init_cache(cfg, 1, max_seq, opts)
                        for _ in range(slots)]
        self._jit_decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t, opts))
        self._jit_prefill = None  # shapes vary; built per prompt bucket
        self._prefill_cache: Dict[int, Callable] = {}
        self.generation = 0
        # telemetry: wall-time of recent steps (bounded — engines are
        # long-lived); optional sink called with (step_seconds,
        # tokens_emitted, generation) — the back-end→front-end feedback
        # channel the fleet's TelemetryStore subscribes to.
        self.step_times: Deque[float] = deque(maxlen=2048)
        self.on_step: Optional[Callable[[float, int, int], None]] = None

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _prefill_fn(self, bucket: int) -> Callable:
        if bucket not in self._prefill_cache:
            cfg, opts = self.cfg, self.opts
            self._prefill_cache[bucket] = jax.jit(
                lambda p, c, t: prefill(p, cfg, t, c, opts))
            self.stats.recompiles += 1
        return self._prefill_cache[bucket]

    # ------------------------------------------------------------ stepping --
    def _admit(self) -> None:
        for slot in range(self.slots):
            if self._active[slot] is not None or not self._queue:
                continue
            req = self._queue.pop(0)
            if len(req.generated) >= req.max_new_tokens:
                # re-queued after a swap with its budget already spent (or
                # submitted with max_new_tokens=0): emitting another prefill
                # token would overshoot the budget and double-count it.
                req.done = True
                continue
            bucket = self._bucket(len(req.prompt))
            if len(req.prompt) > bucket:
                # prompt exceeds max_seq (e.g. a swap re-queue whose prompt
                # grew by the generated prefix): keep the newest context
                req.prompt = req.prompt[-bucket:]
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - len(req.prompt):] = req.prompt  # left-pad
            cache = init_cache(self.cfg, 1, self.max_seq, self.opts)
            logits, cache = self._prefill_fn(bucket)(
                self.params, cache, jnp.asarray(toks))
            self._caches[slot] = cache
            nxt = int(jnp.argmax(logits[0, -1, : self.cfg.vocab_size]))
            req.generated.append(nxt)
            self.stats.prefills += 1
            self.stats.tokens_out += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True      # prefill token completed the budget
            else:
                self._active[slot] = req

    def step(self) -> int:
        """One engine tick: admit waiting requests, decode one token for
        every active slot.  Returns number of tokens emitted."""
        self._admit()
        # time only the decode sweep: prefill/compile costs would otherwise
        # masquerade as decode-step latency in the telemetry channel
        t0 = time.perf_counter()
        emitted = 0
        for slot, req in enumerate(self._active):
            if req is None:
                continue
            tok = jnp.asarray([req.generated[-1]], jnp.int32)
            logits, cache = self._jit_decode(self.params,
                                             self._caches[slot], tok)
            self._caches[slot] = cache
            nxt = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
            req.generated.append(nxt)
            emitted += 1
            if len(req.generated) >= req.max_new_tokens \
                    or int(cache["pos"]) >= self.max_seq - 1:
                req.done = True
                self._active[slot] = None
        self.stats.steps += 1
        self.stats.tokens_out += emitted
        dt = time.perf_counter() - t0
        self.step_times.append(dt)
        if self.on_step is not None:
            self.on_step(dt, emitted, self.generation)
        return emitted

    def drain(self, max_steps: int = 10_000) -> None:
        while (any(self._active) or self._queue) and max_steps:
            self.step()
            max_steps -= 1

    # ----------------------------------------------------------- adaptation --
    def swap_model(self, cfg: ModelConfig, params: Params,
                   opts: RuntimeOptions) -> None:
        """Middleware hook: switch the serving variant.  Active requests
        finish their decode on fresh caches via re-prefill of their
        generated prefix (retraining-free variant switching)."""
        pending = [r for r in self._active if r is not None]
        for r in pending:
            r_prompt = np.concatenate([r.prompt, np.asarray(r.generated,
                                                            np.int32)])
            self._queue.insert(0, dataclasses.replace(
                r, prompt=r_prompt, generated=list(r.generated)))
        self.cfg, self.params, self.opts = cfg, params, opts
        self._active = [None] * self.slots
        self._caches = [init_cache(cfg, 1, self.max_seq, opts)
                        for _ in range(self.slots)]
        self._jit_decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t, opts))
        self._prefill_cache.clear()
        self.generation += 1
        self.stats.recompiles += 1
