"""Per-request sampling policy for the serving engine.

:class:`SamplingOpts` is the host-side description of how one request's
tokens are drawn; the *device-side* state it induces (a PRNG key, a
temperature and a top-k per slot) lives inside the slot-stacked cache
pytree (see :func:`repro.models.model.init_slot_cache`), so it is
donated, vmapped and slot-scattered exactly like the model's KV/SSM
state.  Because temperature/top-k/keys are runtime *arrays*, not compile
constants, sampling never enters a :class:`CompileCache` key — engines
with heterogeneous per-slot policies still share one decode program.

``temperature == 0`` short-circuits (on device, via ``jnp.where``) to
the exact argmax the pre-sampling engine computed, so greedy token
streams are bit-identical to the historical greedy decode.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["SamplingOpts", "DEFAULT_SAMPLING", "request_key"]


@dataclass(frozen=True)
class SamplingOpts:
    """How one request's continuation is sampled.

    ``temperature`` — 0 selects greedy argmax (bit-identical to the
    pre-sampling decode path); > 0 samples from the softmax of
    ``logits / temperature``.  ``top_k`` — 0 keeps the full vocabulary;
    k > 0 masks everything below the k-th largest logit (``top_k=1`` is
    argmax again).  ``seed`` — folded with the request id into the
    slot's PRNG key, so fixed seeds give reproducible streams."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


DEFAULT_SAMPLING = SamplingOpts()


def request_key(seed: int, rid: int, consumed: int = 0) -> np.ndarray:
    """Deterministic per-request PRNG key material (``(2,) uint32``).

    Depends only on ``(seed, rid, tokens already generated)`` — never on
    the slot index, the admission order or the decode mode — so a
    request's sampled stream is reproducible across runs and identical
    across the batched and per-slot decode paths.  A swap re-queue is
    re-admitted with its ``consumed`` count folded in, so the resumed
    continuation advances the stream instead of replaying it."""
    hi = (int(seed) ^ (int(consumed) * 2654435761)) & 0xFFFFFFFF
    return np.array([hi, int(rid) & 0xFFFFFFFF], dtype=np.uint32)
