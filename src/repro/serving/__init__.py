"""Slot-batched continuous-batching serving layer.

:class:`ServingEngine` packs asynchronous :class:`Request` objects into
fixed decode slots and advances all of them in one jitted, cache-donated
step per tick (``decode_mode="batched"``; the per-slot reference loop
survives as ``decode_mode="per_slot"``).  :class:`CompileCache` shares
jitted decode/prefill programs across engines keyed on ``(cfg, opts,
slots, max_seq, compile_domain)`` — same-platform fleet members compile
once — with :data:`GLOBAL_COMPILE_CACHE` as the process-wide default.
:class:`ServeStats` counts steps/tokens/prefills/recompiles, and the
engine's ``step_time_ewma_s`` / ``on_step`` hooks are the measured
back-end feed the fleet's telemetry and event scheduler consume."""
from .compile_cache import (CompileCache, GLOBAL_COMPILE_CACHE,
                            ServePrograms)
from .engine import Request, ServeStats, ServingEngine

__all__ = ["CompileCache", "GLOBAL_COMPILE_CACHE", "ServePrograms",
           "Request", "ServeStats", "ServingEngine"]
