"""Slot-batched continuous-batching serving layer.

:class:`ServingEngine` packs asynchronous :class:`Request` objects into
fixed decode slots, admits same-bucket bursts in ONE batched prefill
call (``prefill_mode="batched"``; the sequential reference survives as
``"per_request"``) and advances all slots in one jitted, cache-donated
sampling step per tick (``decode_mode="batched"``; the per-slot
reference loop survives as ``"per_slot"``).  Per-request
:class:`SamplingOpts` (temperature / top-k / seed) become per-slot
device state inside the stacked cache — temperature 0 is bit-identical
to the historical greedy decode.  :class:`CompileCache` shares jitted
decode/prefill programs across engines keyed on ``(cfg, opts, slots,
max_seq, compile_domain)`` — same-platform fleet members compile once,
and sampling never enters the key — with :data:`GLOBAL_COMPILE_CACHE` as
the process-wide default.  :class:`ServeStats` counts steps/tokens/
prefills/prefill-calls/sampled-tokens/recompiles, and the engine's
``step_time_ewma_s`` / ``on_step`` hooks are the measured back-end feed
the fleet's telemetry and event scheduler consume.

``decode_mode="paged"`` swaps the dense per-slot ``max_seq`` KV
allocation for a :class:`BlockPool` of fixed-size blocks with
refcounted copy-on-write prefix sharing (:mod:`repro.serving.paging`),
and every engine mode gains ``freeze``/``thaw``: a request's pages,
sampling subtree and consumed count serialize into a host-side
:class:`FrozenRequest` that resumes on any engine with a matching
``(cfg, opts, params_version)`` fingerprint — zero token loss, zero
re-prefill — which is the fleet's live-migration primitive."""
from .compile_cache import (CompileCache, GLOBAL_COMPILE_CACHE,
                            ServePrograms)
from .engine import DECODE_MODES, Request, ServeStats, ServingEngine
from .paging import (DEFAULT_BLOCK_SIZE, BlockPool, FrozenRequest,
                     PrefixCache, PrefixEntry, block_hash_chain,
                     blocks_needed)
from .sampling import DEFAULT_SAMPLING, SamplingOpts, request_key

__all__ = ["CompileCache", "GLOBAL_COMPILE_CACHE", "ServePrograms",
           "Request", "ServeStats", "ServingEngine", "DECODE_MODES",
           "SamplingOpts", "DEFAULT_SAMPLING", "request_key",
           "DEFAULT_BLOCK_SIZE", "BlockPool", "FrozenRequest",
           "PrefixCache", "PrefixEntry", "block_hash_chain",
           "blocks_needed"]
