from .compile_cache import (CompileCache, GLOBAL_COMPILE_CACHE,
                            ServePrograms)
from .engine import Request, ServeStats, ServingEngine

__all__ = ["CompileCache", "GLOBAL_COMPILE_CACHE", "ServePrograms",
           "Request", "ServeStats", "ServingEngine"]
