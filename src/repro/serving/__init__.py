"""Slot-batched continuous-batching serving layer.

:class:`ServingEngine` packs asynchronous :class:`Request` objects into
fixed decode slots, admits same-bucket bursts in ONE batched prefill
call (``prefill_mode="batched"``; the sequential reference survives as
``"per_request"``) and advances all slots in one jitted, cache-donated
sampling step per tick (``decode_mode="batched"``; the per-slot
reference loop survives as ``"per_slot"``).  Per-request
:class:`SamplingOpts` (temperature / top-k / seed) become per-slot
device state inside the stacked cache — temperature 0 is bit-identical
to the historical greedy decode.  :class:`CompileCache` shares jitted
decode/prefill programs across engines keyed on ``(cfg, opts, slots,
max_seq, compile_domain)`` — same-platform fleet members compile once,
and sampling never enters the key — with :data:`GLOBAL_COMPILE_CACHE` as
the process-wide default.  :class:`ServeStats` counts steps/tokens/
prefills/prefill-calls/sampled-tokens/recompiles, and the engine's
``step_time_ewma_s`` / ``on_step`` hooks are the measured back-end feed
the fleet's telemetry and event scheduler consume."""
from .compile_cache import (CompileCache, GLOBAL_COMPILE_CACHE,
                            ServePrograms)
from .engine import Request, ServeStats, ServingEngine
from .sampling import DEFAULT_SAMPLING, SamplingOpts, request_key

__all__ = ["CompileCache", "GLOBAL_COMPILE_CACHE", "ServePrograms",
           "Request", "ServeStats", "ServingEngine",
           "SamplingOpts", "DEFAULT_SAMPLING", "request_key"]
