from .engine import Request, ServeStats, ServingEngine

__all__ = ["Request", "ServeStats", "ServingEngine"]
