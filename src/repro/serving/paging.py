"""Paged KV state: block pool, block tables, prefix sharing, freeze/thaw.

The dense serving cache allocates ``max_seq`` KV rows per decode slot up
front, so a slot's memory cost is its *worst case* and a request's state
lives and dies with its engine.  This module is the host-side half of
``decode_mode="paged"``:

* :class:`BlockPool` — a refcounted allocator over ``num_blocks`` fixed
  ``block_size``-row KV blocks.  Block 0 is a pinned **trash block**:
  table entries that don't (yet) map a real block point at it, so masked
  decode writes from inactive slots land somewhere harmless and gathers
  of not-yet-written positions read garbage that the causal mask zeroes
  out (``decode_attention`` *replaces* masked scores with ``NEG_INF``,
  so garbage beyond ``pos`` contributes exactly 0 — the paged dense view
  is bit-identical to the dense cache).
* **Block tables** — the pool hands each slot a row of a host
  ``(slots, max_seq // block_size)`` int32 table.  Tables are *runtime
  data*: they ride into the jitted paged step as an ordinary array
  argument of constant shape, so occupancy changes never recompile and
  the :class:`~repro.serving.compile_cache.CompileCache` key stays
  ``(cfg, opts, slots, max_seq, domain)``.
* **Prefix sharing** — prompts are left-padded to power-of-two buckets
  that are always block-aligned, so a prompt's KV occupies whole blocks
  whose content is a pure function of the *padded* token prefix through
  the block (attention is causal).  The pool keeps a chain-hash →
  block index; after a burst prefill, freshly written blocks whose
  hashes already map a live block are merged (the duplicate is freed,
  the survivor increfed) — same-system-prompt admissions share prefill
  blocks, copy-on-write: decode writes always target a private tail
  block, and :meth:`BlockPool.needs_copy` guards the invariant.
* :class:`PrefixCache` — a full-prompt index over finished prefills
  (blocks + the last-position logits row + the non-KV cache leaves), so
  re-admitting an already-seen padded prompt skips the prefill jit call
  entirely: blocks are increfed, the first token is sampled from the
  cached logits row with the request's own key (bit-identical to a real
  prefill), and ``prefill_calls`` does not grow.
* :class:`FrozenRequest` — ``freeze(rid)`` serializes a request's pages
  (trimmed to ``pos`` and densified, so the blob is portable across
  block sizes and into dense engines), its non-KV cache leaves, its
  *advanced* sampling key and its consumed-token count into a host
  blob; ``thaw`` re-materializes it on any engine whose
  ``(cfg, opts, params_version)`` fingerprint matches — zero token
  loss, zero re-prefill.  This is the migration primitive the fleet
  controller uses to move in-flight work off an evicted device.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["DEFAULT_BLOCK_SIZE", "BlockPool", "PrefixCache", "PrefixEntry",
           "FrozenRequest", "block_hash_chain", "blocks_needed"]

DEFAULT_BLOCK_SIZE = 16

# table entries that don't map a real block point here; never allocated
TRASH_BLOCK = 0


def blocks_needed(n_rows: int, block_size: int) -> int:
    """Blocks required to hold ``n_rows`` KV rows."""
    return -(-n_rows // block_size)


def kv_bytes_per_block(n_attn: int, block_size: int, num_kv_heads: int,
                       head_dim: int, kv_dtype: str = "auto",
                       kv_cache_dtype: str = "bfloat16") -> int:
    """Device bytes one pool block costs (K + V, plus int8 scale planes).

    ``kv_dtype`` mirrors ``RuntimeOptions.kv_dtype``: ``"auto"`` stores
    blocks in ``kv_cache_dtype``; ``"int8"`` stores one byte per element
    plus a ``(n_attn, block_size)`` f32 scale plane per side — the
    denominator of the bench's residency-gain axis (how many more slots
    fit in the same pool budget when the KV store is quantized)."""
    elems = n_attn * block_size * num_kv_heads * head_dim
    if kv_dtype == "int8":
        return 2 * (elems + 4 * n_attn * block_size)
    itemsize = {"float32": 4, "bfloat16": 2, "float16": 2,
                "fp8": 1}.get(kv_cache_dtype, 2)
    return 2 * elems * itemsize


def block_hash_chain(padded_tokens: np.ndarray, block_size: int,
                     salt: Any = None) -> List[bytes]:
    """Chain hashes of a left-padded prompt, one per *full* block.

    The hash of block ``b`` covers padded positions ``[0, (b+1)*bs)`` —
    causal attention makes a block's KV content a pure function of that
    prefix — so equal hashes ⇒ bit-identical block content for the same
    ``(cfg, opts, params)``.  ``salt`` folds anything else that changes
    content (e.g. the engine's params_version) into every hash."""
    toks = np.ascontiguousarray(padded_tokens, dtype=np.int32)
    out: List[bytes] = []
    h = hashlib.blake2b(repr(salt).encode(), digest_size=16)
    for b in range(len(toks) // block_size):
        h.update(toks[b * block_size:(b + 1) * block_size].tobytes())
        out.append(h.digest())
        h = hashlib.blake2b(h.digest(), digest_size=16)
    return out


class BlockPool:
    """Host-side refcounted allocator over the device block pool.

    Owns the per-slot block tables and the chain-hash index used for
    prefix dedup.  Purely host bookkeeping — device arrays live in the
    engine; the pool only decides *which* block index goes where."""

    def __init__(self, slots: int, num_blocks: int, block_size: int,
                 max_seq: int):
        if num_blocks < 2:
            raise ValueError("pool needs at least one real block + trash")
        if max_seq % block_size:
            raise ValueError(f"block_size {block_size} must divide "
                             f"max_seq {max_seq}")
        self.slots = slots
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.blocks_per_slot = max_seq // block_size
        self.tables = np.zeros((slots, self.blocks_per_slot), np.int32)
        self.refs = np.zeros(num_blocks, np.int64)
        self.refs[TRASH_BLOCK] = 1          # pinned forever
        self._free: Deque[int] = deque(range(1, num_blocks))
        # chain-hash index for prefix dedup: hash -> live block id
        self._hash_block: Dict[bytes, int] = {}
        self._block_hash: Dict[int, bytes] = {}

    # ------------------------------------------------------------- gauges --
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Real blocks currently referenced (trash excluded)."""
        return self.num_blocks - 1 - len(self._free)

    @property
    def shared_blocks(self) -> int:
        return int((self.refs[1:] > 1).sum())

    # -------------------------------------------------------- alloc/free --
    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """``n`` fresh blocks at refcount 1, or ``None`` (nothing taken)
        when the pool can't satisfy the whole request."""
        if len(self._free) < n:
            return None
        ids = [self._free.popleft() for _ in range(n)]
        for b in ids:
            self.refs[b] = 1
        return ids

    def incref(self, bid: int) -> None:
        if bid != TRASH_BLOCK:
            self.refs[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        if bid == TRASH_BLOCK:
            return False
        self.refs[bid] -= 1
        if self.refs[bid] > 0:
            return False
        h = self._block_hash.pop(bid, None)
        if h is not None and self._hash_block.get(h) == bid:
            del self._hash_block[h]
        self._free.append(bid)
        return True

    # ------------------------------------------------------------ tables --
    def assign(self, slot: int, idx: int, bid: int) -> None:
        self.tables[slot, idx] = bid

    def release_slot(self, slot: int) -> int:
        """Drop the slot's references; returns number of blocks freed."""
        freed = 0
        for idx in range(self.blocks_per_slot):
            bid = int(self.tables[slot, idx])
            if bid != TRASH_BLOCK:
                freed += self.decref(bid)
            self.tables[slot, idx] = TRASH_BLOCK
        return freed

    def needs_copy(self, slot: int, pos: int) -> bool:
        """Copy-on-write guard: True when the block the next decode write
        lands in is shared (refcount > 1).  Prompt buckets are
        block-aligned and thawed blocks are private, so this is an
        invariant check rather than a hot path."""
        bid = int(self.tables[slot, pos // self.block_size])
        return bid != TRASH_BLOCK and self.refs[bid] > 1

    # ------------------------------------------------------ prefix dedup --
    def register_hash(self, bid: int, chash: bytes) -> None:
        self._block_hash[bid] = chash
        self._hash_block.setdefault(chash, bid)

    def shared_for(self, chash: bytes) -> Optional[int]:
        """A live block already holding content for this chain hash."""
        bid = self._hash_block.get(chash)
        if bid is not None and self.refs[bid] > 0:
            return bid
        return None

    def dedup_slot_prefix(self, slot: int, hashes: List[bytes]) -> int:
        """After a burst prefill wrote ``len(hashes)`` fresh prompt blocks
        into ``slot``'s table, merge any block whose chain hash already
        maps a live block: the slot adopts the shared block (incref) and
        the freshly written duplicate is freed.  First writer registers.
        Returns the number of blocks merged away."""
        merged = 0
        for idx, chash in enumerate(hashes):
            own = int(self.tables[slot, idx])
            shared = self.shared_for(chash)
            if shared is not None and shared != own:
                self.incref(shared)
                self.decref(own)
                self.tables[slot, idx] = shared
                merged += 1
            else:
                self.register_hash(own, chash)
        return merged


@dataclass
class PrefixEntry:
    """A finished prefill, reusable by any later identical padded prompt.

    Holds pool block ids (the entry owns one reference each), the
    last-position logits row (device array — sampling a new request's
    first token from it with its *own* key reproduces a real prefill bit
    for bit), and the non-KV batch=1 cache leaves at ``pos``."""
    block_ids: Tuple[int, ...]
    logits_row: Any                       # (vocab,) device array
    leaves: Dict[str, np.ndarray]         # non-KV batch=1 cache leaves
    pos: int
    hits: int = 0


class PrefixCache:
    """LRU full-prompt index: padded-prompt key → :class:`PrefixEntry`.

    Entries hold block references, so a cached prefix survives its
    original request; under pool pressure the engine evicts LRU entries
    to reclaim blocks before declaring exhaustion."""

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self._entries: "OrderedDict[Any, PrefixEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def key_of(self, padded_tokens: np.ndarray, salt: Any) -> Any:
        return (repr(salt), len(padded_tokens),
                np.ascontiguousarray(padded_tokens, np.int32).tobytes())

    def lookup(self, key: Any) -> Optional[PrefixEntry]:
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        e.hits += 1
        return e

    def insert(self, key: Any, entry: PrefixEntry, pool: BlockPool) -> None:
        if key in self._entries or entry.pos <= 0:
            return
        for bid in entry.block_ids:
            pool.incref(bid)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._evict_one(pool)

    def _evict_one(self, pool: BlockPool) -> int:
        _, e = self._entries.popitem(last=False)
        return sum(pool.decref(b) for b in e.block_ids)

    def evict_for_blocks(self, n: int, pool: BlockPool) -> int:
        """Free entries (LRU-first) until ``n`` blocks are available or
        the cache is empty.  Returns blocks actually freed."""
        freed = 0
        while pool.free_blocks < n and self._entries:
            freed += self._evict_one(pool)
        return freed

    def clear(self, pool: BlockPool) -> None:
        while self._entries:
            self._evict_one(pool)


@dataclass
class FrozenRequest:
    """A request's serialized in-flight state: everything needed to
    resume decoding on a compatible engine with zero re-prefill.

    ``leaves`` is the batch=1 cache pytree as host numpy, with the dense
    ``k``/``v`` trimmed to ``pos`` rows — densified so the blob is
    portable across block sizes, into dense-batched engines and into the
    per-slot reference loop.  ``sample`` carries the *advanced* PRNG key
    plus temperature/top-k, so the thawed stream continues bit-identical
    to the uninterrupted one.  ``fingerprint`` is
    ``(cfg, opts, params_version)``: thawing against different weights
    would silently reuse stale KV, so a mismatch falls back to the
    legacy requeue-with-re-prefill path."""
    rid: int
    pos: int
    consumed: int                          # len(generated) at freeze time
    leaves: Dict[str, np.ndarray]
    sample: Dict[str, np.ndarray]
    fingerprint: Tuple[Any, Any, Any]
    reason: str = "freeze"

    @property
    def kv_rows(self) -> int:
        k = self.leaves.get("k")
        return 0 if k is None else int(k.shape[2])
