"""Process-wide cache of jitted serving programs.

A 15-device fleet used to mean ~15 identical decode programs: every
:class:`~repro.serving.engine.ServingEngine` built its own ``jax.jit``
wrappers, and jax's compilation cache keys on function identity, so
nothing was shared.  ``CompileCache`` keys program sets on the things
that actually determine the compiled artifact — ``(cfg, opts, slots,
max_seq, domain)`` — and hands the *same* jitted callables to every
engine that asks, so same-platform fleet members compile once.

Sampling is deliberately **absent** from the key: per-slot temperature,
top-k and PRNG keys are runtime arrays inside the slot-stacked cache
(see :mod:`repro.serving.sampling`), so engines with heterogeneous
sampling policies still share every program.

``domain`` namespaces otherwise-identical keys by compile target
(platform/ISA): in a real deployment a pixel_6 cannot reuse a jetson's
binaries even for the same model, so the fleet controller passes each
device's :attr:`DeviceSpec.compile_domain` here.

Program set per key:

* ``decode``       — one batched sampling step over the slot-stacked
                     cache (``sample_batched_step`` under ``vmap``), with
                     the cache **donated** so KV/SSM buffers are updated
                     in place instead of copied every token; slots whose
                     temperature is 0 argmax exactly as before
* ``decode_greedy`` — the pure-argmax batched step; the engine selects it
                     on ticks where no active slot samples, so all-greedy
                     workloads never pay the sampling machinery
* ``decode_ref``   — the batch=1 reference decode returning raw logits
                     (kept for equivalence tests and benchmarks)
* ``sample_ref``   — the batch=1 sampling decode (the per-slot loop
                     path); ``decode`` is precisely ``vmap`` of this
* ``sample_first`` — draws a prefill's first token from its last-position
                     logits row (per-request admission path)
* ``admit_slot``   — writes a fresh prefill + its sampling state into one
                     slot of the stacked cache (stacked side donated;
                     slot index traced, so one program covers every slot)
* ``prefill(bucket)`` — per-prompt-bucket batch=1 prefill jits, lazy
* ``prefill_batch(bucket, k)`` — ONE-call burst admission: prefill a
                     ``(k, bucket)`` stack of same-bucket prompts and
                     scatter every row into its slot; keyed on the
                     k-bucket so mixed burst sizes reuse a handful of
                     programs instead of recompiling per shape

Paged-mode programs (``decode_mode="paged"``) are lazy dicts keyed on
the pool geometry ``(num_blocks, block_size)`` — block *tables* are
runtime int32 arrays of constant shape, so occupancy, sharing and
admission churn never recompile and the outer cache key stays
``(cfg, opts, slots, max_seq, domain)``:

* ``paged_decode(nb, bs)`` — one batched sampling step (slot cache +
                     pool donated); bit-identical to ``decode``.  With
                     ``opts.paged_kernel`` the program is the
                     kernel step (attention reads blocks through the
                     table — no gather-to-dense detour); ``opts`` is in
                     the cache key, so the selection never aliases
* ``paged_prefill_batch(bucket, k, nb, bs)`` — burst admission that
                     scatters prefilled KV into destination blocks
* ``paged_admit``   — writes non-KV leaves + sampling state into one
                     slot of the paged slot cache (thaw / prefix reuse)
* ``thaw_scatter(nblk, nb, bs)`` — writes a thawed request's densified
                     KV back into freshly allocated blocks
* ``copy_block(nb, bs)`` — copy-on-write block duplication
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax

from repro.models.configs import ModelConfig
from repro.models.model import (admit_slot, batched_prefill_admit,
                                decode_step, greedy_batched_step,
                                paged_copy_block,
                                paged_kernel_sample_batched_step,
                                paged_prefill_admit,
                                paged_sample_batched_step, paged_thaw_write,
                                prefill, sample_batched_step, sample_logits,
                                sample_step)
from repro.models.runtime import RuntimeOptions

Key = Tuple[ModelConfig, RuntimeOptions, int, int, str]


class ServePrograms:
    """The jitted callables for one (cfg, opts, slots, max_seq, domain)."""

    def __init__(self, cfg: ModelConfig, opts: RuntimeOptions,
                 max_seq: int = 512):
        self._cfg, self._opts, self._max_seq = cfg, opts, max_seq
        # donate the stacked cache: its buffers are rewritten every token,
        # so aliasing input→output storage avoids a full cache copy per step
        self.decode: Callable = jax.jit(
            lambda p, c, t: sample_batched_step(p, cfg, c, t, opts),
            donate_argnums=(1,))
        # all-greedy ticks skip the sampling machinery entirely (the
        # engine picks this program when no active slot has temp > 0;
        # outputs are bit-identical to `decode` at temperature 0)
        self.decode_greedy: Callable = jax.jit(
            lambda p, c, t: greedy_batched_step(p, cfg, c, t, opts),
            donate_argnums=(1,))
        self.decode_ref: Callable = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t, opts))
        self.sample_ref: Callable = jax.jit(
            lambda p, c, t: sample_step(p, cfg, c, t, opts))
        self.sample_first: Callable = jax.jit(
            lambda lg, k, t, tk: sample_logits(lg, k, t, tk, cfg.vocab_size))
        self.admit_slot: Callable = jax.jit(
            lambda stacked, c, i, k, t, tk: admit_slot(stacked, c, i, k, t,
                                                       tk),
            donate_argnums=(0,))
        self._prefills: Dict[int, Callable] = {}
        self._prefill_batches: Dict[Tuple[int, int], Callable] = {}
        self._paged_decodes: Dict[Tuple[int, int], Callable] = {}
        self._paged_prefill_batches: Dict[Tuple[int, int, int, int],
                                          Callable] = {}
        self._paged_admit: Dict[str, Callable] = {}
        self._thaw_scatters: Dict[Tuple[int, int, int], Callable] = {}
        self._copy_blocks: Dict[Tuple[int, int], Callable] = {}

    def prefill(self, bucket: int) -> Tuple[Callable, bool]:
        """The batch=1 prefill jit for one prompt bucket, plus whether this
        call created it (a compile the caller should account for)."""
        fresh = bucket not in self._prefills
        if fresh:
            cfg, opts = self._cfg, self._opts
            self._prefills[bucket] = jax.jit(
                lambda p, c, t: prefill(p, cfg, t, c, opts))
        return self._prefills[bucket], fresh

    def prefill_batch(self, bucket: int, k: int) -> Tuple[Callable, bool]:
        """The one-call burst-admission program for ``(prompt bucket,
        k-bucket)``: prefill ``(k, bucket)`` stacked prompts and scatter
        each row's cache + sampling state into its slot of the (donated)
        slot-stacked cache.  Callers bucket ``k`` (powers of two capped at
        the slot count) so mixed burst sizes share a handful of programs."""
        fresh = (bucket, k) not in self._prefill_batches
        if fresh:
            cfg, opts, max_seq = self._cfg, self._opts, self._max_seq
            self._prefill_batches[(bucket, k)] = jax.jit(
                lambda p, st, t, s, ky, tp, tk: batched_prefill_admit(
                    p, cfg, st, t, s, ky, tp, tk, opts, max_seq),
                donate_argnums=(1,))
        return self._prefill_batches[(bucket, k)], fresh

    # --------------------------------------------------- paged programs --
    def paged_decode(self, num_blocks: int,
                     block_size: int) -> Tuple[Callable, bool]:
        """The batched paged sampling step for one pool geometry.  Slot
        cache and pool are donated; block tables ride in as runtime
        data, so every occupancy shares this one program.
        ``opts.paged_kernel`` swaps in the block-table attention step
        (same signature, no gather-to-dense detour)."""
        key = (num_blocks, block_size)
        fresh = key not in self._paged_decodes
        if fresh:
            cfg, opts = self._cfg, self._opts
            step = (paged_kernel_sample_batched_step if opts.paged_kernel
                    else paged_sample_batched_step)
            self._paged_decodes[key] = jax.jit(
                lambda p, c, pl, t, tb: step(p, cfg, c, pl, t, tb, opts),
                donate_argnums=(1, 2))
        return self._paged_decodes[key], fresh

    def paged_prefill_batch(self, bucket: int, k: int, num_blocks: int,
                            block_size: int) -> Tuple[Callable, bool]:
        """Burst admission into the paged cache for ``(prompt bucket,
        k-bucket)``: KV rows scatter into destination blocks, non-KV
        leaves + sampling into slots (slot cache and pool donated)."""
        key = (bucket, k, num_blocks, block_size)
        fresh = key not in self._paged_prefill_batches
        if fresh:
            cfg, opts = self._cfg, self._opts
            self._paged_prefill_batches[key] = jax.jit(
                lambda p, st, pl, t, s, ky, tp, tk, db: paged_prefill_admit(
                    p, cfg, st, pl, t, s, ky, tp, tk, db, opts),
                donate_argnums=(1, 2))
        return self._paged_prefill_batches[key], fresh

    def paged_admit(self) -> Tuple[Callable, bool]:
        """``admit_slot`` over the paged (KV-less) slot cache: writes one
        request's non-KV leaves plus sampling state (thaw and
        prefix-reuse admissions; stacked side donated)."""
        fresh = "admit" not in self._paged_admit
        if fresh:
            self._paged_admit["admit"] = jax.jit(
                lambda st, c, i, k, t, tk: admit_slot(st, c, i, k, t, tk),
                donate_argnums=(0,))
        return self._paged_admit["admit"], fresh

    def thaw_scatter(self, nblk: int, num_blocks: int,
                     block_size: int) -> Tuple[Callable, bool]:
        """Writes ``nblk`` densified thawed KV blocks into the (donated)
        pool; keyed on the block count so thaws of similar depth share
        programs (callers bucket ``nblk`` via the prompt buckets)."""
        key = (nblk, num_blocks, block_size)
        fresh = key not in self._thaw_scatters
        if fresh:
            self._thaw_scatters[key] = jax.jit(
                lambda pl, rk, rv, ids: paged_thaw_write(pl, rk, rv, ids),
                donate_argnums=(0,))
        return self._thaw_scatters[key], fresh

    def copy_block(self, num_blocks: int,
                   block_size: int) -> Tuple[Callable, bool]:
        """Copy-on-write block duplication (src/dst traced; pool
        donated) — one program per pool geometry."""
        key = (num_blocks, block_size)
        fresh = key not in self._copy_blocks
        if fresh:
            self._copy_blocks[key] = jax.jit(
                lambda pl, s, d: paged_copy_block(pl, s, d),
                donate_argnums=(0,))
        return self._copy_blocks[key], fresh


class CompileCache:
    """Shares :class:`ServePrograms` across engines.  Thread-hostile like
    the rest of the serving layer (one engine loop per process)."""

    def __init__(self):
        self._entries: Dict[Key, ServePrograms] = {}
        self.hits = 0
        self.misses = 0

    def entry_for(self, cfg: ModelConfig, opts: RuntimeOptions, slots: int,
                  max_seq: int, domain: str = ""
                  ) -> Tuple[ServePrograms, bool]:
        key: Key = (cfg, opts, slots, max_seq, domain)
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            return entry, False
        self.misses += 1
        entry = ServePrograms(cfg, opts, max_seq)
        self._entries[key] = entry
        return entry, True

    def __len__(self) -> int:
        return len(self._entries)


# Engines that aren't handed an explicit cache share this one, so two
# engines in one process never compile the same program twice.
GLOBAL_COMPILE_CACHE = CompileCache()
