"""Process-wide cache of jitted serving programs.

A 15-device fleet used to mean ~15 identical decode programs: every
:class:`~repro.serving.engine.ServingEngine` built its own ``jax.jit``
wrappers, and jax's compilation cache keys on function identity, so
nothing was shared.  ``CompileCache`` keys program sets on the things
that actually determine the compiled artifact — ``(cfg, opts, slots,
max_seq, domain)`` — and hands the *same* jitted callables to every
engine that asks, so same-platform fleet members compile once.

``domain`` namespaces otherwise-identical keys by compile target
(platform/ISA): in a real deployment a pixel_6 cannot reuse a jetson's
binaries even for the same model, so the fleet controller passes each
device's :attr:`DeviceSpec.compile_domain` here.

Program set per key:

* ``decode``     — one batched greedy step over the slot-stacked cache
                   (``greedy_batched_step`` under ``vmap``), with the
                   cache **donated** so KV/SSM buffers are updated in
                   place instead of copied every token
* ``decode_ref`` — the batch=1 reference decode (the per-slot loop path,
                   kept for equivalence tests and benchmarks)
* ``write_slot`` — writes a fresh prefill into one slot of the stacked
                   cache (stacked side donated; slot index traced, so one
                   program covers every slot)
* ``prefill(bucket)`` — per-prompt-bucket prefill jits, built lazily
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax

from repro.models.configs import ModelConfig
from repro.models.model import (decode_step, greedy_batched_step, prefill,
                                write_cache_slot)
from repro.models.runtime import RuntimeOptions

Key = Tuple[ModelConfig, RuntimeOptions, int, int, str]


class ServePrograms:
    """The jitted callables for one (cfg, opts, slots, max_seq, domain)."""

    def __init__(self, cfg: ModelConfig, opts: RuntimeOptions):
        self._cfg, self._opts = cfg, opts
        # donate the stacked cache: its buffers are rewritten every token,
        # so aliasing input→output storage avoids a full cache copy per step
        self.decode: Callable = jax.jit(
            lambda p, c, t: greedy_batched_step(p, cfg, c, t, opts),
            donate_argnums=(1,))
        self.decode_ref: Callable = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t, opts))
        self.write_slot: Callable = jax.jit(
            lambda stacked, c, i: write_cache_slot(stacked, c, i),
            donate_argnums=(0,))
        self._prefills: Dict[int, Callable] = {}

    def prefill(self, bucket: int) -> Tuple[Callable, bool]:
        """The prefill jit for one prompt bucket, plus whether this call
        created it (a compile the caller should account for)."""
        fresh = bucket not in self._prefills
        if fresh:
            cfg, opts = self._cfg, self._opts
            self._prefills[bucket] = jax.jit(
                lambda p, c, t: prefill(p, cfg, t, c, opts))
        return self._prefills[bucket], fresh


class CompileCache:
    """Shares :class:`ServePrograms` across engines.  Thread-hostile like
    the rest of the serving layer (one engine loop per process)."""

    def __init__(self):
        self._entries: Dict[Key, ServePrograms] = {}
        self.hits = 0
        self.misses = 0

    def entry_for(self, cfg: ModelConfig, opts: RuntimeOptions, slots: int,
                  max_seq: int, domain: str = ""
                  ) -> Tuple[ServePrograms, bool]:
        key: Key = (cfg, opts, slots, max_seq, domain)
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            return entry, False
        self.misses += 1
        entry = ServePrograms(cfg, opts)
        self._entries[key] = entry
        return entry, True

    def __len__(self) -> int:
        return len(self._entries)


# Engines that aren't handed an explicit cache share this one, so two
# engines in one process never compile the same program twice.
GLOBAL_COMPILE_CACHE = CompileCache()
