"""Sharding-aware checkpointing (msgpack container + per-leaf npy blobs).

Saves the param/optimizer pytree with its PartitionSpec metadata so a
restore onto a different mesh re-shards correctly (the paper's model
porting across heterogeneous deployments).  No orbax dependency — the
container format is flat and explicit.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    import msgpack
    _HAVE_MSGPACK = True
except Exception:  # pragma: no cover
    _HAVE_MSGPACK = False


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | Path, tree: Any, *, step: int = 0,
                    metadata: Optional[Dict[str, Any]] = None) -> Path:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {},
                "leaves": {}}
    for key, arr in flat.items():
        fn = key.replace("/", "__") + ".npy"
        np.save(path / fn, arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    if _HAVE_MSGPACK:
        (path / "manifest.msgpack").write_bytes(
            msgpack.packb(manifest, use_bin_type=True))
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return path


def restore_checkpoint(path: str | Path, like: Any,
                       shardings: Optional[Any] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype validated);
    ``shardings`` (same structure) re-shards each leaf on load."""
    path = Path(path)
    mpath = path / "manifest.msgpack"
    if _HAVE_MSGPACK and mpath.exists():
        manifest = msgpack.unpackb(mpath.read_bytes(), raw=False)
    else:
        manifest = json.loads((path / "manifest.json").read_text())
    leaves = manifest["leaves"]

    flat_sh = None
    if shardings is not None:
        flat_sh = {k: s for k, s in _flatten_paths(shardings)}

    def load(kp, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        info = leaves[key]
        arr = np.load(path / info["file"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if flat_sh is not None and key in flat_sh and flat_sh[key] is not None:
            return jax.device_put(arr, flat_sh[key])
        return jnp.asarray(arr)

    tree = jax.tree_util.tree_map_with_path(load, like)
    return tree, int(manifest["step"])


def _flatten_paths(tree: Any):
    for kp, leaf in jax.tree_util.tree_leaves_with_path(
            tree, is_leaf=lambda x: x is None):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        yield key, leaf


def latest_checkpoint(root: str | Path) -> Optional[Path]:
    root = Path(root)
    if not root.exists():
        return None
    cands = sorted(p for p in root.iterdir()
                   if p.is_dir() and (p / "manifest.json").exists())
    return cands[-1] if cands else None
