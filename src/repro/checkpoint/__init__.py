from .io import latest_checkpoint, restore_checkpoint, save_checkpoint

__all__ = ["latest_checkpoint", "restore_checkpoint", "save_checkpoint"]
