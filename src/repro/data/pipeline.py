"""Synthetic sharded data pipeline.

Deterministic, seekable token streams (a mixture of Zipfian unigram noise
and copy/induction patterns so a ~100M model has real structure to learn),
plus drift injection for the TTA experiments — the live-data distribution
shift the paper's runtime parameter adaptation handles.

Batches are produced host-side as numpy and placed with the batch sharding,
which is exactly what a multi-host input pipeline does per-process.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import InputShape, ModelConfig


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 16      # induction structure: token repeats period
    drift: float = 0.0         # 0..1 distribution shift magnitude


class SyntheticLM:
    """Seekable synthetic LM stream: batch(i) is pure function of (seed, i)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        base = 1.0 / np.arange(1, v + 1) ** cfg.zipf_a
        self.base_probs = base / base.sum()
        # drifted distribution: permuted zipf mixed in
        perm = rng.permutation(v)
        self.drift_probs = self.base_probs[perm]

    def probs(self) -> np.ndarray:
        d = self.cfg.drift
        return (1 - d) * self.base_probs + d * self.drift_probs

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, index))
        p = self.probs()
        toks = rng.choice(c.vocab_size, size=(c.batch_size, c.seq_len + 1),
                          p=p).astype(np.int32)
        # induction structure: every copy_period-th token repeats the one
        # copy_period earlier — learnable signal for the train driver
        for off in range(c.copy_period, c.seq_len + 1, c.copy_period):
            toks[:, off] = toks[:, off - c.copy_period]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def make_batch_fn(cfg: ModelConfig, shape: InputShape, seed: int = 0,
                  drift: float = 0.0):
    """Batch factory including the modality-stub inputs (audio frames /
    vision patch embeddings) each arch family needs."""
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=shape.seq_len,
                                  batch_size=shape.global_batch,
                                  seed=seed, drift=drift))

    def get(index: int) -> Dict[str, np.ndarray]:
        b = data.batch(index)
        rng = np.random.default_rng((seed, index, 7))
        if cfg.is_encoder_decoder:
            b["encoder_frames"] = rng.standard_normal(
                (shape.global_batch, cfg.encoder_seq_len, cfg.d_model)
            ).astype(np.float32) * 0.1
        if cfg.vision_embed_dim:
            b["vision_embeds"] = rng.standard_normal(
                (shape.global_batch, cfg.num_vision_tokens,
                 cfg.vision_embed_dim)).astype(np.float32) * 0.1
        return b

    return get


def place_batch(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    out = {}
    for k, v in batch.items():
        sh = shardings.get(k) if hasattr(shardings, "get") else None
        out[k] = jax.device_put(v, sh) if sh is not None else jnp.asarray(v)
    return out
