from .pipeline import DataConfig, SyntheticLM, make_batch_fn, place_batch

__all__ = ["DataConfig", "SyntheticLM", "make_batch_fn", "place_batch"]
