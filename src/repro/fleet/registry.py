"""Heterogeneous device registry (the "15 platforms" of paper §IV-A).

Each platform is a ``PlatformProfile``: a :class:`HardwareProfile` (the
roofline/Eq.1/Eq.2 substrate the profiler consumes) plus the resource
envelope the monitor projects shared scenarios through — battery
capacity, typical memory headroom, DVFS floor — and the *latent*
prediction error the analytic profiler makes on that silicon.  The
latent factors are ground truth for the telemetry simulation: the
profiler never sees them directly; it only observes their effect on
measured step timings, which is exactly the gap crowd-shared
calibration exists to close.

Tiers group platforms by capability class (heavy / medium / light);
devices of one tier share most of their systematic profiler bias (same
ISA family, same memory subsystem idioms), which is what makes
cross-device calibration transfer — the "crowd" in CrowdHMTware —
well-posed.
"""
from __future__ import annotations

import dataclasses
import random
import zlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.monitor import ResourceContext, case_study_trace, shaped_trace
from repro.core.profiler import HardwareProfile, MOBILE_CPU, TPU_V5E

HEAVY, MEDIUM, LIGHT = "heavy", "medium", "light"
TIERS = (HEAVY, MEDIUM, LIGHT)

# Nominal seconds of simulated time between adaptation-loop wakes per
# tier.  Heavy silicon re-evaluates its deployment more often than a
# little-core phone: its monitor sampling, profiler sweep and apply step
# all cost a fraction of what they cost downmarket.  These set the
# *relative* tick rates of the event-driven fleet scheduler; absolute
# values are arbitrary simulated seconds.
TIER_TICK_S: Dict[str, float] = {HEAVY: 0.25, MEDIUM: 0.5, LIGHT: 1.0}

# site a device lives at unless the fleet builder says otherwise — a
# single-site fleet is the legacy behavior (every peer one LAN hop away)
DEFAULT_SITE = "site0"


@dataclass(frozen=True)
class TickEnvelope:
    """Per-device bounds on the adaptation-loop wake period.

    ``nominal_s`` is the steady-state period between wakes (the tier's
    base rate scaled by :attr:`DeviceSpec.tick_scale`); ``min_s`` is the
    fastest the device is allowed to re-adapt (its nominal rate — a
    device never runs its loop faster than designed); ``max_s`` is the
    slowest it degrades to under a full DVFS throttle
    (``nominal_s / dvfs_floor``).  The event scheduler derives every
    next-wake time by clamping the DVFS-derated period into this
    envelope, then adding any measured execution latency on top."""
    nominal_s: float
    min_s: float
    max_s: float

    def clamp(self, period_s: float) -> float:
        """Bound a candidate wake period into [min_s, max_s]."""
        return min(max(period_s, self.min_s), self.max_s)


@dataclass(frozen=True)
class PlatformProfile:
    """One of the registry's hardware platforms."""
    platform: str
    tier: str
    hw: HardwareProfile
    battery_wh: float            # ∞-ish for wall-powered platforms
    mem_headroom: float          # fraction of hbm_bytes typically free
    dvfs_floor: float            # worst-case sustained clock derate
    chips: int = 1
    # systematic analytic-profiler bias on this platform (ground truth the
    # telemetry loop must discover; >1 = profiler is optimistic)
    latency_bias: float = 1.0
    energy_bias: float = 1.0


def _hw(name, flops, bw, link, mem, idle, peak) -> HardwareProfile:
    return HardwareProfile(name=name, peak_flops=flops, hbm_bw=bw,
                           ici_bw=link, hbm_bytes=mem, idle_w=idle,
                           peak_w=peak)


# ~15 platforms spanning TPU pods down to little-core phone CPUs.  Numbers
# are order-of-magnitude public specs, not measurements.
PLATFORMS: Dict[str, PlatformProfile] = {p.platform: p for p in (
    # ---------------------------------------------------------- heavy -----
    PlatformProfile("tpu_v5e", HEAVY, TPU_V5E, 1e9, 0.85, 0.95, chips=4,
                    latency_bias=1.18, energy_bias=1.10),
    PlatformProfile("tpu_v4i", HEAVY,
                    _hw("tpu_v4i", 138e12, 615e9, 50e9, 8e9, 55, 175),
                    1e9, 0.85, 0.95, chips=4,
                    latency_bias=1.22, energy_bias=1.12),
    PlatformProfile("edge_server_a100", HEAVY,
                    _hw("edge_server_a100", 312e12, 1555e9, 25e9, 40e9,
                        100, 400),
                    1e9, 0.80, 0.90,
                    latency_bias=1.15, energy_bias=1.20),
    PlatformProfile("desktop_4090", HEAVY,
                    _hw("desktop_4090", 165e12, 1008e9, 8e9, 24e9, 60, 450),
                    1e9, 0.75, 0.90,
                    latency_bias=1.20, energy_bias=1.25),
    PlatformProfile("jetson_agx_orin", HEAVY,
                    _hw("jetson_agx_orin", 10.6e12, 204e9, 1e9, 64e9, 15, 60),
                    90.0, 0.70, 0.80,
                    latency_bias=1.25, energy_bias=1.15),
    # --------------------------------------------------------- medium -----
    PlatformProfile("jetson_orin_nano", MEDIUM,
                    _hw("jetson_orin_nano", 2.5e12, 68e9, 0.5e9, 8e9, 5, 15),
                    40.0, 0.60, 0.70,
                    latency_bias=1.38, energy_bias=1.30),
    PlatformProfile("apple_a17_npu", MEDIUM,
                    _hw("apple_a17_npu", 2.1e12, 51e9, 0.2e9, 8e9, 0.5, 8),
                    13.0, 0.55, 0.65,
                    latency_bias=1.35, energy_bias=1.28),
    PlatformProfile("snapdragon_8g3_npu", MEDIUM,
                    _hw("snapdragon_8g3_npu", 1.7e12, 77e9, 0.2e9, 12e9,
                        0.5, 7),
                    19.0, 0.55, 0.65,
                    latency_bias=1.42, energy_bias=1.33),
    PlatformProfile("mali_g720_gpu", MEDIUM,
                    _hw("mali_g720_gpu", 0.9e12, 60e9, 0.1e9, 8e9, 0.4, 6),
                    18.0, 0.50, 0.60,
                    latency_bias=1.45, energy_bias=1.35),
    PlatformProfile("raspberry_pi5", MEDIUM,
                    _hw("raspberry_pi5", 30e9, 17e9, 0.1e9, 8e9, 2.5, 12),
                    1e9, 0.65, 0.85,
                    latency_bias=1.40, energy_bias=1.25),
    # ---------------------------------------------------------- light -----
    PlatformProfile("snapdragon_8g3_cpu", LIGHT, dataclasses.replace(
        MOBILE_CPU, name="snapdragon_8g3_cpu", peak_flops=40e9, hbm_bw=9e9),
        19.0, 0.45, 0.55,
        latency_bias=1.60, energy_bias=1.45),
    PlatformProfile("dimensity_700_cpu", LIGHT, dataclasses.replace(
        MOBILE_CPU, name="dimensity_700_cpu", peak_flops=18e9, hbm_bw=6e9),
        16.0, 0.40, 0.50,
        latency_bias=1.68, energy_bias=1.50),
    PlatformProfile("pixel_6_cpu", LIGHT, dataclasses.replace(
        MOBILE_CPU, name="pixel_6_cpu", peak_flops=24e9, hbm_bw=7e9),
        17.0, 0.45, 0.55,
        latency_bias=1.62, energy_bias=1.48),
    PlatformProfile("raspberry_pi4", LIGHT, dataclasses.replace(
        MOBILE_CPU, name="raspberry_pi4", peak_flops=13e9, hbm_bw=4e9,
        hbm_bytes=4e9),
        1e9, 0.50, 0.75,
        latency_bias=1.55, energy_bias=1.40),
    PlatformProfile("cortex_a55_quad", LIGHT, dataclasses.replace(
        MOBILE_CPU, name="cortex_a55_quad", peak_flops=8e9, hbm_bw=3e9,
        hbm_bytes=1e9),
        10.0, 0.35, 0.45,
        latency_bias=1.72, energy_bias=1.55),
)}


def platforms_by_tier(tier: str) -> List[PlatformProfile]:
    """All registry platforms in one capability tier (``"heavy"``,
    ``"medium"`` or ``"light"``), in registry declaration order — the
    order :func:`build_fleet` round-robins over when instantiating a
    mixed fleet."""
    return [p for p in PLATFORMS.values() if p.tier == tier]


# ----------------------------------------------------------- device spec ---
@dataclass(frozen=True)
class DeviceSpec:
    """One concrete device in the fleet: a platform instance plus the
    per-unit silicon-lottery jitter on the platform's latent bias."""
    device_id: str
    platform: str
    tier: str
    hw: HardwareProfile
    chips: int
    battery_wh: float
    mem_headroom: float
    dvfs_floor: float
    latent_latency_factor: float      # true observed/predicted latency ratio
    latent_energy_factor: float
    trace_seed: int = 0
    # multiplier on the tier's nominal wake period — >1 slows this unit's
    # adaptation loop (a busy or degraded device); tests use it to pin an
    # artificially slow fleet member
    tick_scale: float = 1.0
    # physical location: devices sharing a site reach each other over the
    # LAN link of the fleet's SiteTopology; cross-site hops pay WAN cost.
    # Cross-device placement prefers idle same-site helpers.
    site: str = DEFAULT_SITE
    # how far the analytic accuracy proxy overshoots the *crowd-labeled*
    # task accuracy on this unit (ground truth for the accuracy telemetry
    # channel; the proxy never sees it directly)
    latent_accuracy_bias: float = 0.0

    @property
    def wall_powered(self) -> bool:
        return self.battery_wh >= 1e6

    @property
    def tick_envelope(self) -> TickEnvelope:
        """The device's wake-period bounds for the event-driven fleet
        scheduler: nominal period = tier base rate × ``tick_scale``,
        degrading at worst to ``nominal / dvfs_floor`` under throttle."""
        base = TIER_TICK_S[self.tier] * self.tick_scale
        return TickEnvelope(nominal_s=base, min_s=base,
                            max_s=base / max(self.dvfs_floor, 1e-3))

    @property
    def compile_domain(self) -> str:
        """Namespace for shared jit programs: compiled artifacts are
        platform/toolchain-specific, so devices of one platform can reuse
        each other's programs while cross-platform reuse is forbidden.
        The fleet compile cache keys on this."""
        return self.platform


def make_device(platform: str, index: int, seed: int = 0,
                site: str = DEFAULT_SITE) -> DeviceSpec:
    """Instantiate device ``index`` of a platform at ``site``.  The
    per-unit jitter is small (±5%) relative to the platform's systematic
    bias, so same-tier calibration transfers while still leaving a
    residual only per-device measurements could remove."""
    p = PLATFORMS[platform]
    # zlib.crc32, not hash(): str hashing is salted per-process and would
    # break cross-run determinism of the fleet
    phash = zlib.crc32(platform.encode())
    rng = random.Random((phash & 0xFFFF) * 1009 + index * 97 + seed)
    jit_l = 1.0 + rng.uniform(-0.05, 0.05)
    jit_e = 1.0 + rng.uniform(-0.05, 0.05)
    # proxy overshoot grows downmarket: heavy silicon runs closer to the
    # reference task pipeline the proxy was anchored on
    acc_base = {HEAVY: 0.015, MEDIUM: 0.03, LIGHT: 0.05}[p.tier]
    return DeviceSpec(
        device_id=f"{platform}#{index}",
        platform=platform, tier=p.tier, hw=p.hw, chips=p.chips,
        battery_wh=p.battery_wh, mem_headroom=p.mem_headroom,
        dvfs_floor=p.dvfs_floor,
        latent_latency_factor=p.latency_bias * jit_l,
        latent_energy_factor=p.energy_bias * jit_e,
        trace_seed=seed + index * 31 + (phash & 0xFF),
        site=site,
        latent_accuracy_bias=acc_base * (1.0 + rng.uniform(-0.3, 0.3)))


def build_fleet(n: int, seed: int = 0,
                tiers: Tuple[str, ...] = TIERS,
                sites: Tuple[str, ...] = (DEFAULT_SITE,)) -> List[DeviceSpec]:
    """A heterogeneous fleet of ``n`` devices, round-robin over every
    platform in the requested tiers (so any n ≥ #platforms covers all of
    them, and smaller fleets still mix tiers).  The pool interleaves
    tiers — heavy[0], medium[0], light[0], heavy[1], … — so even a
    3-device fleet spans all capability classes.  ``sites`` assigns each
    device a location round-robin (default: everyone at one site, i.e.
    every peer one LAN hop away)."""
    per_tier = [platforms_by_tier(t) for t in tiers]
    if not any(per_tier):
        raise ValueError(f"no platforms in tiers {tiers}")
    pool = []
    for i in range(max(len(ps) for ps in per_tier)):
        for ps in per_tier:
            if i < len(ps):
                pool.append(ps[i])
    counts: Dict[str, int] = {}
    fleet = []
    for i in range(n):
        p = pool[i % len(pool)]
        idx = counts.get(p.platform, 0)
        counts[p.platform] = idx + 1
        fleet.append(make_device(p.platform, idx, seed=seed,
                                 site=sites[i % len(sites)]))
    return fleet


# -------------------------------------------------------- per-device trace --
def device_trace(spec: DeviceSpec, n: int = 24,
                 base: Optional[Iterator[ResourceContext]] = None
                 ) -> Iterator[ResourceContext]:
    """The shared day-long scenario projected through this device's
    envelope.  Wall-powered devices don't drain; small batteries drain
    faster than the fleet-wide curve; weak coolers throttle harder but
    never below the platform's DVFS floor."""
    if base is None:
        base = case_study_trace(n, seed=spec.trace_seed)
    battery_scale = 1.0 if spec.wall_powered else min(
        1.0, spec.battery_wh / 20.0 + 0.35)
    return shaped_trace(
        base,
        battery_scale=battery_scale,
        mem_scale=spec.mem_headroom / 0.85,
        derate_floor=spec.dvfs_floor,
        chips=spec.chips)
