"""FleetPlacer: offload partitions onto live fleet peers.

The scalable-offloading search (``repro.offload.placer``) is kept as-is
— an exact DP over a device chain — but the chain is no longer a
hard-coded pool.  The placer maintains a :class:`MemberState` per fleet
member (capability spec × crowd calibration × current context × tenancy
load), selects candidate helper chains (idle same-site members first),
synthesizes live :class:`DeviceProfile` chains with per-hop link
bandwidths from the :class:`SiteTopology`, and runs the DP over each
candidate chain.  A placement only changes when it clears two bars:

* **hysteresis** — the new chain must beat the *re-predicted* latency of
  the current one by a relative margin, so two near-equal placements
  never ping-pong;
* **migration** — parameter bytes that must move to newly assigned
  hosts are priced over the actual link, and the per-inference gain
  must amortize that cost within ``amortize_steps`` inferences.

Accepted placements update the multi-tenant ledger: each helper's
``hosted`` map records the compute fraction it now spends on this
requester, which discounts the profile every *other* requester sees.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.monitor import ResourceContext
from repro.core.profiler import Calibration
from repro.fleet.registry import DeviceSpec
from repro.models.configs import ModelConfig
from repro.obs import NULL_RECORDER
from repro.offload.graph_ir import build_model_graph
from repro.offload.partition import PrePartition, pre_partition
from repro.offload.placer import (NO_NEXT_LINK, DeviceProfile, Placement,
                                  local_only, place_dp)

from .profiles import MemberState, synthesize_profile
from .topology import SiteTopology

# decision reasons
LOCAL, PLACED, HOLD, FALLBACK, INFEASIBLE = (
    "local", "placed", "hold", "fallback", "infeasible")


@dataclass(frozen=True)
class PlacementDecision:
    """One requester's current placement.

    ``hosts`` is the device chain in execution order — ``hosts[0]`` is
    always the requester itself; a 1-chain means run everything locally.
    ``placement`` carries the DP's cut/assignment detail (``None`` when
    local or infeasible).  ``latency_s`` is the end-to-end predicted
    latency under the live profiles at decision time; ``migration_s``
    the one-off cost of moving parameters onto newly assigned hosts."""
    requester: str
    hosts: Tuple[str, ...]
    placement: Optional[Placement]
    latency_s: float
    migration_s: float
    reason: str
    timestamp_s: float = 0.0

    @property
    def offloaded(self) -> bool:
        return len(self.hosts) > 1 and self.placement is not None

    def describe(self) -> str:
        chain = " -> ".join(self.hosts)
        return (f"{self.requester}: [{chain}] lat={self.latency_s:.4g}s "
                f"migrate={self.migration_s:.3g}s ({self.reason})")


@dataclass(frozen=True)
class PlacementAudit:
    """Why one :meth:`FleetPlacer.place` call decided what it decided —
    the decision log the benchmarks serialize and the trace's
    ``placement.decide`` instants carry.

    ``considered`` lists every candidate chain enumerated (in search
    order) with its DP-predicted latency in ``latencies`` (``inf`` for
    chains the DP rejected as infeasible, counted in ``infeasible``).
    ``held_by_hysteresis`` marks sweeps where a challenger beat the
    incumbent but not by the hysteresis margin (or couldn't amortize its
    migration), so the incumbent was kept; ``incumbent_latency_s`` is
    then the incumbent's *re-predicted* live latency the challenger was
    judged against."""
    requester: str
    timestamp_s: float
    considered: Tuple[Tuple[str, ...], ...]
    latencies: Tuple[float, ...]
    infeasible: int
    chosen: Tuple[str, ...]
    chosen_latency_s: float
    reason: str
    held_by_hysteresis: bool = False
    incumbent_latency_s: Optional[float] = None
    migration_s: float = 0.0


class FleetPlacer:
    """Turns the live fleet into the offloading device pool.

    ``considered`` caps how many candidate helpers feed the chain
    search; ``max_helpers`` caps the chain length (requester + helpers).
    ``hysteresis`` and ``amortize_steps`` gate re-placement (see module
    docstring)."""

    def __init__(self, cfg: ModelConfig,
                 topology: Optional[SiteTopology] = None, *,
                 level: int = 2, seq: int = 512,
                 max_helpers: int = 2, considered: int = 4,
                 hysteresis: float = 0.15, amortize_steps: int = 20):
        self.topology = topology or SiteTopology()
        self.level = level
        self.max_helpers = max_helpers
        self.considered = considered
        self.hysteresis = hysteresis
        self.amortize_steps = amortize_steps
        graph = build_model_graph(cfg, 1, min(cfg.max_seq_len, seq))
        self.pp: PrePartition = pre_partition(graph)
        units = self.pp.units(level)
        # nominal per-hop tensor size for folding link RTT into a flat
        # bandwidth: the mean boundary the DP might cut at
        cut_bytes = [u.boundary_bytes for u in units[:-1]] or [1]
        self._nominal_boundary = max(
            1.0, sum(cut_bytes) / len(cut_bytes))
        self._members: Dict[str, MemberState] = {}
        self._current: Dict[str, PlacementDecision] = {}
        # decision log: one PlacementAudit per place() call; the fleet
        # controller points ``recorder`` at its TraceRecorder so each
        # audit also lands as a placement.decide trace instant
        self.audits: List[PlacementAudit] = []
        self.recorder = NULL_RECORDER
        self.obs_pid = "fleet"
        # clock of the most recent place() call — candidate_helpers
        # judges quarantine windows against it
        self._place_now_s = 0.0

    # ------------------------------------------------------- membership ----
    def register(self, spec: DeviceSpec) -> MemberState:
        st = MemberState(spec=spec)
        self._members[spec.device_id] = st
        return st

    def member(self, device_id: str) -> MemberState:
        return self._members[device_id]

    @property
    def members(self) -> Dict[str, MemberState]:
        return self._members

    def update_member(self, device_id: str, *,
                      ctx: Optional[ResourceContext] = None,
                      calibration: Optional[Calibration] = None,
                      own_load: Optional[float] = None) -> None:
        st = self._members[device_id]
        if ctx is not None:
            st.ctx = ctx
        if calibration is not None:
            st.calibration = calibration
        if own_load is not None:
            st.own_load = max(0.0, min(0.95, own_load))

    def remove_member(self, device_id: str) -> List[str]:
        """A member left the fleet (battery died, walked out of range).
        Returns the requesters whose current placement used it — they
        must fall back / re-place."""
        st = self._members.pop(device_id, None)
        affected = [rid for rid, dec in self._current.items()
                    if device_id in dec.hosts and rid != device_id]
        self._current.pop(device_id, None)
        if st is not None:
            st.alive = False
        # anything the departed device was *requesting* stops consuming
        # its helpers — a dead tenant must not keep inflating their load
        for other in self._members.values():
            other.hosted.pop(device_id, None)
        for rid in affected:
            self._current[rid] = self._fallback(rid, FALLBACK)
        return affected

    # -------------------------------------------------------- chain build --
    def chain_profiles(self, ids: Sequence[str],
                       for_requester: Optional[str] = None
                       ) -> List[DeviceProfile]:
        """Live profiles for a device chain, with per-hop link bandwidth
        from the topology (RTT folded in at the nominal boundary size);
        the terminal device gets :data:`NO_NEXT_LINK`."""
        req = for_requester or (ids[0] if ids else None)
        profs = []
        for i, did in enumerate(ids):
            st = self._members[did]
            if i + 1 < len(ids):
                nxt = self._members[ids[i + 1]]
                link = self.topology.link_between(st.spec, nxt.spec)
                bw = link.effective_bw(self._nominal_boundary)
            else:
                bw = NO_NEXT_LINK
            profs.append(synthesize_profile(st, for_requester=req,
                                            link_bw=bw))
        return profs

    def candidate_helpers(self, requester: str,
                          now_s: Optional[float] = None) -> List[str]:
        """Helpers worth considering, best first: same-site before
        cross-site, then the least busy, then the most capable.
        Quarantined members (flapping devices on post-recovery
        probation, see ``MemberState.quarantined_until_s``) are
        excluded: the placer never ping-pongs onto a helper that just
        proved unreliable.  ``now_s`` defaults to the clock of the
        enclosing :meth:`place` call."""
        me = self._members[requester]
        if now_s is None:
            now_s = self._place_now_s

        def rank(item):
            did, st = item
            same = self.topology.same_site(me.spec, st.spec)
            cap = st.spec.hw.peak_flops * st.spec.chips
            return (0 if same else 1, st.busy_frac(excluding=requester),
                    -cap)

        cands = [(did, st) for did, st in self._members.items()
                 if did != requester and st.alive
                 and st.quarantined_until_s <= now_s]
        cands.sort(key=rank)
        return [did for did, _ in cands[:self.considered]]

    # ---------------------------------------------------------- latency ----
    def _chain_latency(self, ids: Sequence[str],
                       profs: Sequence[DeviceProfile],
                       placement: Placement) -> float:
        """Re-predict a FIXED placement's latency under current live
        profiles (used to hold the incumbent to the same standard as
        challengers).  Infinite if any host is gone."""
        if any(did not in self._members for did in ids):
            return float("inf")
        units = self.pp.units(placement.level)
        lat = 0.0
        for i, u in enumerate(units):
            d = placement.assignment[i]
            lat += profs[d].compute_seconds(u)
        for c in placement.cuts:
            d = placement.assignment[c]
            lat += units[c].boundary_bytes / max(profs[d].link_bw, 1.0)
        return lat

    def _migration_s(self, requester: str, hosts: Sequence[str],
                     placement: Placement) -> float:
        """Cost of moving parameters onto newly assigned hosts: bytes of
        every unit that lands on a helper which did not already hold it,
        shipped from the requester over the actual link."""
        prev = self._current.get(requester)
        prev_owner: Dict[str, str] = {}
        if prev is not None and prev.placement is not None:
            punits = self.pp.units(prev.placement.level)
            for i, u in enumerate(punits):
                prev_owner[u.name] = prev.hosts[prev.placement.assignment[i]]
        units = self.pp.units(placement.level)
        me = self._members[requester].spec
        cost = 0.0
        for i, u in enumerate(units):
            host = hosts[placement.assignment[i]]
            if host == requester or prev_owner.get(u.name) == host:
                continue
            if host not in self._members:
                return float("inf")
            link = self.topology.link_between(
                me, self._members[host].spec)
            cost += link.transfer_s(u.param_bytes)
        return cost

    def _fallback(self, requester: str, reason: str) -> PlacementDecision:
        """Local-only decision (or infeasible marker when even the
        requester alone cannot hold the model)."""
        profs = self.chain_profiles([requester])
        pl = local_only(self.pp, profs, level=self.level)
        if pl.per_device_mem[0] > profs[0].mem_bytes:
            return PlacementDecision(requester, (requester,), None,
                                     float("inf"), 0.0, INFEASIBLE)
        return PlacementDecision(requester, (requester,), None,
                                 pl.latency_s, 0.0, reason)

    # -------------------------------------------------------------- place --
    def place(self, requester: str, now_s: float = 0.0
              ) -> PlacementDecision:
        """(Re-)place one requester's partitions over the live fleet.

        Enumerates candidate chains — the requester alone, plus each
        single helper and each ordered helper pair from the ranked
        candidate set — runs the exact DP on every feasible chain, and
        applies hysteresis + migration amortization against the
        incumbent before committing.  Never raises on infeasibility:
        the worst case is an explicit local/infeasible fallback."""
        self._place_now_s = now_s
        local = self._fallback(requester, LOCAL)
        helpers = self.candidate_helpers(requester, now_s=now_s)
        chains: List[Tuple[str, ...]] = [(requester,)]
        chains += [(requester, h) for h in helpers]
        if self.max_helpers >= 2:
            for h1, h2 in itertools.permutations(helpers, 2):
                chains.append((requester, h1, h2))

        considered: List[Tuple[str, ...]] = []
        latencies: List[float] = []
        infeasible = 0
        best: Optional[PlacementDecision] = None
        for ids in chains:
            profs = self.chain_profiles(ids)
            considered.append(tuple(ids))
            if len(ids) == 1:
                cand = local
            else:
                try:
                    pl = place_dp(self.pp, profs, level=self.level)
                except ValueError:
                    infeasible += 1
                    latencies.append(float("inf"))
                    continue
                used = sorted(set(pl.assignment))
                if used == [0]:
                    cand = local          # DP kept everything at home
                else:
                    mig = self._migration_s(requester, ids, pl)
                    cand = PlacementDecision(
                        requester, tuple(ids), pl, pl.latency_s, mig,
                        PLACED, now_s)
            latencies.append(cand.latency_s)
            if best is None or cand.latency_s < best.latency_s:
                best = cand
        if best is None:
            best = local
        best = PlacementDecision(
            best.requester, best.hosts, best.placement, best.latency_s,
            best.migration_s, best.reason, now_s)

        cur = self._current.get(requester)
        if cur is None or cur.reason == INFEASIBLE:
            # fresh placement: no churn to damp, but migration must
            # still pay for itself against simply staying local
            if best.offloaded and \
                    (local.latency_s - best.latency_s) \
                    * self.amortize_steps < best.migration_s:
                best = PlacementDecision(
                    requester, local.hosts, local.placement,
                    local.latency_s, 0.0, local.reason, now_s)
        elif best.hosts != cur.hosts:
            cur_live = self._relive(cur)
            gain = cur_live.latency_s - best.latency_s
            if gain < self.hysteresis * cur_live.latency_s or \
                    gain * self.amortize_steps < best.migration_s:
                held = PlacementDecision(
                    requester, cur_live.hosts, cur_live.placement,
                    cur_live.latency_s, 0.0, HOLD, now_s)
                self._commit(held)
                self._audit(held, considered, latencies, infeasible,
                            held_by_hysteresis=True,
                            incumbent_latency_s=cur_live.latency_s)
                return held
        self._commit(best)
        self._audit(best, considered, latencies, infeasible)
        return best

    def _audit(self, dec: PlacementDecision,
               considered: List[Tuple[str, ...]], latencies: List[float],
               infeasible: int, *, held_by_hysteresis: bool = False,
               incumbent_latency_s: Optional[float] = None) -> None:
        """Log why this decision won (see :class:`PlacementAudit`)."""
        audit = PlacementAudit(
            requester=dec.requester, timestamp_s=dec.timestamp_s,
            considered=tuple(considered), latencies=tuple(latencies),
            infeasible=infeasible, chosen=dec.hosts,
            chosen_latency_s=dec.latency_s, reason=dec.reason,
            held_by_hysteresis=held_by_hysteresis,
            incumbent_latency_s=incumbent_latency_s,
            migration_s=dec.migration_s)
        self.audits.append(audit)
        if self.recorder.enabled:
            self.recorder.instant(
                "placement.decide", pid=self.obs_pid, tid="placement",
                cat="placement",
                args={"requester": dec.requester,
                      "chosen": " -> ".join(dec.hosts),
                      "latency_s": dec.latency_s,
                      "reason": dec.reason,
                      "considered": len(considered),
                      "infeasible": infeasible,
                      "held_by_hysteresis": held_by_hysteresis})

    def _relive(self, dec: PlacementDecision) -> PlacementDecision:
        """The incumbent decision with its latency re-predicted under
        the CURRENT live profiles (a helper that slowed down since the
        placement was made shows up here, triggering re-placement)."""
        if dec.placement is None or not dec.offloaded:
            fresh = self._fallback(dec.requester, dec.reason)
            return fresh
        if any(did not in self._members for did in dec.hosts):
            return PlacementDecision(dec.requester, dec.hosts,
                                     dec.placement, float("inf"), 0.0,
                                     dec.reason, dec.timestamp_s)
        profs = self.chain_profiles(dec.hosts)
        lat = self._chain_latency(dec.hosts, profs, dec.placement)
        return PlacementDecision(dec.requester, dec.hosts, dec.placement,
                                 lat, 0.0, dec.reason, dec.timestamp_s)

    def _commit(self, dec: PlacementDecision) -> None:
        """Record the decision and refresh the tenancy ledger: each
        helper's hosted fraction is its share of the pipeline's compute
        time, which discounts its profile for every other requester."""
        rid = dec.requester
        for st in self._members.values():
            st.hosted.pop(rid, None)
        if dec.offloaded and dec.placement is not None \
                and dec.latency_s < float("inf"):
            profs = self.chain_profiles(dec.hosts)
            units = self.pp.units(dec.placement.level)
            per_host: Dict[str, float] = {}
            for i, u in enumerate(units):
                host = dec.hosts[dec.placement.assignment[i]]
                per_host[host] = per_host.get(host, 0.0) \
                    + profs[dec.placement.assignment[i]].compute_seconds(u)
            for host, t in per_host.items():
                if host == rid or host not in self._members:
                    continue
                frac = min(0.9, t / max(dec.latency_s, 1e-12))
                self._members[host].hosted[rid] = frac
        self._current[rid] = dec

    # ------------------------------------------------------------ queries --
    def local_decision(self, requester: str) -> PlacementDecision:
        """Predicted local-only execution for a requester under its live
        profile — the baseline every placement is judged against."""
        return self._fallback(requester, LOCAL)

    def current(self, requester: str) -> Optional[PlacementDecision]:
        return self._current.get(requester)

    @property
    def decisions(self) -> Dict[str, PlacementDecision]:
        return dict(self._current)

    def resolve_profiles(self, peers: Sequence[str]
                         ) -> List[DeviceProfile]:
        """Profiles for an :class:`OffloadChoice.peers` chain as the
        evaluator sees it.  Dead members are dropped from the chain
        (the requester — ``peers[0]`` — is always kept), so an action
        referencing a vanished helper degrades to a shorter chain
        instead of crashing the optimizer."""
        alive = [p for i, p in enumerate(peers)
                 if i == 0 or (p in self._members
                               and self._members[p].alive)]
        if not alive or alive[0] not in self._members:
            return []
        return self.chain_profiles(alive)
