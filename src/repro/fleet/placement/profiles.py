"""Live DeviceProfiles: the fleet's calibrated state as a device pool.

The static pools in ``repro.offload.placer`` describe hypothetical
hardware; a running fleet knows better.  :func:`synthesize_profile`
turns one member's :class:`~repro.fleet.registry.DeviceSpec` capability
envelope into an offloading :class:`~repro.offload.placer.DeviceProfile`
corrected by everything the fleet has *measured*:

* the ``(tier, channel)`` telemetry calibration — a tier whose silicon
  runs 1.4× slower than the analytic model predicts yields a profile
  with 1.4× fewer achievable FLOP/s, so the placement DP sees the same
  reality the calibrated evaluator does;
* the member's current context — DVFS derate, competing processes, free
  memory fraction;
* load the member is already carrying: its own serving work
  (``own_load``, e.g. from an attached engine's step-time EWMA) and the
  partitions it hosts *for other members* (multi-tenant accounting — a
  jetson helping two phones looks slower to the third).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.monitor import ResourceContext
from repro.core.profiler import Calibration
from repro.fleet.registry import DeviceSpec
from repro.offload.placer import NO_NEXT_LINK, DeviceProfile

# a host never surrenders its whole budget to tenants/backlog: the
# synthesized profile keeps at least this fraction of derated capability
MIN_CAPACITY_FRAC = 0.1


@dataclass
class MemberState:
    """What the placer knows about one fleet member right now.

    ``ctx`` is the member's last observed resource context; ``own_load``
    is the fraction of its compute already consumed by local work (an
    engine-backed device reports its serving duty cycle here);
    ``hosted`` maps requester device-id → compute fraction this member
    spends hosting that requester's offloaded partitions."""
    spec: DeviceSpec
    ctx: ResourceContext = field(default_factory=ResourceContext)
    calibration: Calibration = field(default_factory=Calibration)
    own_load: float = 0.0
    hosted: Dict[str, float] = field(default_factory=dict)
    alive: bool = True
    # flap hysteresis: until this fleet-clock instant the member is not
    # offered as a helper for NEW placements (existing chains through it
    # keep working — it is alive, just on probation after blinking)
    quarantined_until_s: float = 0.0

    def tenant_load(self, excluding: Optional[str] = None) -> float:
        """Compute fraction consumed hosting *other* requesters — the
        multi-tenant term a prospective requester must discount."""
        return sum(f for rid, f in self.hosted.items() if rid != excluding)

    def busy_frac(self, excluding: Optional[str] = None) -> float:
        """Total utilization a new requester would contend with."""
        return min(0.95, self.own_load + self.tenant_load(excluding))


def synthesize_profile(state: MemberState, *,
                       for_requester: Optional[str] = None,
                       link_bw: float = NO_NEXT_LINK) -> DeviceProfile:
    """One member's live offloading profile.

    Capability = spec peaks × chips, derated by (a) the context's DVFS /
    competing-process factor, (b) the crowd-calibrated latency scale
    (observed ≈ scale × predicted ⇒ the device achieves 1/scale of its
    analytic FLOP/s), and (c) the busy fraction from its own serving
    work plus partitions hosted for members other than
    ``for_requester``.  Memory = HBM × headroom × the context's free
    fraction.  ``link_bw`` is the bandwidth toward the NEXT device in
    whatever chain the caller is assembling (the topology decides it)."""
    spec, ctx = state.spec, state.ctx
    peak = spec.hw.peak_flops * spec.chips
    flops = ctx.effective_flops(peak)
    scale = state.calibration.latency_scale \
        if state.calibration.samples else 1.0
    flops /= max(scale, 1e-3)
    free = max(1.0 - state.busy_frac(excluding=for_requester),
               MIN_CAPACITY_FRAC)
    flops *= free
    mem_bw = spec.hw.hbm_bw * spec.chips * free / max(scale, 1e-3)
    mem = spec.hw.hbm_bytes * spec.chips * spec.mem_headroom \
        * ctx.mem_free_frac
    return DeviceProfile(
        name=spec.device_id,
        flops=max(flops, 1.0),
        mem_bytes=max(mem, 0.0),
        mem_bw=max(mem_bw, 1.0),
        link_bw=link_bw,
        power_w=spec.hw.peak_w,
        kind="fleet")
