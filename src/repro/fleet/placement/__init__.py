"""Fleet-aware cross-device offload placement.

Turns the live fleet — calibrated latency, DVFS state, serving load,
multi-tenant hosting — into the device pool the scalable-offloading
search places partitions onto, replacing the static ``DEVICE_POOLS``
for fleet members.  See :class:`FleetPlacer` for the search + hysteresis
+ migration model, :class:`SiteTopology` for first-class links, and
:func:`synthesize_profile` for how a member's measured state becomes an
offloading :class:`DeviceProfile`.
"""
from .placer import (FALLBACK, HOLD, INFEASIBLE, LOCAL, PLACED,
                     FleetPlacer, PlacementAudit, PlacementDecision)
from .profiles import MIN_CAPACITY_FRAC, MemberState, synthesize_profile
from .topology import (DEFAULT_LAN, DEFAULT_WAN, LAN, SELF_LINK, WAN,
                       LinkSpec, SiteTopology)

__all__ = ["FALLBACK", "HOLD", "INFEASIBLE", "LOCAL", "PLACED",
           "FleetPlacer", "PlacementAudit", "PlacementDecision",
           "MIN_CAPACITY_FRAC", "MemberState", "synthesize_profile",
           "DEFAULT_LAN", "DEFAULT_WAN", "LAN", "SELF_LINK", "WAN",
           "LinkSpec", "SiteTopology"]
