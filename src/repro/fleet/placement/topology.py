"""Fleet link topology: who can reach whom, and how fast.

The static ``DEVICE_POOLS`` chains carried a single ``link_bw`` scalar
per hop; a live fleet needs links between *members* to be first-class.
Every :class:`~repro.fleet.registry.DeviceSpec` carries a ``site``;
devices sharing a site talk over the site's LAN, cross-site hops pay the
WAN's lower bandwidth and higher RTT.  :class:`SiteTopology` maps any
ordered pair of sites to a :class:`LinkSpec` (with optional per-pair
overrides — e.g. two campuses joined by a fat fiber link), which the
fleet placer turns into per-hop ``DeviceProfile.link_bw`` values for the
offloading DP and into the migration-cost model (parameter bytes moved
over the actual link when a placement changes hosts).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.fleet.registry import DeviceSpec

LAN, WAN, LOOPBACK = "lan", "wan", "loopback"


@dataclass(frozen=True)
class LinkSpec:
    """One directed network link: sustained bandwidth plus round-trip
    latency.  ``transfer_s`` is the wire time of one tensor (RTT + bytes
    over bandwidth); ``effective_bw`` folds the RTT into an equivalent
    flat bandwidth for a *nominal* transfer size, which is what the
    bandwidth-only placement DP consumes."""
    bandwidth_bytes_s: float
    rtt_s: float = 0.0
    kind: str = LAN

    def transfer_s(self, nbytes: float) -> float:
        return self.rtt_s + nbytes / max(self.bandwidth_bytes_s, 1.0)

    def effective_bw(self, nominal_bytes: float) -> float:
        """Flat bytes/s equivalent for transfers of ``nominal_bytes``:
        small tensors over a high-RTT WAN see far less than the wire
        rate.  This is the value handed to ``DeviceProfile.link_bw``."""
        t = self.transfer_s(nominal_bytes)
        return nominal_bytes / max(t, 1e-12)


# order-of-magnitude defaults: a home/office LAN (Wi-Fi 6 / GbE class)
# and a metered uplink between sites
DEFAULT_LAN = LinkSpec(bandwidth_bytes_s=125e6, rtt_s=2e-4, kind=LAN)
DEFAULT_WAN = LinkSpec(bandwidth_bytes_s=12.5e6, rtt_s=2e-2, kind=WAN)
# a device talking to itself (placement chain of length 1)
SELF_LINK = LinkSpec(bandwidth_bytes_s=float("inf"), rtt_s=0.0,
                     kind=LOOPBACK)


@dataclass
class SiteTopology:
    """Site-pair → link map for one fleet.

    Same-site pairs resolve to ``lan``, cross-site pairs to ``wan``,
    unless an explicit override exists for the (unordered) site pair.
    The topology is deliberately ignorant of individual devices — a
    device's location is its :attr:`DeviceSpec.site`, so membership
    churn never touches the topology."""
    lan: LinkSpec = DEFAULT_LAN
    wan: LinkSpec = DEFAULT_WAN
    overrides: Dict[Tuple[str, str], LinkSpec] = field(default_factory=dict)

    def link(self, site_a: str, site_b: str) -> LinkSpec:
        """The link between two sites (loopback if they are one device's
        own site paired with itself is *not* special-cased — same site
        means LAN; use :data:`SELF_LINK` for a degenerate 1-chain)."""
        key = (site_a, site_b) if site_a <= site_b else (site_b, site_a)
        if key in self.overrides:
            return self.overrides[key]
        return self.lan if site_a == site_b else self.wan

    def link_between(self, a: DeviceSpec, b: DeviceSpec) -> LinkSpec:
        return self.link(a.site, b.site)

    def same_site(self, a: DeviceSpec, b: DeviceSpec) -> bool:
        return a.site == b.site
