"""Fleet-wide rollups: per-tier SLA/violation/energy + calibration gain.

Turns a :class:`FleetController` run into the numbers the paper reports
per platform class — latency distributions, SLA violation rates, energy
totals — plus the before/after prediction error (MAPE) that quantifies
what the crowd-telemetry feedback loop bought.  Under event-driven
stepping the report also surfaces the *asynchrony* itself: per-device
tick counts (fast devices accumulate strictly more wakes over one
horizon) and the fleet's wall-clock skew (how far apart devices' last
wakes landed on the simulated clock).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from .controller import FleetController


@dataclass
class TierSummary:
    """One hardware tier's rollup over a fleet run: device/tick counts
    (including the min/max per-device tick spread that event stepping
    introduces), latency distribution, SLA violations, energy, and the
    raw-vs-calibrated prediction error."""
    tier: str
    devices: int
    ticks: int
    mean_latency_s: float
    p95_latency_s: float
    violations: int
    violation_rate: float
    energy_j: float
    # raw analytic predictions vs observed, across ALL of the tier's
    # measurement channels — for a tier mixing engine-backed (wall-time)
    # and simulated devices this is dominated by the engine records'
    # genuinely huge raw error, which is exactly the gap the per-channel
    # calibration (mape_after) closes
    mape_before: float
    mape_after: float             # calibrated predictions vs observed
    min_device_ticks: int = 0     # slowest member's wake count
    max_device_ticks: int = 0     # fastest member's wake count


@dataclass
class FleetReport:
    """A rendered-ready summary of one fleet run: per-tier
    :class:`TierSummary` rows, fleet totals, the first-half/second-half
    violation split (halved on the fleet clock, so it is meaningful for
    both lockstep and event stepping), per-device tick counts, and
    ``clock_skew_s`` — the spread between the earliest and latest final
    wake across devices (0 under lockstep; under event stepping, how far
    the fleet's members drifted apart over the horizon)."""
    tiers: List[TierSummary]
    total_ticks: int
    total_violations: int
    total_energy_j: float
    violations_first_half: int
    violations_second_half: int
    device_ticks: Dict[str, int] = field(default_factory=dict)
    clock_skew_s: float = 0.0
    # cross-device placement (empty when the fleet runs without the
    # placer): requester -> human-readable current placement, plus how
    # many re-placement sweeps the controller ran
    placements: Dict[str, str] = field(default_factory=dict)
    placement_events: int = 0

    def render(self) -> str:
        hdr = (f"{'tier':8s} {'dev':>4s} {'ticks':>6s} {'t/dev':>9s} "
               f"{'mean_lat':>10s} {'p95_lat':>10s} {'viol':>5s} "
               f"{'rate':>6s} {'energy_J':>10s} {'MAPE_raw':>9s} "
               f"{'MAPE_cal':>9s}")
        lines = [hdr, "-" * len(hdr)]
        for t in self.tiers:
            lines.append(
                f"{t.tier:8s} {t.devices:4d} {t.ticks:6d} "
                f"{t.min_device_ticks:4d}-{t.max_device_ticks:<4d} "
                f"{t.mean_latency_s:10.4g} {t.p95_latency_s:10.4g} "
                f"{t.violations:5d} {t.violation_rate:6.1%} "
                f"{t.energy_j:10.4g} {t.mape_before:9.1%} "
                f"{t.mape_after:9.1%}")
        lines.append(
            f"total: ticks={self.total_ticks} "
            f"violations={self.total_violations} "
            f"(1st half {self.violations_first_half} → "
            f"2nd half {self.violations_second_half}) "
            f"energy={self.total_energy_j:.4g} J "
            f"clock_skew={self.clock_skew_s:.3g}s")
        if self.placements:
            lines.append(f"placements ({self.placement_events} sweeps):")
            for rid in sorted(self.placements):
                lines.append(f"  {self.placements[rid]}")
        return "\n".join(lines)


def _mape_after(ctl: FleetController, tier: str) -> float:
    """Calibrated error uses the correction each device's loop would
    actually consult — tier-pooled under crowd sharing, per-device
    otherwise — always on the record's own measurement channel."""
    if ctl.share_calibration:
        return ctl.telemetry.mape(tier=tier, per_tier_calibration=True)
    return ctl.telemetry.mape(tier=tier, per_device_calibration=True)


def fleet_report(ctl: FleetController) -> FleetReport:
    """Roll a controller's records up into a :class:`FleetReport` (see
    the class docstrings for field semantics)."""
    recs = ctl.records
    tiers = sorted({r.tier for r in recs})
    device_ticks = ctl.tick_counts
    tier_of = {spec.device_id: spec.tier for spec in ctl.devices}
    summaries = []
    for tier in tiers:
        rs = [r for r in recs if r.tier == tier]
        lats = np.array([r.observed_s for r in rs])
        viol = sum(1 for r in rs if r.violated)
        tier_ticks = [n for did, n in device_ticks.items()
                      if tier_of.get(did) == tier]
        summaries.append(TierSummary(
            tier=tier,
            devices=len({r.device_id for r in rs}),
            ticks=len(rs),
            mean_latency_s=float(lats.mean()) if len(lats) else 0.0,
            p95_latency_s=float(np.percentile(lats, 95)) if len(lats)
            else 0.0,
            violations=viol,
            violation_rate=viol / max(len(rs), 1),
            energy_j=float(sum(r.observed_energy_j for r in rs)),
            mape_before=ctl.telemetry.mape(tier=tier),
            mape_after=_mape_after(ctl, tier),
            min_device_ticks=min(tier_ticks, default=0),
            max_device_ticks=max(tier_ticks, default=0)))
    # halve the run on the fleet clock: under lockstep timestamps equal
    # global ticks, so this reproduces the old tick-based split exactly
    max_ts = max((r.timestamp_s for r in recs), default=0.0)
    mid_ts = max_ts / 2.0
    last_wake = {}
    for r in recs:
        last_wake[r.device_id] = max(last_wake.get(r.device_id, 0.0),
                                     r.timestamp_s)
    skew = (max(last_wake.values()) - min(last_wake.values())
            if last_wake else 0.0)
    placements = {}
    if ctl.placer is not None:
        placements = {rid: dec.describe()
                      for rid, dec in ctl.placer.decisions.items()}
    # fleet totals are views over the controller's metrics registry
    # (incremented exactly where records are appended, so they always
    # agree with a records-derived sum — test_obs.py pins this)
    return FleetReport(
        tiers=summaries,
        total_ticks=len(recs),
        total_violations=ctl.metrics.counter("fleet.violations").value,
        total_energy_j=float(
            ctl.metrics.counter("fleet.energy_j").value),
        violations_first_half=ctl.violations(last_s=mid_ts),
        violations_second_half=ctl.violations()
        - ctl.violations(last_s=mid_ts),
        device_ticks=device_ticks,
        clock_skew_s=skew,
        placements=placements,
        placement_events=ctl.placement_events)
