"""Fleet-wide rollups: per-tier SLA/violation/energy + calibration gain.

Turns a :class:`FleetController` run into the numbers the paper reports
per platform class — latency distributions, SLA violation rates, energy
totals — plus the before/after prediction error (MAPE) that quantifies
what the crowd-telemetry feedback loop bought.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from .controller import FleetController


@dataclass
class TierSummary:
    tier: str
    devices: int
    ticks: int
    mean_latency_s: float
    p95_latency_s: float
    violations: int
    violation_rate: float
    energy_j: float
    # raw analytic predictions vs observed, across ALL of the tier's
    # measurement channels — for a tier mixing engine-backed (wall-time)
    # and simulated devices this is dominated by the engine records'
    # genuinely huge raw error, which is exactly the gap the per-channel
    # calibration (mape_after) closes
    mape_before: float
    mape_after: float             # calibrated predictions vs observed


@dataclass
class FleetReport:
    tiers: List[TierSummary]
    total_ticks: int
    total_violations: int
    total_energy_j: float
    violations_first_half: int
    violations_second_half: int

    def render(self) -> str:
        hdr = (f"{'tier':8s} {'dev':>4s} {'ticks':>6s} {'mean_lat':>10s} "
               f"{'p95_lat':>10s} {'viol':>5s} {'rate':>6s} "
               f"{'energy_J':>10s} {'MAPE_raw':>9s} {'MAPE_cal':>9s}")
        lines = [hdr, "-" * len(hdr)]
        for t in self.tiers:
            lines.append(
                f"{t.tier:8s} {t.devices:4d} {t.ticks:6d} "
                f"{t.mean_latency_s:10.4g} {t.p95_latency_s:10.4g} "
                f"{t.violations:5d} {t.violation_rate:6.1%} "
                f"{t.energy_j:10.4g} {t.mape_before:9.1%} "
                f"{t.mape_after:9.1%}")
        lines.append(
            f"total: ticks={self.total_ticks} "
            f"violations={self.total_violations} "
            f"(1st half {self.violations_first_half} → "
            f"2nd half {self.violations_second_half}) "
            f"energy={self.total_energy_j:.4g} J")
        return "\n".join(lines)


def _mape_after(ctl: FleetController, tier: str) -> float:
    """Calibrated error uses the correction each device's loop would
    actually consult — tier-pooled under crowd sharing, per-device
    otherwise — always on the record's own measurement channel."""
    if ctl.share_calibration:
        return ctl.telemetry.mape(tier=tier, per_tier_calibration=True)
    return ctl.telemetry.mape(tier=tier, per_device_calibration=True)


def fleet_report(ctl: FleetController) -> FleetReport:
    recs = ctl.records
    tiers = sorted({r.tier for r in recs})
    summaries = []
    for tier in tiers:
        rs = [r for r in recs if r.tier == tier]
        lats = np.array([r.observed_s for r in rs])
        viol = sum(1 for r in rs if r.violated)
        summaries.append(TierSummary(
            tier=tier,
            devices=len({r.device_id for r in rs}),
            ticks=len(rs),
            mean_latency_s=float(lats.mean()) if len(lats) else 0.0,
            p95_latency_s=float(np.percentile(lats, 95)) if len(lats)
            else 0.0,
            violations=viol,
            violation_rate=viol / max(len(rs), 1),
            energy_j=float(sum(r.observed_energy_j for r in rs)),
            mape_before=ctl.telemetry.mape(tier=tier),
            mape_after=_mape_after(ctl, tier)))
    max_tick = max((r.tick for r in recs), default=0)
    mid = max_tick // 2
    return FleetReport(
        tiers=summaries,
        total_ticks=len(recs),
        total_violations=sum(1 for r in recs if r.violated),
        total_energy_j=float(sum(r.observed_energy_j for r in recs)),
        violations_first_half=ctl.violations(last_tick=mid),
        violations_second_half=ctl.violations(first_tick=mid + 1))
