"""Heterogeneous device-fleet simulation with crowd-shared telemetry
calibration (the "Crowd" level of CrowdHMTware): a registry of ~15
platform profiles in three hardware tiers, per-device context traces,
one co-adaptation loop per device, and a telemetry store that feeds
observed step timings back into the profiler's estimates — pooled per
``(tier, channel)`` so devices learn from each other's measurements
without mixing engine wall-times and simulated-silicon scales.

Stepping is event-driven by default: :class:`FleetController` keeps a
min-heap of per-device next-wake times derived from each
:class:`DeviceSpec`'s :class:`TickEnvelope`, so fast devices tick at
their own rate, slow devices never gate them, and telemetry reports
arrive at the :class:`TelemetryStore` out of order (which its
timestamp-sorted calibrators absorb).  ``step_mode="lockstep"`` restores
the legacy one-global-tick-advances-everyone behavior.
"""
from .controller import (DEFAULT_SHAPE, STEP_MODES, FleetController,
                         FleetTickRecord)
from .placement import (FleetPlacer, LinkSpec, MemberState,
                        PlacementDecision, SiteTopology,
                        synthesize_profile)
from .registry import (DEFAULT_SITE, DeviceSpec, HEAVY, LIGHT, MEDIUM,
                       PLATFORMS, PlatformProfile, TIER_TICK_S, TIERS,
                       TickEnvelope, build_fleet, device_trace,
                       make_device, platforms_by_tier)
from .report import FleetReport, TierSummary, fleet_report
from .telemetry import (ACCURACY, CHANNELS, ENGINE, SIMULATED,
                        AccuracyRecord, EwmaLsqCalibrator,
                        MeasurementRecord, TelemetryStore)

__all__ = ["DEFAULT_SHAPE", "STEP_MODES", "FleetController",
           "FleetTickRecord", "FleetPlacer", "LinkSpec", "MemberState",
           "PlacementDecision", "SiteTopology", "synthesize_profile",
           "DEFAULT_SITE", "DeviceSpec", "HEAVY", "LIGHT", "MEDIUM",
           "PLATFORMS", "PlatformProfile", "TIER_TICK_S", "TIERS",
           "TickEnvelope", "build_fleet", "device_trace", "make_device",
           "platforms_by_tier", "FleetReport", "TierSummary",
           "fleet_report", "ACCURACY", "CHANNELS", "ENGINE", "SIMULATED",
           "AccuracyRecord", "EwmaLsqCalibrator", "MeasurementRecord",
           "TelemetryStore"]
