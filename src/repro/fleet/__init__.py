"""Heterogeneous device-fleet simulation with crowd-shared telemetry
calibration (the "Crowd" level of CrowdHMTware): a registry of ~15
platform profiles in three hardware tiers, per-device context traces,
one co-adaptation loop per device, and a telemetry store that feeds
observed step timings back into the profiler's estimates — pooled per
tier so devices learn from each other's measurements."""
from .controller import (DEFAULT_SHAPE, FleetController, FleetTickRecord)
from .registry import (DeviceSpec, HEAVY, LIGHT, MEDIUM, PLATFORMS,
                       PlatformProfile, TIERS, build_fleet, device_trace,
                       make_device, platforms_by_tier)
from .report import FleetReport, TierSummary, fleet_report
from .telemetry import (CHANNELS, ENGINE, SIMULATED, EwmaLsqCalibrator,
                        MeasurementRecord, TelemetryStore)

__all__ = ["DEFAULT_SHAPE", "FleetController", "FleetTickRecord",
           "DeviceSpec", "HEAVY", "LIGHT", "MEDIUM", "PLATFORMS",
           "PlatformProfile", "TIERS", "build_fleet", "device_trace",
           "make_device", "platforms_by_tier", "FleetReport", "TierSummary",
           "fleet_report", "CHANNELS", "ENGINE", "SIMULATED",
           "EwmaLsqCalibrator", "MeasurementRecord", "TelemetryStore"]
