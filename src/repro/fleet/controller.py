"""FleetController: one co-adaptation loop per device, crowd-calibrated.

Runs the paper's monitor→profiler→optimizer→apply loop for every device
in a heterogeneous fleet over interleaved per-device context traces.
Each tick produces a (predicted, observed) measurement pair; telemetry
fits per-tier corrections and the controller pushes them back into every
same-tier loop's evaluator — back-end measurements steering front-end
decisions, across devices.

Observations come from either (a) the device's latent ground-truth bias
(simulated silicon, default) or (b) a real :class:`ServingEngine`
attached to the device, whose measured step wall-times become the
observed latencies (see ``attach_engine``).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

from repro.core.actions import Action
from repro.core.loop import AdaptationLoop, Decision
from repro.core.monitor import ResourceContext
from repro.core.optimizer import Budgets
from repro.models.configs import InputShape, ModelConfig
from repro.serving import CompileCache

from .registry import DeviceSpec, device_trace
from .telemetry import ENGINE, SIMULATED, MeasurementRecord, TelemetryStore

DEFAULT_SHAPE = InputShape("fleet", 256, 4, "prefill")


@dataclass
class FleetTickRecord:
    """What one device did and what it cost on one fleet tick."""
    device_id: str
    tier: str
    tick: int
    ctx: ResourceContext
    decision: Decision
    predicted_raw_s: float        # uncalibrated analytic estimate
    predicted_s: float            # what the optimizer believed (calibrated)
    observed_s: float             # measured (simulated silicon or engine)
    observed_energy_j: float
    sla_s: float
    violated: bool


@dataclass
class _DeviceRuntime:
    spec: DeviceSpec
    loop: AdaptationLoop
    trace: Iterator[ResourceContext]
    rng: random.Random
    sla_s: float
    engine: object = None         # optional ServingEngine
    engine_steps: int = 4
    exhausted: bool = False


class FleetController:
    """Steps a heterogeneous fleet through shared scenarios, closing the
    telemetry loop per hardware tier."""

    def __init__(self, fleet: Sequence[DeviceSpec], cfg: ModelConfig,
                 shape: InputShape = DEFAULT_SHAPE, *,
                 budget_margin: float = 1.5,
                 share_calibration: bool = True,
                 warmup_ticks: int = 6,
                 recalibrate_every: int = 2,
                 observation_noise: float = 0.03,
                 allow_offload: bool = False,
                 trace_ticks: int = 24,
                 trace_factory=None,
                 compile_cache: Optional[CompileCache] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.telemetry = TelemetryStore()
        # fleet-level jit-program cache: engine-backed devices of the same
        # platform share compiled decode/prefill programs through this
        self.compile_cache = (compile_cache if compile_cache is not None
                              else CompileCache())
        self.share_calibration = share_calibration
        self.warmup_ticks = warmup_ticks
        self.recalibrate_every = recalibrate_every
        self.observation_noise = observation_noise
        self.records: List[FleetTickRecord] = []
        self._tick = 0
        self._budget_margin = budget_margin
        self._devices: Dict[str, _DeviceRuntime] = {}
        nominal = ResourceContext()
        for spec in fleet:
            loop = AdaptationLoop(
                cfg=cfg, shape=shape, hw=spec.hw,
                allow_offload=allow_offload)
            # per-device SLA: margin × the *raw* full-variant estimate on
            # this silicon under a nominal context — tight enough that the
            # profiler's latent optimism causes real violations until the
            # feedback loop corrects it
            full = loop.evaluator.evaluate(Action(), nominal, calibrate=False)
            sla = budget_margin * full.latency_s
            loop.budgets = Budgets(
                latency_s=sla,
                memory_bytes=spec.hw.hbm_bytes * spec.chips)
            trace = (trace_factory(spec, trace_ticks) if trace_factory
                     else device_trace(spec, trace_ticks))
            self._devices[spec.device_id] = _DeviceRuntime(
                spec=spec, loop=loop, trace=iter(trace),
                rng=random.Random(seed * 7919 + spec.trace_seed),
                sla_s=sla)

    # ----------------------------------------------------------- plumbing --
    @property
    def devices(self) -> List[DeviceSpec]:
        return [d.spec for d in self._devices.values()]

    def loop_for(self, device_id: str) -> AdaptationLoop:
        return self._devices[device_id].loop

    def sla_for(self, device_id: str) -> float:
        return self._devices[device_id].sla_s

    def set_sla(self, device_id: str, sla_s: float) -> None:
        """Override a device's latency SLA (e.g. an externally mandated
        budget for an engine-backed device whose real step times live on
        a different scale than the analytic estimate)."""
        d = self._devices[device_id]
        d.sla_s = sla_s
        d.loop.budgets = Budgets(latency_s=sla_s,
                                 memory_bytes=d.loop.budgets.memory_bytes)

    def attach_engine(self, device_id: str, engine, steps_per_tick: int = 4
                      ) -> None:
        """Back a device with a real ServingEngine: its measured step
        wall-times replace the simulated observation for that device."""
        d = self._devices[device_id]
        d.engine = engine
        d.engine_steps = steps_per_tick

    def build_engine(self, device_id: str, params, *, cfg=None, slots: int = 4,
                     max_seq: int = 256, opts=None, steps_per_tick: int = 4,
                     decode_mode: str = "batched"):
        """Construct and attach a ServingEngine for a device, wired to the
        fleet's shared compile cache under the device's compile domain —
        same-platform fleet members reuse each other's jitted decode and
        prefill programs instead of compiling ~identical ones per device.

        ``cfg`` defaults to the fleet's model config; demos and tests pass
        a reduced variant so real decode steps stay cheap."""
        from repro.models.runtime import DEFAULT_OPTIONS
        from repro.serving import ServingEngine
        spec = self._devices[device_id].spec
        engine = ServingEngine(
            cfg if cfg is not None else self.cfg, params,
            slots=slots, max_seq=max_seq,
            opts=opts if opts is not None else DEFAULT_OPTIONS,
            decode_mode=decode_mode,
            compile_cache=self.compile_cache,
            compile_domain=spec.compile_domain)
        self.attach_engine(device_id, engine, steps_per_tick)
        return engine

    # ------------------------------------------------------------ observe --
    def _observe(self, d: _DeviceRuntime, raw_pred_s: float,
                 raw_pred_j: float) -> Optional[tuple]:
        if d.engine is not None:
            times = []
            for _ in range(d.engine_steps):
                if not d.engine.has_work:
                    break
                d.engine.step()
                times.append(d.engine.step_times[-1])
            if times:
                obs_s = sum(times) / len(times)
                # energy ≈ observed time at the device's sustained power
                obs_j = obs_s * d.spec.hw.peak_w
                return obs_s, obs_j, ENGINE
            # engine idle: no measurement this tick.  Falling back to the
            # simulated channel would mix wall-clock and analytic scales
            # in one calibrator and fake SLA violations.
            return None
        eps = d.rng.gauss(0.0, self.observation_noise)
        eps = max(-0.5, min(0.5, eps))
        obs_s = raw_pred_s * d.spec.latent_latency_factor * (1.0 + eps)
        eps_e = d.rng.gauss(0.0, self.observation_noise)
        obs_j = raw_pred_j * d.spec.latent_energy_factor * (1.0 + eps_e)
        return obs_s, obs_j, SIMULATED

    # --------------------------------------------------------------- step --
    def step(self) -> List[FleetTickRecord]:
        """One fleet tick: every device advances its trace by one context,
        adapts, executes (simulated or engine-backed), reports telemetry."""
        self._tick += 1
        out: List[FleetTickRecord] = []
        for d in self._devices.values():
            try:
                ctx = next(d.trace)
            except StopIteration:
                d.exhausted = True
                continue
            decision = d.loop.tick(ctx)
            raw = d.loop.evaluator.evaluate(decision.action, ctx,
                                            calibrate=False)
            obs = self._observe(d, raw.latency_s, raw.energy_j)
            if obs is None:
                continue
            obs_s, obs_j, chan = obs
            self.telemetry.record(MeasurementRecord(
                device_id=d.spec.device_id, tier=d.spec.tier,
                tick=self._tick,
                predicted_latency_s=raw.latency_s,
                observed_latency_s=obs_s,
                predicted_energy_j=raw.energy_j,
                observed_energy_j=obs_j,
                channel=chan))
            rec = FleetTickRecord(
                device_id=d.spec.device_id, tier=d.spec.tier,
                tick=self._tick, ctx=ctx, decision=decision,
                predicted_raw_s=raw.latency_s,
                predicted_s=decision.eval.latency_s,
                observed_s=obs_s, observed_energy_j=obs_j,
                sla_s=d.sla_s, violated=obs_s > d.sla_s)
            self.records.append(rec)
            out.append(rec)
        if self._tick >= self.warmup_ticks \
                and (self._tick - self.warmup_ticks) \
                % self.recalibrate_every == 0:
            self.recalibrate()
        return out

    def run(self, ticks: int) -> List[FleetTickRecord]:
        out = []
        for _ in range(ticks):
            if all(d.exhausted for d in self._devices.values()):
                break
            out.extend(self.step())
        return out

    # -------------------------------------------------------- calibration --
    def recalibrate(self) -> None:
        """Push telemetry-fitted corrections back into every loop — tier-
        pooled (crowd-shared) or per-device, always on the device's own
        measurement channel (engine wall-times and simulated silicon live
        on unrelated scales and must never share a fit)."""
        for d in self._devices.values():
            chan = ENGINE if d.engine is not None else SIMULATED
            if self.share_calibration:
                cal = self.telemetry.calibration_for_tier(d.spec.tier, chan)
            else:
                cal = self.telemetry.calibration_for_device(
                    d.spec.device_id, chan)
            if cal.samples:
                d.loop.set_calibration(cal)

    def calibration_of(self, device_id: str):
        return self._devices[device_id].loop.evaluator.calibration

    # ------------------------------------------------------------ queries --
    def probe_loop(self, spec: DeviceSpec,
                   channel: str = SIMULATED) -> AdaptationLoop:
        """A fresh loop for this device class — no decision history, same
        SLA recipe as ``__init__``, carrying only the tier's crowd-learned
        calibration on the probe's measurement ``channel``.  What a
        brand-new fleet member would decide with.  Under
        ``share_calibration=False`` there is no crowd transfer, so the
        probe (like any new member in that regime) starts uncalibrated."""
        loop = AdaptationLoop(cfg=self.cfg, shape=self.shape, hw=spec.hw,
                              allow_offload=False)
        full = loop.evaluator.evaluate(Action(), ResourceContext(),
                                       calibrate=False)
        loop.budgets = Budgets(
            latency_s=self._budget_margin * full.latency_s,
            memory_bytes=spec.hw.hbm_bytes * spec.chips)
        if self.share_calibration:
            loop.set_calibration(
                self.telemetry.calibration_for_tier(spec.tier, channel))
        return loop

    def violations(self, tier: Optional[str] = None,
                   first_tick: int = 0, last_tick: int = 10 ** 9) -> int:
        return sum(1 for r in self.records
                   if r.violated and first_tick <= r.tick <= last_tick
                   and (tier is None or r.tier == tier))
