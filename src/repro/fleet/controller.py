"""FleetController: one co-adaptation loop per device, crowd-calibrated.

Runs the paper's monitor→profiler→optimizer→apply loop for every device
in a heterogeneous fleet over interleaved per-device context traces.
Each tick produces a (predicted, observed) measurement pair; telemetry
fits per-tier corrections and the controller pushes them back into every
same-tier loop's evaluator — back-end measurements steering front-end
decisions, across devices.

Stepping is **event-driven** by default (``step_mode="event"``): a
min-heap of per-device next-wake times lets every device tick at its own
rate — the wake period comes from the device's
:attr:`~repro.fleet.registry.DeviceSpec.tick_envelope` (tier base rate,
DVFS-derated, clamped) plus, for engine-backed devices, the engine's
measured step-time EWMA.  A throttled little-core phone therefore never
gates an idle TPU slice, and telemetry reports reach the
:class:`TelemetryStore` out of order (per-device reporting jitter),
which the store's timestamp-sorted calibrators absorb.  The legacy
synchronized path is kept as ``step_mode="lockstep"``: one global tick
advances every device in unison, exactly the pre-event behavior.

Observations come from either (a) the device's latent ground-truth bias
(simulated silicon, default) or (b) a real :class:`ServingEngine`
attached to the device, whose measured step wall-times become the
observed latencies (see ``attach_engine``).
"""
from __future__ import annotations

import dataclasses
import heapq
import random
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.actions import Action, OffloadChoice
from repro.core.loop import AdaptationLoop, Decision
from repro.core.monitor import ResourceContext
from repro.core.optimizer import DRIFT_ACCURACY_COST, Budgets
from repro.faults.detector import (DEAD, SUSPECT, DetectorConfig,
                                   HeartbeatDetector, Transition)
from repro.faults.recovery import (RetryPolicy, execute_chain,
                                   plan_migration)
from repro.models.configs import InputShape, ModelConfig
from repro.obs import NULL_RECORDER, MetricsRegistry
from repro.serving import CompileCache

from .placement import FleetPlacer, PlacementDecision, SiteTopology
from .registry import DeviceSpec, device_trace
from .telemetry import (ENGINE, SIMULATED, AccuracyRecord,
                        MeasurementRecord, TelemetryStore)

# the workload shape fleet loops adapt for unless a caller overrides it
DEFAULT_SHAPE = InputShape("fleet", 256, 4, "prefill")

# "event": min-heap of per-device next-wake times (default);
# "lockstep": legacy synchronized stepping, one global tick for everyone
STEP_MODES = ("event", "lockstep")

# reserved heap ids ("<" cannot appear in a device_id, which is always
# "<platform>#<index>"): fleet-wide re-placement wakes, failure-detector
# sweeps, and one-shot scheduled callbacks (fault injection)
_PLACEMENT_WAKE = "<placement>"
_DETECTOR_WAKE = "<detector>"
_CALLBACK_WAKE = "<callback>"


@dataclass
class FleetTickRecord:
    """What one device did and what it cost on one fleet tick.

    ``tick`` is the device's own wake counter (in lockstep mode it
    coincides with the global tick); ``timestamp_s`` is the simulated
    fleet-clock instant of the wake — under event stepping, same-tick
    records from different devices carry different timestamps."""
    device_id: str
    tier: str
    tick: int
    ctx: ResourceContext
    decision: Decision
    predicted_raw_s: float        # uncalibrated analytic estimate
    predicted_s: float            # what the optimizer believed (calibrated)
    observed_s: float             # measured (simulated silicon or engine)
    observed_energy_j: float
    sla_s: float
    violated: bool
    timestamp_s: float = 0.0


@dataclass
class _DeviceRuntime:
    spec: DeviceSpec
    loop: AdaptationLoop
    trace: Iterator[ResourceContext]
    rng: random.Random
    sla_s: float
    engine: object = None         # optional ServingEngine
    engine_steps: int = 4
    exhausted: bool = False
    ticks: int = 0                # wakes taken so far
    dropped: bool = False         # left the fleet (drop_device)
    failed: Optional[str] = None  # active silence fault: "crash"|"freeze"
    scheduled: bool = False       # has a live heap entry (event mode)
    penalty_s: float = 0.0        # pending chain-recovery latency penalty


class FleetController:
    """Steps a heterogeneous fleet through shared scenarios, closing the
    telemetry loop per hardware tier.

    ``step_mode="event"`` (default) schedules devices on a min-heap of
    next-wake times so each ticks at its envelope's rate;
    ``step_mode="lockstep"`` advances all devices once per global tick
    (the legacy synchronized behavior).  In both modes ``run(ticks)``
    and ``step()`` work; event mode additionally exposes
    ``run_for(duration_s)`` to advance the simulated clock by a fixed
    horizon, which is where differential tick counts come from."""

    def __init__(self, fleet: Sequence[DeviceSpec], cfg: ModelConfig,
                 shape: InputShape = DEFAULT_SHAPE, *,
                 budget_margin: float = 1.5,
                 share_calibration: bool = True,
                 warmup_ticks: int = 6,
                 recalibrate_every: int = 2,
                 observation_noise: float = 0.03,
                 allow_offload: bool = False,
                 trace_ticks: int = 24,
                 trace_factory=None,
                 compile_cache: Optional[CompileCache] = None,
                 step_mode: str = "event",
                 telemetry_jitter_s: Optional[float] = None,
                 placement: bool = False,
                 topology: Optional[SiteTopology] = None,
                 placement_every_s: Optional[float] = None,
                 placement_drift: float = 0.15,
                 placement_hysteresis: float = 0.15,
                 detection: bool = True,
                 detector_config: Optional[DetectorConfig] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 recorder=NULL_RECORDER,
                 metrics: Optional[MetricsRegistry] = None,
                 slo=None,
                 seed: int = 0):
        if step_mode not in STEP_MODES:
            raise ValueError(f"unknown step_mode {step_mode!r}; "
                             f"expected one of {STEP_MODES}")
        self.cfg = cfg
        self.shape = shape
        self.step_mode = step_mode
        # ---- observability ------------------------------------------
        # One recorder, one simulated clock: the controller installs its
        # fleet clock into the recorder, so engine spans (wall-time) and
        # fleet clock events export onto a single shared timebase.  The
        # metrics registry replaces the old scattered tallies (_wakes,
        # placement_events); the public attributes below are views.
        self.recorder = recorder
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if recorder.enabled and getattr(recorder, "sim_clock", None) is None:
            recorder.sim_clock = self._sim_now
        self._wake_counter = self.metrics.counter("fleet.wakes")
        self._placement_counter = self.metrics.counter(
            "fleet.placement_events")
        self._violation_counter = self.metrics.counter("fleet.violations")
        self._energy_counter = self.metrics.counter("fleet.energy_j")
        self._recal_counter = self.metrics.counter("fleet.recalibrations")
        # ---- SLO burn-rate feedback ---------------------------------
        # When an SLOTracker is installed, engine-backed devices feed it
        # TTFT/TPOT observations and the wake path polls its pressure
        # signal; pressure transitions push `set_pressure` into every
        # device's adaptation loop and pull placement forward.  With no
        # tracker (the default) none of this runs — SLO-healthy and
        # tracker-free runs are bit-identical.
        self.slo = slo
        self._slo_pressure = 0.0
        self._slo_counter = self.metrics.counter("fleet.slo_pressure_events")
        if slo is not None:
            slo.bind(clock=self._sim_now, recorder=recorder)
        self.telemetry = TelemetryStore()
        self.telemetry.recorder = recorder
        # fleet-level jit-program cache: engine-backed devices of the same
        # platform share compiled decode/prefill programs through this
        self.compile_cache = (compile_cache if compile_cache is not None
                              else CompileCache())
        self.share_calibration = share_calibration
        self.warmup_ticks = warmup_ticks
        self.recalibrate_every = recalibrate_every
        self.observation_noise = observation_noise
        self.records: List[FleetTickRecord] = []
        self._tick = 0
        self._budget_margin = budget_margin
        self._devices: Dict[str, _DeviceRuntime] = {}
        nominal = ResourceContext()
        for spec in fleet:
            loop = AdaptationLoop(
                cfg=cfg, shape=shape, hw=spec.hw,
                allow_offload=allow_offload)
            # per-device SLA: margin × the *raw* full-variant estimate on
            # this silicon under a nominal context — tight enough that the
            # profiler's latent optimism causes real violations until the
            # feedback loop corrects it
            full = loop.evaluator.evaluate(Action(), nominal, calibrate=False)
            sla = budget_margin * full.latency_s
            loop.budgets = Budgets(
                latency_s=sla,
                memory_bytes=spec.hw.hbm_bytes * spec.chips)
            trace = (trace_factory(spec, trace_ticks) if trace_factory
                     else device_trace(spec, trace_ticks))
            # each member's loop + monitor report onto this device's
            # trace track
            loop.recorder = self.recorder
            loop.obs_pid = spec.device_id
            loop.monitor.recorder = self.recorder
            loop.monitor.obs_pid = spec.device_id
            self._devices[spec.device_id] = _DeviceRuntime(
                spec=spec, loop=loop, trace=iter(trace),
                rng=random.Random(seed * 7919 + spec.trace_seed),
                sla_s=sla)
        # ---- event-scheduler state (inert under lockstep) -------------
        periods = [d.spec.tick_envelope.nominal_s
                   for d in self._devices.values()] or [1.0]
        # run(ticks) horizon unit: the slowest member's nominal period,
        # so one "tick" of run() gives even the slowest device one wake
        self._base_period_s = max(periods)
        self._min_period_s = min(periods)
        # calibration cadence on the fleet clock, scaled so the fastest
        # devices see the same warmup/recalibrate tick counts as lockstep
        self._cal_period_s = recalibrate_every * self._min_period_s
        self._warmup_end_s = warmup_ticks * self._min_period_s
        self._next_cal_s = self._warmup_end_s
        self._now = 0.0
        self._seq = 0
        # telemetry reporting jitter: reports arrive at the store this
        # long after the observation (deterministic per (device, tick)),
        # de-ordering same-window reports across devices
        self._jitter_s = (telemetry_jitter_s if telemetry_jitter_s
                          is not None else 0.5 * self._min_period_s)
        self._pending: List[Tuple[float, int, MeasurementRecord]] = []
        self._heap: List[Tuple[float, int, str]] = []
        n = max(len(fleet), 1)
        for i, d in enumerate(self._devices.values()):
            # stagger first wakes across each device's own period so the
            # fleet doesn't start phase-locked
            self._push_device(d, d.spec.tick_envelope.nominal_s * i / n)
        # ---- cross-device placement (the fleet IS the device pool) ----
        self.placement = placement
        self.placer: Optional[FleetPlacer] = None
        self.placement_log: List[Tuple[float, int, PlacementDecision]] = []
        self._placement_drift = placement_drift
        self._place_period_s = (placement_every_s if placement_every_s
                                is not None else self._cal_period_s)
        self._next_place_s: Optional[float] = None
        if placement:
            self.placer = FleetPlacer(cfg, topology,
                                      hysteresis=placement_hysteresis)
            self.placer.recorder = self.recorder
            for d in self._devices.values():
                self.placer.register(d.spec)
                # placements flow back through the evaluator: fleet-peer
                # OffloadChoices resolve to live calibrated profiles
                d.loop.evaluator.pool_resolver = self._resolve_pool
            if step_mode == "event":
                # first re-placement after the calibration warmup
                self._next_place_s = self._warmup_end_s
                self._push(self._next_place_s, _PLACEMENT_WAKE)
        # ---- failure detection + recovery (the self-healing plane) ----
        # Heartbeat detection rides the same min-heap: every device wake
        # is a beat, a dedicated sweep wake advances the suspect→dead
        # state machine.  Detector/callback wakes deliberately do NOT
        # run the telemetry-flush/recalibration block, so a fault-free
        # run with detection on is bit-identical to one without it.
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        self._suspect_counter = self.metrics.counter(
            "fleet.detector_suspects")
        self._dead_counter = self.metrics.counter("fleet.detector_deaths")
        self._evict_counter = self.metrics.counter("fleet.evictions")
        self._retry_counter = self.metrics.counter("fleet.offload_retries")
        self._degrade_counter = self.metrics.counter(
            "fleet.degraded_fallbacks")
        self._readmit_counter = self.metrics.counter("fleet.readmissions")
        self._migration_counter = self.metrics.counter("fleet.migrations")
        self._telem_drop_counter = self.metrics.counter(
            "fleet.telemetry_dropped")
        self._derate_caps: Dict[str, float] = {}
        self._telem_faults: Dict[str, object] = {}
        self._fault_rng = random.Random(seed * 104729 + 7)
        self._callbacks: Dict[Tuple[float, int], Callable[[], None]] = {}
        self._detect_period_s = self._min_period_s
        self.detector: Optional[HeartbeatDetector] = None
        if detection and step_mode == "event":
            self.detector = HeartbeatDetector(detector_config)
            for d in self._devices.values():
                self.detector.track(d.spec.device_id,
                                    d.spec.tick_envelope.max_s)
            self._push(self._detect_period_s, _DETECTOR_WAKE)

    # ----------------------------------------------------------- plumbing --
    def _device(self, device_id: str) -> _DeviceRuntime:
        """Runtime lookup that fails usefully: an unknown id raises a
        KeyError naming the fleet's actual members instead of a bare
        repr (typos in device ids are a debugging tarpit otherwise)."""
        try:
            return self._devices[device_id]
        except KeyError:
            raise KeyError(
                f"unknown device_id {device_id!r}; known devices: "
                f"{sorted(self._devices)}") from None

    def _sim_now(self) -> float:
        """The simulated fleet-clock reading trace events are stamped
        with: the event clock under event stepping, the global tick
        under lockstep."""
        return self._now if self.step_mode == "event" else float(self._tick)

    @property
    def placement_events(self) -> int:
        """Re-placement sweeps run (view over ``fleet.placement_events``
        in the metrics registry)."""
        return self._placement_counter.value

    @property
    def migrations(self) -> int:
        """Requests live-migrated (frozen on an evicted member, thawed
        on a peer) so far — view over ``fleet.migrations``."""
        return self._migration_counter.value

    @property
    def devices(self) -> List[DeviceSpec]:
        return [d.spec for d in self._devices.values()]

    @property
    def now_s(self) -> float:
        """Current simulated fleet-clock time."""
        return self._now

    @property
    def tick_counts(self) -> Dict[str, int]:
        """Wakes taken per device so far — under event stepping fast
        devices accumulate strictly more than slow ones over the same
        simulated horizon."""
        return {did: d.ticks for did, d in self._devices.items()}

    def loop_for(self, device_id: str) -> AdaptationLoop:
        return self._device(device_id).loop

    def sla_for(self, device_id: str) -> float:
        return self._device(device_id).sla_s

    def set_sla(self, device_id: str, sla_s: float) -> None:
        """Override a device's latency SLA (e.g. an externally mandated
        budget for an engine-backed device whose real step times live on
        a different scale than the analytic estimate)."""
        d = self._device(device_id)
        d.sla_s = sla_s
        d.loop.budgets = Budgets(latency_s=sla_s,
                                 memory_bytes=d.loop.budgets.memory_bytes)

    def attach_engine(self, device_id: str, engine, steps_per_tick: int = 4
                      ) -> None:
        """Back a device with a real ServingEngine: its measured step
        wall-times replace the simulated observation for that device,
        and (in event mode) its step-time EWMA feeds the device's
        next-wake estimate.  An engine still carrying the no-op default
        recorder adopts the fleet's, with this device's id as its trace
        pid — its step/prefill/request spans then land on the device's
        track of the fleet timeline."""
        d = self._device(device_id)
        erec = getattr(engine, "recorder", None)
        if erec is not None and not erec.enabled and self.recorder.enabled:
            engine.recorder = self.recorder
            engine.pid = device_id
        d.engine = engine
        d.engine_steps = steps_per_tick
        # SLO feed: engine-backed devices report TTFT/TPOT into the
        # fleet's tracker (an engine with its own tracker keeps it)
        if self.slo is not None and getattr(engine, "slo", None) is None:
            engine.slo = self.slo

    def build_engine(self, device_id: str, params, *, cfg=None, slots: int = 4,
                     max_seq: int = 256, opts=None, steps_per_tick: int = 4,
                     decode_mode: str = "batched",
                     prefill_mode: str = "batched", sampling=None,
                     block_size: Optional[int] = None,
                     pool_blocks: Optional[int] = None,
                     prefix_entries: Optional[int] = None,
                     params_version: Optional[int] = None):
        """Construct and attach a ServingEngine for a device, wired to the
        fleet's shared compile cache under the device's compile domain —
        same-platform fleet members reuse each other's jitted decode and
        prefill programs instead of compiling ~identical ones per device.
        ``sampling`` sets the engine's default :class:`SamplingOpts`;
        per-slot sampling state is runtime data, so heterogeneous sampling
        across the fleet still shares every compiled program.

        ``cfg`` defaults to the fleet's model config; demos and tests pass
        a reduced variant so real decode steps stay cheap.  The paging
        knobs (``block_size``/``pool_blocks``/``prefix_entries``) only
        matter under ``decode_mode="paged"``; ``params_version`` tags the
        weights for freeze/thaw compatibility — engines built from the
        same params object agree by default, so in-flight requests
        migrate between them with zero re-prefill."""
        from repro.models.runtime import DEFAULT_OPTIONS
        from repro.serving import DEFAULT_SAMPLING, ServingEngine
        spec = self._device(device_id).spec
        paged_kw = {}
        if block_size is not None:
            paged_kw["block_size"] = block_size
        if pool_blocks is not None:
            paged_kw["pool_blocks"] = pool_blocks
        if prefix_entries is not None:
            paged_kw["prefix_entries"] = prefix_entries
        engine = ServingEngine(
            cfg if cfg is not None else self.cfg, params,
            slots=slots, max_seq=max_seq,
            opts=opts if opts is not None else DEFAULT_OPTIONS,
            decode_mode=decode_mode, prefill_mode=prefill_mode,
            sampling=sampling if sampling is not None else DEFAULT_SAMPLING,
            compile_cache=self.compile_cache,
            compile_domain=spec.compile_domain,
            recorder=self.recorder, pid=device_id,
            params_version=params_version, **paged_kw)
        self.attach_engine(device_id, engine, steps_per_tick)
        return engine

    # ---------------------------------------------------------- fault plane --
    # The surface the FaultInjector drives.  Each call is also usable
    # directly by tests: the controller doesn't know *why* a device
    # failed, only that it did.
    def device_is_up(self, device_id: str) -> bool:
        """False once the device crashed/froze, dropped, or ran out of
        trace — i.e. it will not wake again until thawed."""
        d = self._device(device_id)
        return not (d.exhausted or d.dropped) and d.failed is None

    def engine_of(self, device_id: str):
        """The device's attached ServingEngine (None when simulated)."""
        return self._device(device_id).engine

    def fail_device(self, device_id: str, mode: str = "crash") -> None:
        """Silence a device without telling anyone: it stops waking (and
        therefore heartbeating) but — unlike ``drop_device`` — nothing
        is announced; the detector must discover it.  ``"freeze"`` holds
        its loop/trace state for a later :meth:`thaw_device`;
        ``"crash"`` is permanent."""
        if mode not in ("crash", "freeze"):
            raise ValueError(f"unknown failure mode {mode!r}; "
                             f"expected 'crash' or 'freeze'")
        self._device(device_id).failed = mode

    def thaw_device(self, device_id: str) -> None:
        """End a freeze: the device wakes immediately and resumes its
        trace where it stopped.  Its first beat back is a *flap* — the
        detector quarantines it before the placer may use it again."""
        d = self._device(device_id)
        if d.failed is None:
            return
        d.failed = None
        if not d.scheduled and not d.exhausted \
                and self.step_mode == "event":
            self._push_device(d, self._now)

    def set_derate_cap(self, device_id: str,
                       cap: Optional[float]) -> None:
        """Straggler onset: clamp the device's effective DVFS derate to
        ``cap`` (< 1 slows its wakes and its raw latency — the fleet
        sees a device that suddenly runs hot).  ``None`` clears."""
        self._device(device_id)
        if cap is None:
            self._derate_caps.pop(device_id, None)
        else:
            self._derate_caps[device_id] = cap

    def set_telemetry_fault(self, device_id: str, fault) -> None:
        """Attach a :class:`~repro.faults.injector.TelemetryFault` to
        the device's reporting path (loss/delay/corruption applied at
        report time).  ``None`` clears."""
        self._device(device_id)
        if fault is None:
            self._telem_faults.pop(device_id, None)
        else:
            self._telem_faults[device_id] = fault

    def schedule_at(self, when_s: float,
                    fn: Callable[[], None]) -> None:
        """Run ``fn`` when the simulated clock reaches ``when_s`` — the
        hook fault schedules arm themselves with.  Callback wakes skip
        the telemetry-flush/recalibration block, so scheduling callbacks
        never perturbs a fault-free run's calibration stream."""
        if self.step_mode != "event":
            raise RuntimeError("schedule_at() requires step_mode='event'")
        self._seq += 1
        heapq.heappush(self._heap, (when_s, self._seq, _CALLBACK_WAKE))
        self._callbacks[(when_s, self._seq)] = fn

    # ------------------------------------------------------------ observe --
    def _observe(self, d: _DeviceRuntime, raw_pred_s: float,
                 raw_pred_j: float) -> Optional[tuple]:
        if d.engine is not None:
            times = []
            for _ in range(d.engine_steps):
                if not d.engine.has_work:
                    break
                d.engine.step()
                times.append(d.engine.step_times[-1])
            if times:
                obs_s = sum(times) / len(times)
                # energy ≈ observed time at the device's sustained power
                obs_j = obs_s * d.spec.hw.peak_w
                return obs_s, obs_j, ENGINE
            # engine idle: no measurement this tick.  Falling back to the
            # simulated channel would mix wall-clock and analytic scales
            # in one calibrator and fake SLA violations.
            return None
        eps = d.rng.gauss(0.0, self.observation_noise)
        eps = max(-0.5, min(0.5, eps))
        obs_s = raw_pred_s * d.spec.latent_latency_factor * (1.0 + eps)
        eps_e = d.rng.gauss(0.0, self.observation_noise)
        obs_j = raw_pred_j * d.spec.latent_energy_factor * (1.0 + eps_e)
        return obs_s, obs_j, SIMULATED

    # ------------------------------------------------------- shared tick ---
    def _advance(self, d: _DeviceRuntime, now_s: float
                 ) -> Tuple[Optional[FleetTickRecord],
                            Optional[ResourceContext]]:
        """Advance one device by one wake at fleet-clock ``now_s``:
        consume a trace context, adapt, execute, report telemetry.
        The whole wake is one ``fleet.wake`` span on the device's track,
        enclosing (in time) the loop decision, any engine steps, and the
        telemetry report it produced."""
        rec_on = self.recorder.enabled
        if rec_on:
            self.recorder.begin("fleet.wake", pid=d.spec.device_id,
                                tid="wake", cat="fleet",
                                args={"tick": d.ticks + 1})
        out = self._advance_inner(d, now_s)
        if rec_on:
            frec = out[0]
            args = {"exhausted": d.exhausted}
            if frec is not None:
                args.update(observed_s=frec.observed_s,
                            violated=frec.violated)
            self.recorder.end("fleet.wake", pid=d.spec.device_id,
                              tid="wake", cat="fleet", args=args)
        return out

    def _advance_inner(self, d: _DeviceRuntime, now_s: float
                       ) -> Tuple[Optional[FleetTickRecord],
                                  Optional[ResourceContext]]:
        try:
            ctx = next(d.trace)
        except StopIteration:
            d.exhausted = True
            return None, None
        d.ticks += 1
        self._wake_counter.inc()
        cap = self._derate_caps.get(d.spec.device_id)
        if cap is not None:
            # straggler fault: DVFS collapse caps the effective derate —
            # slower wakes, slower raw execution, visible to the placer
            ctx = dataclasses.replace(
                ctx, cpu_temp_derate=min(ctx.cpu_temp_derate, cap))
        self._sync_member(d, ctx)
        decision = d.loop.tick(ctx)
        peers = decision.action.offload.peers
        if peers and self._chain_lost(peers):
            decision = self._recover_chain(d, ctx, decision)
        raw = d.loop.evaluator.evaluate(decision.action, ctx,
                                        calibrate=False)
        obs = self._observe(d, raw.latency_s, raw.energy_j)
        if obs is None:
            return None, ctx
        obs_s, obs_j, chan = obs
        if d.penalty_s > 0.0:
            # chain recovery happened this wake: the timeouts + backoff
            # it burned are real observed latency, not a side channel
            obs_s += d.penalty_s
            d.penalty_s = 0.0
        if chan == SIMULATED:
            self._observe_accuracy(d, decision, ctx, now_s)
        mrec = MeasurementRecord(
            device_id=d.spec.device_id, tier=d.spec.tier,
            tick=d.ticks,
            predicted_latency_s=raw.latency_s,
            observed_latency_s=obs_s,
            predicted_energy_j=raw.energy_j,
            observed_energy_j=obs_j,
            channel=chan, timestamp_s=now_s)
        self._report(mrec)
        rec = FleetTickRecord(
            device_id=d.spec.device_id, tier=d.spec.tier,
            tick=d.ticks, ctx=ctx, decision=decision,
            predicted_raw_s=raw.latency_s,
            predicted_s=decision.eval.latency_s,
            observed_s=obs_s, observed_energy_j=obs_j,
            sla_s=d.sla_s, violated=obs_s > d.sla_s,
            timestamp_s=now_s)
        if rec.violated:
            self._violation_counter.inc()
        self._energy_counter.inc(obs_j)
        self.records.append(rec)
        return rec, ctx

    def _sync_member(self, d: _DeviceRuntime, ctx: ResourceContext) -> None:
        """Refresh the placer's view of this member (context + serving
        load) and trigger an immediate re-placement wake when the
        member's effective speed moved past the drift threshold — a
        helper throttling down is a placement-relevant event, not just a
        telemetry sample."""
        if self.placer is None:
            return
        did = d.spec.device_id
        if did not in self.placer.members:
            return
        prev = self.placer.member(did).ctx
        own_load = None
        if d.engine is not None:
            est = getattr(d.engine, "step_time_ewma_s", None)
            if est:
                busy = d.engine_steps * est
                own_load = busy / (busy + d.spec.tick_envelope.nominal_s)
        self.placer.update_member(did, ctx=ctx, own_load=own_load)
        drift = abs(ctx.cpu_temp_derate - prev.cpu_temp_derate) \
            + 0.15 * abs(ctx.competing_procs - prev.competing_procs)
        if drift >= self._placement_drift:
            self._schedule_placement(self._now)

    # ---------------------------------------------------- chain recovery ---
    def _peer_down(self, peer: str) -> bool:
        """Is this chain hop unusable right now?  Down means failed,
        dropped, exhausted, unknown, or already evicted from the placer
        — quarantined members are alive (just not *preferred*), so an
        existing chain through one keeps working."""
        d = self._devices.get(peer)
        if d is None or d.dropped or d.exhausted or d.failed is not None:
            return True
        return self.placer is not None and peer not in self.placer.members

    def _chain_lost(self, peers: Tuple[str, ...]) -> bool:
        return any(self._peer_down(p) for p in peers[1:])

    def _recover_chain(self, d: _DeviceRuntime, ctx: ResourceContext,
                       decision: Decision) -> Decision:
        """The decision's offload chain references a dead hop.  Pay the
        bounded retry/timeout price (:class:`RetryPolicy`), strip the
        dead fleet target, and re-decide **locally** — the optimizer
        falls back to the compressed elastic variants already in the
        action space, so the requester keeps producing instead of
        stalling until the next placement sweep (which this pulls
        forward)."""
        hosts = decision.action.offload.peers
        hop_s = decision.eval.latency_s / max(len(hosts) - 1, 1)
        outcome = execute_chain(hosts, hop_s,
                                alive=lambda p: not self._peer_down(p),
                                policy=self.retry_policy)
        self._retry_counter.inc(outcome.retries)
        self._degrade_counter.inc()
        d.penalty_s += outcome.penalty_s
        if self.recorder.enabled:
            self.recorder.instant(
                "recovery.retry", pid=d.spec.device_id, tid="recovery",
                cat="fleet",
                args={"failed_hop": outcome.failed_hop,
                      "attempts": outcome.attempts,
                      "penalty_s": outcome.penalty_s})
            self.recorder.instant(
                "recovery.degraded", pid=d.spec.device_id,
                tid="recovery", cat="fleet",
                args={"requester": d.spec.device_id,
                      "lost": outcome.failed_hop, "cause": "chain_loss"})
        d.loop.set_offload_targets(())
        d.loop.abandon_current()     # dead chain must not "hold"
        self._schedule_placement(self._now)
        return d.loop.tick(ctx)

    def _observe_accuracy(self, d: _DeviceRuntime, decision: Decision,
                          ctx: ResourceContext, now_s: float) -> None:
        """Simulate crowd labeling of the decision's task accuracy: the
        analytic proxy overshoots by the device's latent accuracy bias,
        and real drift costs twice what the model budgets.  The record
        lands in the telemetry accuracy channel; ``recalibrate`` feeds
        the pooled per-variant estimates back into every same-tier
        evaluator's ``measured`` dict."""
        variant = decision.action.variant
        pure = d.loop.evaluator.proxy_accuracy(variant)
        noise = max(-0.05, min(0.05,
                               d.rng.gauss(0.0, self.observation_noise / 3)))
        true_acc = max(0.0, pure - d.spec.latent_accuracy_bias
                       - 2.0 * DRIFT_ACCURACY_COST * ctx.data_drift + noise)
        self.telemetry.record_accuracy(AccuracyRecord(
            device_id=d.spec.device_id, tier=d.spec.tier, tick=d.ticks,
            variant=variant,
            predicted_accuracy=decision.eval.accuracy,
            observed_accuracy=true_acc,
            drift=ctx.data_drift, timestamp_s=now_s))

    # -------------------------------------------------- telemetry arrival --
    def _report(self, mrec: MeasurementRecord) -> None:
        """Route a measurement toward the store.  Lockstep (or zero
        jitter) delivers immediately; event mode delays each report by a
        deterministic per-(device, tick) latency, so arrival order at the
        store differs from observation order across devices.  An active
        :class:`~repro.faults.injector.TelemetryFault` on the device is
        applied here: reports may be dropped, delayed, or corrupted
        before the store ever sees them."""
        tf = self._telem_faults.get(mrec.device_id)
        extra_delay_s = 0.0
        if tf is not None:
            if tf.loss_p > 0.0 and self._fault_rng.random() < tf.loss_p:
                self._telem_drop_counter.inc()
                if self.recorder.enabled:
                    self.recorder.instant(
                        "telemetry.lost", pid=mrec.device_id,
                        tid="telemetry", cat="fleet",
                        args={"tick": mrec.tick})
                return
            if tf.corrupt_scale != 1.0:
                mrec = dataclasses.replace(
                    mrec, observed_latency_s=(mrec.observed_latency_s
                                              * tf.corrupt_scale))
            extra_delay_s = tf.delay_s
        if self.step_mode == "lockstep" or self._jitter_s <= 0:
            self.telemetry.record(mrec)
            return
        frac = ((zlib.crc32(mrec.device_id.encode())
                 + mrec.tick * 2654435761) % 1000) / 1000.0
        arrival = mrec.timestamp_s + frac * self._jitter_s + extra_delay_s
        if self.recorder.enabled:
            self.recorder.instant(
                "telemetry.report", pid=mrec.device_id, tid="telemetry",
                cat="fleet",
                args={"tick": mrec.tick, "channel": mrec.channel,
                      "arrival_s": arrival})
        self._seq += 1
        heapq.heappush(self._pending, (arrival, self._seq, mrec))

    def _flush_reports(self, upto_s: float) -> None:
        while self._pending and self._pending[0][0] <= upto_s:
            _, _, mrec = heapq.heappop(self._pending)
            self.telemetry.record(mrec)

    # ------------------------------------------------------ event engine ---
    def _push(self, when_s: float, device_id: str) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (when_s, self._seq, device_id))

    def _push_device(self, d: _DeviceRuntime, when_s: float) -> None:
        """Schedule a device wake, tracking that exactly one heap entry
        is outstanding for it — a thaw must not double-schedule a device
        whose frozen-era entry hasn't popped yet."""
        d.scheduled = True
        self._push(when_s, d.spec.device_id)

    # ----------------------------------------------------- failure detect --
    def _detector_sweep(self) -> None:
        """One detector wake: advance every tracked device's
        suspect→dead state machine on the current clock.  A device
        reaching DEAD is evicted through the same shared path
        ``drop_device`` uses — discovery and announcement converge."""
        rec_on = self.recorder.enabled
        for edge in self.detector.sweep(self._now):
            if edge.state == SUSPECT:
                self._suspect_counter.inc()
                if rec_on:
                    self.recorder.instant(
                        "detector.suspect", pid="fleet", tid="detector",
                        cat="fleet", args={"device": edge.device_id,
                                           "silent_s": edge.silent_s})
            elif edge.state == DEAD:
                self._dead_counter.inc()
                if rec_on:
                    self.recorder.instant(
                        "detector.dead", pid="fleet", tid="detector",
                        cat="fleet", args={"device": edge.device_id,
                                           "silent_s": edge.silent_s})
                self._evict(edge.device_id, cause="detected")

    def _on_recovered(self, d: _DeviceRuntime,
                      edge: Transition) -> None:
        """A suspect/dead device heartbeated again — a flap.  Readmit it
        (re-register with the placer if it was evicted) but under the
        detector's quarantine window: the placer will not select it as
        a helper until the window expires, so a blinking device can't
        ping-pong placements."""
        did = d.spec.device_id
        if self.recorder.enabled:
            self.recorder.instant(
                "detector.recovered", pid="fleet", tid="detector",
                cat="fleet",
                args={"device": did, "was": edge.was,
                      "flaps": edge.flaps,
                      "quarantined_until_s": edge.quarantined_until_s})
        if self.placer is None:
            return
        if did not in self.placer.members:
            self._readmit_counter.inc()
            st = self.placer.register(d.spec)
            st.quarantined_until_s = edge.quarantined_until_s
            self._schedule_placement(self._now)
        else:
            self.placer.member(did).quarantined_until_s = \
                edge.quarantined_until_s

    def _migration_peer(self, device_id: str) -> Optional[str]:
        """A live engine-backed fleet member sharing the evicted device's
        compile domain — frozen KV thaws only where the compiled
        programs (and therefore the weights binding) can match."""
        src = self._device(device_id)
        for did, d in self._devices.items():
            if did == device_id or d.engine is None:
                continue
            if not self.device_is_up(did):
                continue
            if d.spec.compile_domain != src.spec.compile_domain:
                continue
            return did
        return None

    def migrate_engine_requests(self, src_id: str,
                                dst_id: Optional[str] = None) -> int:
        """Move the source engine's entire in-flight + waiting workload
        to a same-domain peer: active requests freeze (pages + sampling
        subtree + consumed count serialized host-side) and thaw on the
        destination with **zero token loss and zero re-prefill** when
        the fingerprints match; waiting requests simply re-submit.
        Returns the number of requests moved (0 when the source has no
        engine or no live peer exists — in-flight work then requeues
        locally so nothing is lost either way)."""
        src = self._device(src_id)
        eng = src.engine
        if eng is None or not eng.has_work:
            return 0
        if dst_id is None:
            dst_id = self._migration_peer(src_id)
        if dst_id is None:
            eng.requeue_active(reason="evict_requeue")
            return 0
        dst = self._device(dst_id).engine
        moved = eng.freeze_all(reason="migrate")
        waiting = eng.drain_waiting()
        plan = plan_migration(moved, dst.can_thaw)
        rec_on = self.recorder.enabled
        for r in reversed(moved):
            ok = dst.thaw(r)
            if rec_on:
                self.recorder.instant(
                    "req.migrate", pid=src_id, tid="migration",
                    cat="request",
                    args={"rid": r.rid, "src": src_id, "dst": dst_id,
                          "reprefill": not ok})
        for r in waiting:
            dst.submit(r)
        n = len(moved) + len(waiting)
        self._migration_counter.inc(n)
        if rec_on:
            self.recorder.instant(
                "fleet.migrate", pid="fleet", tid="control", cat="fleet",
                args={"src": src_id, "dst": dst_id, "frozen": len(moved),
                      "waiting": len(waiting),
                      "zero_reprefill": list(plan.migrated),
                      "fallback": list(plan.fallback),
                      "recovered_tokens": plan.recovered_tokens})
        return n

    def _evict(self, device_id: str, cause: str) -> List[str]:
        """Shared eviction path (detector discovery and ``drop_device``
        announcement both land here): migrate the member's in-flight
        serving work to a same-domain peer (freeze/thaw — zero token
        loss, zero re-prefill), remove it from the placer, degrade every
        requester whose placement used it back to local (zero stall —
        their action spaces lose the dead fleet target immediately), and
        pull the next placement sweep forward.  Returns the affected
        requester ids."""
        self._evict_counter.inc()
        if self.recorder.enabled:
            self.recorder.instant(
                "fleet.evict", pid="fleet", tid="control", cat="fleet",
                args={"device": device_id, "cause": cause})
        self.migrate_engine_requests(device_id)
        if self.placer is None:
            return []
        affected = self.placer.remove_member(device_id)
        for rid in affected:
            dec = self.placer.current(rid)
            if rid in self._devices and dec is not None:
                self._devices[rid].loop.set_offload_targets(())
                self._devices[rid].loop.abandon_current()
                self.placement_log.append((self._now, self.wakes, dec))
                self._degrade_counter.inc()
                if self.recorder.enabled:
                    self.recorder.instant(
                        "recovery.degraded", pid=rid, tid="recovery",
                        cat="fleet",
                        args={"requester": rid, "lost": device_id,
                              "cause": cause})
        self._schedule_placement(self._now)
        return affected

    # -------------------------------------------------------- slo feedback --
    def _slo_feedback(self) -> None:
        """Poll the SLO tracker on the wake path and propagate pressure
        transitions.  While the error budget burns (pressure > 0) every
        device's adaptation loop flips latency-first via
        ``set_pressure``, and on the rising edge the next placement
        sweep is pulled forward so offload targets refresh under load.
        Pressure is pushed only on *change*: a healthy run never calls
        ``set_pressure`` at all, keeping it bit-identical to a
        tracker-free run."""
        p = self.slo.update(self._now)
        if p == self._slo_pressure:
            return
        rising = self._slo_pressure == 0.0
        self._slo_pressure = p
        for dd in self._devices.values():
            dd.loop.set_pressure(p)
        if rising and p > 0.0:
            self._slo_counter.inc()
            self._schedule_placement(self._now)

    # ---------------------------------------------------------- placement --
    def _schedule_placement(self, when_s: float) -> None:
        """Pull the next re-placement wake forward to ``when_s`` (no-op
        when one is already due sooner, or under lockstep — where
        placement runs on the recalibration cadence instead).  Never
        pulls a sweep before the calibration warmup ends: placing on
        zero-sample calibrations would commit a blind placement that
        hysteresis then defends."""
        if self.placer is None or self.step_mode != "event":
            return
        when_s = max(when_s, self._warmup_end_s)
        if self._next_place_s is None or when_s < self._next_place_s - 1e-9:
            self._next_place_s = when_s
            self._push(when_s, _PLACEMENT_WAKE)

    def _placement_wake(self, when_s: float) -> None:
        """One popped placement heap entry.  Entries superseded by a
        pulled-forward wake are stale and skipped; a live one runs the
        fleet-wide re-placement sweep and schedules the next periodic
        wake."""
        if self._next_place_s is not None \
                and when_s < self._next_place_s - 1e-9:
            return                      # superseded by an earlier wake
        self._placement_event(self._now)
        self._next_place_s = self._now + self._place_period_s
        self._push(self._next_place_s, _PLACEMENT_WAKE)

    def _placement_event(self, now_s: float) -> None:
        """Fleet-wide re-placement sweep (a clock event): refresh every
        member's crowd calibration in the placer, re-place each live
        requester over the current fleet state, and push changed
        placements back into that device's action space as fleet-peer
        ``OffloadChoice`` targets — the optimizer then weighs them
        against local variants on its next wake."""
        if self.placer is None:
            return
        self._placement_counter.inc()
        if self.recorder.enabled:
            self.recorder.begin("placement.sweep", pid="fleet",
                                tid="placement", cat="placement",
                                args={"sweep": self._placement_counter.value})
        changed = 0
        for d in self._devices.values():
            if d.spec.device_id not in self.placer.members:
                continue
            chan = ENGINE if d.engine is not None else SIMULATED
            cal = (self.telemetry.calibration_for_tier(d.spec.tier, chan)
                   if self.share_calibration else
                   self.telemetry.calibration_for_device(
                       d.spec.device_id, chan))
            self.placer.update_member(d.spec.device_id, calibration=cal)
        for d in self._devices.values():
            if d.dropped or d.exhausted or d.failed is not None:
                continue
            did = d.spec.device_id
            prev = self.placer.current(did)
            dec = self.placer.place(did, now_s=now_s)
            if prev is not None and dec.hosts == prev.hosts:
                continue
            changed += 1
            self.placement_log.append((now_s, self.wakes, dec))
            if dec.offloaded:
                d.loop.set_offload_targets((OffloadChoice(
                    enabled=True, pool="fleet", level=self.placer.level,
                    peers=dec.hosts),))
            else:
                d.loop.set_offload_targets(())
        if self.recorder.enabled:
            self.recorder.end("placement.sweep", pid="fleet",
                              tid="placement", cat="placement",
                              args={"changed": changed})

    def _resolve_pool(self, offload):
        """Evaluator hook: fleet-peer choices resolve through the placer
        to live calibrated profiles; pool keys stay static."""
        if offload.peers and self.placer is not None:
            return self.placer.resolve_profiles(offload.peers)
        from repro.offload.placer import DEVICE_POOLS
        return DEVICE_POOLS[offload.pool]

    def inject_load(self, device_id: str, own_load: float) -> None:
        """Externally mark a member as (un)loaded — e.g. a helper whose
        owner started a game — and pull the next re-placement wake
        forward so the fleet reacts within a bounded number of clock
        events."""
        self._device(device_id)
        if self.placer is None:
            raise RuntimeError("placement is not enabled on this fleet")
        if self.recorder.enabled:
            self.recorder.instant("fleet.inject_load", pid="fleet",
                                  tid="control", cat="fleet",
                                  args={"device": device_id,
                                        "own_load": own_load})
        self.placer.update_member(device_id, own_load=own_load)
        self._schedule_placement(self._now)

    def drop_device(self, device_id: str) -> List[str]:
        """A member leaves the fleet mid-run — the *announced* caller of
        the shared eviction path (the failure detector is the
        *discovered* one).  Its loop stops waking; any requester whose
        placement used it falls back to local-only immediately (the
        placer rewrites their decisions) and their action spaces lose
        the dead fleet target.  Returns the affected requester ids."""
        d = self._device(device_id)
        d.dropped = True
        d.exhausted = True
        if self.detector is not None:
            # announced departures are expected silences, not failures
            self.detector.untrack(device_id)
        if self.recorder.enabled:
            self.recorder.instant("fleet.drop_device", pid="fleet",
                                  tid="control", cat="fleet",
                                  args={"device": device_id})
        return self._evict(device_id, cause="announced")

    def placement_of(self, device_id: str) -> Optional[PlacementDecision]:
        """The device's current placement decision (None before the
        first sweep or when placement is disabled)."""
        return self.placer.current(device_id) if self.placer else None

    @property
    def wakes(self) -> int:
        """Device wakes processed so far — the clock-event count used to
        bound re-placement reaction time (view over ``fleet.wakes`` in
        the metrics registry)."""
        return self._wake_counter.value

    def _next_period(self, d: _DeviceRuntime,
                     ctx: Optional[ResourceContext]) -> float:
        """Seconds until this device's next wake: DVFS-derated envelope
        period, plus the engine's measured step latency when one is
        attached (the serving hook feeding next-wake estimates)."""
        env = d.spec.tick_envelope
        derate = ctx.cpu_temp_derate if ctx is not None else 1.0
        period = env.clamp(env.nominal_s / max(derate, 1e-3))
        if d.engine is not None:
            est = getattr(d.engine, "step_time_ewma_s", None)
            if est:
                period += d.engine_steps * est
        return period

    def run_for(self, duration_s: float) -> List[FleetTickRecord]:
        """Event mode: advance the simulated clock by ``duration_s``,
        processing every device wake that falls due.  Fast devices wake
        many times per slow-device wake; devices whose traces end go
        idle without holding anyone back.  Finishes with a telemetry
        flush and recalibration so loop corrections reflect everything
        observed inside the horizon."""
        if self.step_mode != "event":
            raise RuntimeError("run_for() requires step_mode='event'; "
                               "use step()/run() under lockstep")
        horizon = self._now + duration_s
        out: List[FleetTickRecord] = []
        while self._heap and self._heap[0][0] <= horizon:
            when, seq, did = heapq.heappop(self._heap)
            if did == _DETECTOR_WAKE:
                # detector/callback wakes advance the clock but skip the
                # telemetry-flush/recalibration block below — a fault-free
                # run's calibration points stay bit-identical to a run
                # without detection
                self._now = max(self._now, when)
                self._detector_sweep()
                self._push(self._now + self._detect_period_s,
                           _DETECTOR_WAKE)
                continue
            if did == _CALLBACK_WAKE:
                self._now = max(self._now, when)
                cb = self._callbacks.pop((when, seq), None)
                if cb is not None:
                    cb()
                continue
            self._now = max(self._now, when)
            self._flush_reports(self._now)
            while self._now >= self._next_cal_s:
                self.recalibrate()
                self._next_cal_s += self._cal_period_s
            if did == _PLACEMENT_WAKE:
                self._placement_wake(when)
                continue
            d = self._devices[did]
            d.scheduled = False
            if d.exhausted:
                continue
            if d.failed is not None:
                # crashed/frozen: silent — no trace advance, no report,
                # no heartbeat, no re-push (thaw_device re-pushes)
                continue
            rec, ctx = self._advance(d, self._now)
            if self.slo is not None:
                self._slo_feedback()
            if self.detector is not None:
                edge = self.detector.beat(
                    did, self._now, period_s=self._next_period(d, ctx))
                if edge is not None:
                    self._on_recovered(d, edge)
            if d.exhausted:
                if self.detector is not None:
                    # ran out of trace: an expected silence
                    self.detector.untrack(did)
            else:
                self._push_device(d, self._now + self._next_period(d, ctx))
            if rec is not None:
                out.append(rec)
        self._now = horizon
        # every pending report was observed inside the horizon — deliver
        # even those whose jittered arrival would land past it, so the
        # closing recalibration and any post-run report see everything
        self._flush_reports(float("inf"))
        if self._now >= self._warmup_end_s:
            self.recalibrate()
        return out

    # --------------------------------------------------------------- step --
    def step(self) -> List[FleetTickRecord]:
        """One fleet step.  Lockstep: every device advances its trace by
        one context in unison.  Event: the simulated clock advances by
        one base period (the slowest member's nominal wake interval) and
        whichever wakes fall due are processed — fast devices several,
        slow devices at most one."""
        if self.step_mode == "event":
            return self.run_for(self._base_period_s)
        self._tick += 1
        out: List[FleetTickRecord] = []
        for d in self._devices.values():
            if d.exhausted:           # trace ended or drop_device()
                continue
            rec, _ = self._advance(d, float(self._tick))
            if rec is not None:
                out.append(rec)
        if self._tick >= self.warmup_ticks \
                and (self._tick - self.warmup_ticks) \
                % self.recalibrate_every == 0:
            self.recalibrate()
            if self.placer is not None:
                # under lockstep, re-placement rides the recalibration
                # cadence instead of being its own clock event
                self._placement_event(float(self._tick))
        return out

    def run(self, ticks: int) -> List[FleetTickRecord]:
        """Advance the fleet by ``ticks`` steps (see :meth:`step` for
        what one step means per mode), stopping early once every trace
        is exhausted."""
        out = []
        for _ in range(ticks):
            if all(d.exhausted for d in self._devices.values()):
                break
            out.extend(self.step())
        return out

    # -------------------------------------------------------- calibration --
    def recalibrate(self) -> None:
        """Push telemetry-fitted corrections back into every loop — tier-
        pooled (crowd-shared) or per-device, always on the device's own
        measurement channel (engine wall-times and simulated silicon live
        on unrelated scales and must never share a fit).  Crowd-measured
        task accuracy flows back the same way: the tier's per-variant
        drift-free estimates land in each evaluator's ``measured`` dict,
        so the accuracy proxy is corrected alongside latency/energy."""
        self._recal_counter.inc()
        if self.recorder.enabled:
            self.recorder.begin("fleet.recalibrate", pid="fleet",
                                tid="calibration", cat="fleet",
                                args={"round": self._recal_counter.value})
        acc_by_tier: Dict[str, Dict] = {}
        for d in self._devices.values():
            chan = ENGINE if d.engine is not None else SIMULATED
            if self.share_calibration:
                cal = self.telemetry.calibration_for_tier(d.spec.tier, chan)
            else:
                cal = self.telemetry.calibration_for_device(
                    d.spec.device_id, chan)
            if cal.samples:
                d.loop.set_calibration(cal)
            tier = d.spec.tier
            if tier not in acc_by_tier:
                acc_by_tier[tier] = \
                    self.telemetry.measured_accuracy_for_tier(tier)
            if acc_by_tier[tier]:
                d.loop.evaluator.measured.update(acc_by_tier[tier])
                d.loop.front = []
        if self.recorder.enabled:
            self.recorder.end("fleet.recalibrate", pid="fleet",
                              tid="calibration", cat="fleet")

    def calibration_of(self, device_id: str):
        return self._device(device_id).loop.evaluator.calibration

    # ------------------------------------------------------------ queries --
    def probe_loop(self, spec: DeviceSpec,
                   channel: str = SIMULATED) -> AdaptationLoop:
        """A fresh loop for this device class — no decision history, same
        SLA recipe as ``__init__``, carrying only the tier's crowd-learned
        calibration on the probe's measurement ``channel``.  What a
        brand-new fleet member would decide with.  Under
        ``share_calibration=False`` there is no crowd transfer, so the
        probe (like any new member in that regime) starts uncalibrated."""
        loop = AdaptationLoop(cfg=self.cfg, shape=self.shape, hw=spec.hw,
                              allow_offload=False)
        full = loop.evaluator.evaluate(Action(), ResourceContext(),
                                       calibrate=False)
        loop.budgets = Budgets(
            latency_s=self._budget_margin * full.latency_s,
            memory_bytes=spec.hw.hbm_bytes * spec.chips)
        if self.share_calibration:
            loop.set_calibration(
                self.telemetry.calibration_for_tier(spec.tier, channel))
        return loop

    def violations(self, tier: Optional[str] = None,
                   first_tick: int = 0, last_tick: int = 10 ** 9,
                   first_s: Optional[float] = None,
                   last_s: Optional[float] = None) -> int:
        """Count SLA violations, filtered by tier and either per-device
        tick range (``first_tick``/``last_tick``) or fleet-clock window
        (``first_s``/``last_s`` — the natural filter under event
        stepping, where tick numbers aren't comparable across devices)."""
        def keep(r: FleetTickRecord) -> bool:
            if not r.violated or (tier is not None and r.tier != tier):
                return False
            if first_s is not None and r.timestamp_s < first_s:
                return False
            if last_s is not None and r.timestamp_s > last_s:
                return False
            return first_tick <= r.tick <= last_tick
        return sum(1 for r in self.records if keep(r))
