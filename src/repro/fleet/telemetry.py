"""Crowd telemetry: measurement records + prediction calibration.

This is the feedback path the paper names as the key open challenge —
"feeding back runtime performance from the back-end level to the
front-end level optimization decision".  Devices report (predicted,
observed) latency/energy pairs per adaptation tick; the store fits an
affine correction per hardware tier (EWMA ratio while samples are
scarce, windowed least squares once enough accumulate) and hands back
:class:`repro.core.profiler.Calibration` objects the optimizer's
``ActionEvaluator`` applies to every subsequent estimate.

Tier-level pooling is the crowd-knowledge transfer: a freshly joined
pixel_6 benefits immediately from measurements contributed by every
other light-tier phone, before it has produced a single sample itself.

Pooling is split by **measurement channel**: engine-backed devices
report real decode-step wall-times, simulated devices report analytic
latencies scaled by latent silicon bias — two scales that share no
affine relationship.  Calibrator populations are keyed on
``(tier, channel)`` (and ``(device, channel)``), so a fleet mixing both
kinds never cross-contaminates its fits.

A third channel carries **crowd-labeled task accuracy**: devices report
:class:`AccuracyRecord`\\ s per elastic variant, the store pools a
drift-corrected per-``(tier, variant)`` estimate
(:meth:`TelemetryStore.measured_accuracy_for_tier`), and the fleet
controller feeds it back into every same-tier
``ActionEvaluator.measured`` — closing the accuracy loop the same way
the latency/energy loop closes.

Arrival-order independence: under the event-driven fleet scheduler,
devices tick at independent rates and their reports reach the store out
of order (reporting latency jitters per device).  Every record carries a
``timestamp_s``; calibrators keep their samples in a container sorted by
``(timestamp, device, tick)`` and compute every fit from that sorted
view, so any permutation of the same record set yields bit-identical
:class:`Calibration` objects.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core.optimizer import DRIFT_ACCURACY_COST
from repro.core.profiler import Calibration
from repro.obs import NULL_RECORDER

# measurement channels: what produced the observation
SIMULATED = "simulated"     # latent-bias silicon simulation (analytic scale)
ENGINE = "engine"           # real ServingEngine step wall-times
ACCURACY = "accuracy"       # crowd-labeled task accuracy per variant
CHANNELS = (SIMULATED, ENGINE, ACCURACY)


@dataclass(frozen=True)
class MeasurementRecord:
    """One back-end observation of one adaptation-loop decision.

    ``predicted_*`` fields are the *raw* (uncalibrated) analytic
    estimates the profiler produced for the decision; ``observed_*`` are
    what execution actually cost on the ``channel`` that measured it
    (``"simulated"`` latent-bias silicon or ``"engine"`` wall-clock).
    ``tick`` counts the reporting device's own adaptation wakes;
    ``timestamp_s`` is the simulated fleet-clock instant the observation
    was taken — the sort key that makes calibrator fits independent of
    the order records reach the store."""
    device_id: str
    tier: str
    tick: int
    predicted_latency_s: float       # raw analytic estimate (uncalibrated)
    observed_latency_s: float
    predicted_energy_j: float
    observed_energy_j: float
    tokens: int = 0
    channel: str = SIMULATED
    timestamp_s: float = 0.0


@dataclass(frozen=True)
class AccuracyRecord:
    """One crowd-labeled task-accuracy observation.

    ``variant`` identifies the elastic variant the accuracy was measured
    for (any hashable key — in practice a ``VariantSpec``);
    ``predicted_accuracy`` is what the optimizer believed when it chose
    the action, ``observed_accuracy`` what crowd labeling actually
    measured under ``drift`` units of distribution shift.  Records merge
    by ``timestamp_s`` exactly like latency records, so the accuracy
    channel is arrival-order independent too."""
    device_id: str
    tier: str
    tick: int
    variant: Hashable
    predicted_accuracy: float
    observed_accuracy: float
    drift: float = 0.0
    timestamp_s: float = 0.0


# one calibrator sample: (sort_key, pred_lat, obs_lat, pred_en, obs_en)
_Entry = Tuple[tuple, float, float, float, float]


class EwmaLsqCalibrator:
    """Affine latency correction + ratio energy correction.

    Cold start: an EWMA of the observed/predicted ratio (bias-only, robust
    from the very first sample).  Warm: least-squares fit of
    ``observed ≈ a·predicted + b`` over a sliding window, which also
    captures fixed per-step overheads (dispatch, cache swaps) that a pure
    ratio cannot.

    Samples are merged in **timestamp order**, not arrival order: each
    ``observe`` carries a sort key (timestamp plus a deterministic
    tie-break) and is inserted into a sorted container; ``calibration()``
    walks that container, so shuffling the arrival order of one record
    set cannot change the fit.  Direct ``observe`` calls without an
    explicit timestamp fall back to an arrival counter (the legacy
    in-order behavior); records fed through :class:`TelemetryStore`
    always carry their ``timestamp_s`` — unstamped legacy records share
    a 0.0 timestamp and are ordered by the ``(device_id, tick)``
    tie-break rather than by arrival."""

    def __init__(self, window: int = 64, alpha: float = 0.3,
                 min_lsq_samples: int = 8):
        self.window = window
        self.alpha = alpha
        self.min_lsq_samples = min_lsq_samples
        # sorted by sort_key; pruned to the newest _keep entries by time
        self._entries: List[_Entry] = []
        self._keep = 4 * window
        self._arrivals = 0
        self._n = 0
        self._cached: Optional[Calibration] = None

    def observe(self, pred_lat: float, obs_lat: float,
                pred_en: float, obs_en: float, *,
                timestamp_s: Optional[float] = None,
                key: tuple = ()) -> None:
        """Merge one (predicted, observed) pair.  ``timestamp_s`` orders
        the sample on the fleet clock (``None`` → arrival order);
        ``key`` deterministically breaks timestamp ties (the store passes
        ``(device_id, tick)``)."""
        self._arrivals += 1
        if pred_lat <= 0 or obs_lat <= 0:
            return
        sort_key = ((timestamp_s,) + key if timestamp_s is not None
                    else (float(self._arrivals),))
        bisect.insort(self._entries,
                      (sort_key, pred_lat, obs_lat, pred_en, obs_en))
        if len(self._entries) > self._keep:
            # drop the oldest-by-timestamp — the kept set is always "the
            # newest _keep samples", whatever order they arrived in
            del self._entries[0]
        self._n += 1
        self._cached = None

    @property
    def samples(self) -> int:
        return self._n

    def calibration(self) -> Calibration:
        """The current fit, computed from the time-sorted sample view
        (cached until the next ``observe``)."""
        if self._cached is not None:
            return self._cached
        ratio_lat: Optional[float] = None
        ratio_en: Optional[float] = None
        a = self.alpha
        for _, pl, ol, pe, oe in self._entries:
            r = ol / pl
            ratio_lat = r if ratio_lat is None \
                else (1 - a) * ratio_lat + a * r
            if pe > 0 and oe > 0:
                re_ = oe / pe
                ratio_en = re_ if ratio_en is None \
                    else (1 - a) * ratio_en + a * re_
        scale = ratio_lat if ratio_lat is not None else 1.0
        bias = 0.0
        win = self._entries[-self.window:]
        if len(win) >= self.min_lsq_samples:
            p = np.array([e[1] for e in win])
            o = np.array([e[2] for e in win])
            # degenerate spread (all predictions identical) → ratio only
            if float(p.std()) > 1e-9 * max(float(p.mean()), 1e-30):
                A = np.stack([p, np.ones_like(p)], axis=1)
                (sl, b), *_ = np.linalg.lstsq(A, o, rcond=None)
                # accept the affine fit only if it actually beats the
                # ratio on the window — outliers (compile spikes, load
                # bursts) can drive LSQ to wild slopes/negative intercepts
                if sl > 0:
                    lsq_err = np.mean(np.abs(np.maximum(sl * p + b, 1e-12)
                                             - o) / o)
                    ratio_err = np.mean(np.abs(scale * p - o) / o)
                    if lsq_err < ratio_err:
                        scale, bias = float(sl), float(b)
        self._cached = Calibration(
            latency_scale=scale, latency_bias_s=bias,
            energy_scale=ratio_en if ratio_en is not None else 1.0,
            samples=self._n)
        return self._cached


class TelemetryStore:
    """Fleet-wide record store with per-(tier, channel) crowd-shared and
    per-(device, channel) calibrators.

    ``record`` routes each :class:`MeasurementRecord` into both its
    tier's pooled calibrator and its device's private one, keyed on the
    record's measurement channel; lookups return fitted
    :class:`Calibration` objects (identity until a key has samples).
    Because calibrators merge by record timestamp, the store accepts
    out-of-order arrival — late reports from slow fleet members slot
    into their proper place in every fit."""

    def __init__(self, window: int = 64, alpha: float = 0.3,
                 min_lsq_samples: int = 8):
        self._kw = dict(window=window, alpha=alpha,
                        min_lsq_samples=min_lsq_samples)
        self._alpha = alpha
        # observability: the fleet controller points this at its
        # TraceRecorder so every merge lands as a telemetry.merge
        # instant (flagging reports that arrived out of timestamp order)
        self.recorder = NULL_RECORDER
        self.obs_pid = "fleet"
        self._max_ts_seen = float("-inf")
        self.records: List[MeasurementRecord] = []
        self.accuracy_records: List[AccuracyRecord] = []
        self._by_tier: Dict[Tuple[str, str], EwmaLsqCalibrator] = {}
        self._by_device: Dict[Tuple[str, str], EwmaLsqCalibrator] = {}
        # (tier, variant) -> timestamp-sorted (sort_key, drift-free obs),
        # trimmed to the newest _acc_keep like the latency calibrators,
        # with the EWMA memoized until the next insert
        self._acc: Dict[Tuple[str, Hashable], List[Tuple[tuple, float]]] = {}
        self._acc_keep = 4 * window
        self._acc_cached: Dict[Tuple[str, Hashable], Optional[float]] = {}

    # ------------------------------------------------------------ intake --
    def record(self, rec: MeasurementRecord) -> None:
        """Ingest one observation (any arrival order): append to the
        audit log and merge into the ``(tier, channel)`` and
        ``(device, channel)`` calibrators at its timestamp."""
        if self.recorder.enabled:
            self.recorder.instant(
                "telemetry.merge", pid=self.obs_pid, tid="telemetry",
                cat="fleet",
                args={"device": rec.device_id, "tier": rec.tier,
                      "tick": rec.tick, "channel": rec.channel,
                      "observed_ts_s": rec.timestamp_s,
                      "out_of_order": rec.timestamp_s < self._max_ts_seen})
        if rec.timestamp_s > self._max_ts_seen:
            self._max_ts_seen = rec.timestamp_s
        self.records.append(rec)
        for key, table in (((rec.tier, rec.channel), self._by_tier),
                           ((rec.device_id, rec.channel), self._by_device)):
            if key not in table:
                table[key] = EwmaLsqCalibrator(**self._kw)
            table[key].observe(rec.predicted_latency_s,
                               rec.observed_latency_s,
                               rec.predicted_energy_j,
                               rec.observed_energy_j,
                               timestamp_s=rec.timestamp_s,
                               key=(rec.device_id, rec.tick))

    def record_accuracy(self, rec: AccuracyRecord) -> None:
        """Ingest one crowd-labeled accuracy observation.  The modeled
        drift penalty (``DRIFT_ACCURACY_COST × drift``) is backed OUT of
        the observation before pooling, so what accumulates per
        ``(tier, variant)`` is the drift-free measured accuracy — the
        quantity ``ActionEvaluator.measured`` expects (the evaluator
        re-applies the drift term for whatever context it scores)."""
        self.accuracy_records.append(rec)
        driftfree = rec.observed_accuracy \
            + DRIFT_ACCURACY_COST * rec.drift
        key = (rec.tier, rec.variant)
        sort_key = (rec.timestamp_s, rec.device_id, rec.tick)
        entries = self._acc.setdefault(key, [])
        bisect.insort(entries, (sort_key, driftfree))
        if len(entries) > self._acc_keep:
            del entries[0]          # drop the oldest-by-timestamp
        self._acc_cached[key] = None

    def measured_accuracy_for_tier(self, tier: str) -> Dict[Hashable,
                                                            float]:
        """Crowd-measured drift-free accuracy per variant for one tier —
        an EWMA over the timestamp-sorted samples (arrival-order
        independent, like the latency calibrators).  Feed the result
        into ``ActionEvaluator.measured``."""
        out: Dict[Hashable, float] = {}
        for key, entries in self._acc.items():
            t, variant = key
            if t != tier or not entries:
                continue
            est = self._acc_cached.get(key)
            if est is None:
                for _, v in entries:
                    est = v if est is None \
                        else (1 - self._alpha) * est + self._alpha * v
                self._acc_cached[key] = est
            out[variant] = est
        return out

    def accuracy_mae(self, tier: Optional[str] = None,
                     measured: Optional[Dict[Hashable, float]] = None
                     ) -> float:
        """Mean absolute error of accuracy predictions vs crowd labels.
        With ``measured``, each record's prediction is replaced by the
        crowd estimate for its variant (minus the modeled drift term at
        the record's own drift) — before/after under one record set
        isolates what the accuracy feedback loop bought."""
        errs = []
        for r in self.accuracy_records:
            if tier is not None and r.tier != tier:
                continue
            pred = r.predicted_accuracy
            if measured is not None and r.variant in measured:
                pred = max(0.0, measured[r.variant]
                           - DRIFT_ACCURACY_COST * r.drift)
            errs.append(abs(pred - r.observed_accuracy))
        return float(np.mean(errs)) if errs else float("nan")

    # ----------------------------------------------------------- lookup ---
    def calibration_for_tier(self, tier: str,
                             channel: str = SIMULATED) -> Calibration:
        """The crowd-shared fit for one ``(tier, channel)`` pool — what a
        fresh same-tier device should correct its estimates with."""
        c = self._by_tier.get((tier, channel))
        return c.calibration() if c else Calibration()

    def calibration_for_device(self, device_id: str,
                               channel: str = SIMULATED) -> Calibration:
        """One device's private fit on one channel (the non-crowd-shared
        regime, capturing its individual silicon)."""
        c = self._by_device.get((device_id, channel))
        return c.calibration() if c else Calibration()

    def device_channel(self, device_id: str) -> str:
        """The channel a device most recently reported on (a device is
        either engine-backed or simulated for its whole life, but the
        store shouldn't have to be told which)."""
        for r in reversed(self.records):
            if r.device_id == device_id:
                return r.channel
        return SIMULATED

    # ------------------------------------------------------------ errors --
    def mape(self, tier: Optional[str] = None,
             calibration: Optional[Calibration] = None,
             per_device_calibration: bool = False,
             per_tier_calibration: bool = False,
             since_tick: int = 0,
             channel: Optional[str] = None) -> float:
        """Mean absolute percentage error of latency predictions vs
        observations.  With ``calibration`` the stored *raw* predictions
        are corrected first — so before/after MAPE under the same record
        set isolates exactly what the feedback loop bought.  With
        ``per_tier_calibration`` each record uses its tier's pooled fit on
        its own channel (the crowd-shared regime); with
        ``per_device_calibration`` each record instead uses its own
        device's fitted correction on its own channel (the
        non-crowd-shared regime).  ``channel`` restricts the record set to
        one measurement channel."""
        errs = []
        for r in self.records:
            if tier is not None and r.tier != tier:
                continue
            if channel is not None and r.channel != channel:
                continue
            if r.tick < since_tick or r.observed_latency_s <= 0:
                continue
            pred = r.predicted_latency_s
            if per_device_calibration:
                pred = self.calibration_for_device(
                    r.device_id, r.channel).latency(pred)
            elif per_tier_calibration:
                pred = self.calibration_for_tier(
                    r.tier, r.channel).latency(pred)
            elif calibration is not None:
                pred = calibration.latency(pred)
            errs.append(abs(pred - r.observed_latency_s)
                        / r.observed_latency_s)
        return float(np.mean(errs)) if errs else float("nan")
