"""Crowd telemetry: measurement records + prediction calibration.

This is the feedback path the paper names as the key open challenge —
"feeding back runtime performance from the back-end level to the
front-end level optimization decision".  Devices report (predicted,
observed) latency/energy pairs per adaptation tick; the store fits an
affine correction per hardware tier (EWMA ratio while samples are
scarce, windowed least squares once enough accumulate) and hands back
:class:`repro.core.profiler.Calibration` objects the optimizer's
``ActionEvaluator`` applies to every subsequent estimate.

Tier-level pooling is the crowd-knowledge transfer: a freshly joined
pixel_6 benefits immediately from measurements contributed by every
other light-tier phone, before it has produced a single sample itself.

Pooling is split by **measurement channel**: engine-backed devices
report real decode-step wall-times, simulated devices report analytic
latencies scaled by latent silicon bias — two scales that share no
affine relationship.  Calibrator populations are keyed on
``(tier, channel)`` (and ``(device, channel)``), so a fleet mixing both
kinds never cross-contaminates its fits.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.profiler import Calibration

# measurement channels: what produced the observation
SIMULATED = "simulated"     # latent-bias silicon simulation (analytic scale)
ENGINE = "engine"           # real ServingEngine step wall-times
CHANNELS = (SIMULATED, ENGINE)


@dataclass(frozen=True)
class MeasurementRecord:
    """One back-end observation of one adaptation-loop decision."""
    device_id: str
    tier: str
    tick: int
    predicted_latency_s: float       # raw analytic estimate (uncalibrated)
    observed_latency_s: float
    predicted_energy_j: float
    observed_energy_j: float
    tokens: int = 0
    channel: str = SIMULATED


class EwmaLsqCalibrator:
    """Affine latency correction + ratio energy correction.

    Cold start: an EWMA of the observed/predicted ratio (bias-only, robust
    from the very first sample).  Warm: least-squares fit of
    ``observed ≈ a·predicted + b`` over a sliding window, which also
    captures fixed per-step overheads (dispatch, cache swaps) that a pure
    ratio cannot."""

    def __init__(self, window: int = 64, alpha: float = 0.3,
                 min_lsq_samples: int = 8):
        self.window = window
        self.alpha = alpha
        self.min_lsq_samples = min_lsq_samples
        self._lat: Deque[Tuple[float, float]] = deque(maxlen=window)
        self._ratio_lat = 1.0
        self._ratio_en = 1.0
        self._n = 0

    def observe(self, pred_lat: float, obs_lat: float,
                pred_en: float, obs_en: float) -> None:
        if pred_lat <= 0 or obs_lat <= 0:
            return
        self._lat.append((pred_lat, obs_lat))
        r = obs_lat / pred_lat
        a = self.alpha
        self._ratio_lat = (1 - a) * self._ratio_lat + a * r if self._n \
            else r
        if pred_en > 0 and obs_en > 0:
            re = obs_en / pred_en
            self._ratio_en = (1 - a) * self._ratio_en + a * re if self._n \
                else re
        self._n += 1

    @property
    def samples(self) -> int:
        return self._n

    def calibration(self) -> Calibration:
        scale, bias = self._ratio_lat, 0.0
        if len(self._lat) >= self.min_lsq_samples:
            p = np.array([x for x, _ in self._lat])
            o = np.array([y for _, y in self._lat])
            # degenerate spread (all predictions identical) → ratio only
            if float(p.std()) > 1e-9 * max(float(p.mean()), 1e-30):
                A = np.stack([p, np.ones_like(p)], axis=1)
                (a, b), *_ = np.linalg.lstsq(A, o, rcond=None)
                # accept the affine fit only if it actually beats the
                # ratio on the window — outliers (compile spikes, load
                # bursts) can drive LSQ to wild slopes/negative intercepts
                if a > 0:
                    lsq_err = np.mean(np.abs(np.maximum(a * p + b, 1e-12)
                                             - o) / o)
                    ratio_err = np.mean(np.abs(self._ratio_lat * p - o) / o)
                    if lsq_err < ratio_err:
                        scale, bias = float(a), float(b)
        return Calibration(latency_scale=scale, latency_bias_s=bias,
                           energy_scale=self._ratio_en, samples=self._n)


class TelemetryStore:
    """Fleet-wide record store with per-(tier, channel) crowd-shared and
    per-(device, channel) calibrators."""

    def __init__(self, window: int = 64, alpha: float = 0.3,
                 min_lsq_samples: int = 8):
        self._kw = dict(window=window, alpha=alpha,
                        min_lsq_samples=min_lsq_samples)
        self.records: List[MeasurementRecord] = []
        self._by_tier: Dict[Tuple[str, str], EwmaLsqCalibrator] = {}
        self._by_device: Dict[Tuple[str, str], EwmaLsqCalibrator] = {}

    # ------------------------------------------------------------ intake --
    def record(self, rec: MeasurementRecord) -> None:
        self.records.append(rec)
        for key, table in (((rec.tier, rec.channel), self._by_tier),
                           ((rec.device_id, rec.channel), self._by_device)):
            if key not in table:
                table[key] = EwmaLsqCalibrator(**self._kw)
            table[key].observe(rec.predicted_latency_s,
                               rec.observed_latency_s,
                               rec.predicted_energy_j,
                               rec.observed_energy_j)

    # ----------------------------------------------------------- lookup ---
    def calibration_for_tier(self, tier: str,
                             channel: str = SIMULATED) -> Calibration:
        c = self._by_tier.get((tier, channel))
        return c.calibration() if c else Calibration()

    def calibration_for_device(self, device_id: str,
                               channel: str = SIMULATED) -> Calibration:
        c = self._by_device.get((device_id, channel))
        return c.calibration() if c else Calibration()

    def device_channel(self, device_id: str) -> str:
        """The channel a device most recently reported on (a device is
        either engine-backed or simulated for its whole life, but the
        store shouldn't have to be told which)."""
        for r in reversed(self.records):
            if r.device_id == device_id:
                return r.channel
        return SIMULATED

    # ------------------------------------------------------------ errors --
    def mape(self, tier: Optional[str] = None,
             calibration: Optional[Calibration] = None,
             per_device_calibration: bool = False,
             per_tier_calibration: bool = False,
             since_tick: int = 0,
             channel: Optional[str] = None) -> float:
        """Mean absolute percentage error of latency predictions vs
        observations.  With ``calibration`` the stored *raw* predictions
        are corrected first — so before/after MAPE under the same record
        set isolates exactly what the feedback loop bought.  With
        ``per_tier_calibration`` each record uses its tier's pooled fit on
        its own channel (the crowd-shared regime); with
        ``per_device_calibration`` each record instead uses its own
        device's fitted correction on its own channel (the
        non-crowd-shared regime).  ``channel`` restricts the record set to
        one measurement channel."""
        errs = []
        for r in self.records:
            if tier is not None and r.tier != tier:
                continue
            if channel is not None and r.channel != channel:
                continue
            if r.tick < since_tick or r.observed_latency_s <= 0:
                continue
            pred = r.predicted_latency_s
            if per_device_calibration:
                pred = self.calibration_for_device(
                    r.device_id, r.channel).latency(pred)
            elif per_tier_calibration:
                pred = self.calibration_for_tier(
                    r.tier, r.channel).latency(pred)
            elif calibration is not None:
                pred = calibration.latency(pred)
            errs.append(abs(pred - r.observed_latency_s)
                        / r.observed_latency_s)
        return float(np.mean(errs)) if errs else float("nan")
