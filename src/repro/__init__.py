"""repro — CrowdHMTware-in-JAX: cross-level co-adaptation middleware for
TPU-pod DL deployment (see README.md / DESIGN.md)."""

__version__ = "1.0.0"

from repro.models.configs import INPUT_SHAPES, InputShape, ModelConfig

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "__version__"]
