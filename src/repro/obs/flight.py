"""Flight recorder: a bounded ring of trace events with anomaly dumps.

A long-running fleet cannot keep its whole timeline in memory, but the
seconds *around* an anomaly are exactly what a post-mortem needs.
:class:`FlightRecorder` is a drop-in :class:`~repro.obs.recorder
.TraceRecorder` whose event list is a fixed-size ring (oldest events
evicted, ``dropped`` counts evictions so span queries degrade to the
lenient pairing path automatically).  When a trigger instant lands —
by default ``detector.dead``, ``engine.oom``, ``slo.page``,
``fleet.evict`` — it arms a dump of the last ``window_s`` seconds of
trace; the dump finalizes once ``post_roll_s`` more trace has streamed
past (or at :meth:`flush`), so the capture brackets the anomaly rather
than ending on it.

Dumps are full Chrome-trace documents (rendered through
:func:`~repro.obs.export.chrome_trace`, which closes spans left open at
the window edge and drops ENDs whose BEGIN fell outside it), so every
dump validates through ``tools/check_trace.py`` — truncation is flagged
via ``otherData.dropped_events``, never a validation failure.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from .export import chrome_trace
from .recorder import Event, INSTANT, TraceRecorder

DEFAULT_TRIGGERS = ("detector.dead", "engine.oom", "slo.page",
                    "fleet.evict")


class _RingView:
    """The minimal recorder surface ``chrome_trace`` consumes: a slice
    of the ring plus an honest dropped count (ring evictions + events
    clipped off the front of the window)."""

    __slots__ = ("events", "dropped")

    def __init__(self, events: List[Event], dropped: int):
        self.events = events
        self.dropped = dropped


class FlightRecorder(TraceRecorder):
    """A :class:`TraceRecorder` over a bounded ring, with triggered
    post-mortem dumps.  Pass it anywhere a recorder goes (engine,
    controller) — recording never stops; only the oldest events age
    out."""

    def __init__(self, sim_clock=None, capacity: int = 8192,
                 window_s: float = 5.0, post_roll_s: float = 0.5,
                 triggers: Tuple[str, ...] = DEFAULT_TRIGGERS,
                 max_dumps: int = 16):
        super().__init__(sim_clock=sim_clock, capacity=capacity)
        self.events = deque(maxlen=capacity)      # ring, not a stop-list
        self.window_s = float(window_s)
        self.post_roll_s = float(post_roll_s)
        self.triggers = tuple(triggers)
        self.max_dumps = int(max_dumps)
        self.dumps: List[Dict] = []
        self._pending: List[Tuple[Event, float]] = []

    # ------------------------------------------------------------- emit --
    def _clock_of(self, e: Event) -> float:
        # one timebase per dump, same rule as the exporter's "auto":
        # the sim clock only when every ringed event carries one
        use_sim = all(ev.sim_s is not None for ev in self.events)
        return e.sim_s if (use_sim and e.sim_s is not None) else e.wall_s

    def _emit(self, name, cat, ph, pid, tid, wall_s, args) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1           # the ring evicts its oldest
        e = Event(name=name, cat=cat, ph=ph,
                  wall_s=time.perf_counter() if wall_s is None else wall_s,
                  sim_s=self.sim_clock() if self.sim_clock is not None
                  else None,
                  pid=pid, tid=tid, args=args)
        self.events.append(e)
        ts = self._clock_of(e)
        if self._pending:
            self._finalize_due(ts)
        if ph == INSTANT and name in self.triggers \
                and len(self.dumps) + len(self._pending) < self.max_dumps:
            self._pending.append((e, ts + self.post_roll_s))

    # ------------------------------------------------------------ dumps --
    def _finalize_due(self, now_ts: float) -> None:
        due = [p for p in self._pending if now_ts >= p[1]]
        if due:
            self._pending = [p for p in self._pending if now_ts < p[1]]
            for trig, deadline in due:
                self.dumps.append(self._dump(trig, deadline))

    def _dump(self, trigger: Event, until_ts: float) -> Dict:
        trig_ts = self._clock_of(trigger)
        lo = trig_ts - self.window_s
        use_sim = all(ev.sim_s is not None for ev in self.events)
        clock = "sim" if use_sim else "wall"

        def ts_of(ev: Event) -> float:
            return ev.sim_s if use_sim else ev.wall_s

        window = [ev for ev in self.events if lo <= ts_of(ev) <= until_ts]
        clipped = sum(1 for ev in self.events if ts_of(ev) < lo)
        trace = chrome_trace(_RingView(window, self.dropped + clipped),
                             clock=clock)
        return {"anomaly": trigger.name, "pid": trigger.pid,
                "args": dict(trigger.args or {}), "ts_s": trig_ts,
                "clock": clock, "events": len(window), "trace": trace}

    def snapshot(self, anomaly: str = "manual") -> Dict:
        """Dump the current window unconditionally (post-mortems of
        conditions the trigger list doesn't name)."""
        if not self.events:
            raise ValueError("flight ring is empty — nothing to snapshot")
        marker = self.events[-1]
        dump = self._dump(
            Event(name=anomaly, cat="fleet", ph=INSTANT,
                  wall_s=marker.wall_s, sim_s=marker.sim_s,
                  pid=marker.pid, tid=marker.tid, args=None),
            self._clock_of(marker))
        self.dumps.append(dump)
        return dump

    def flush(self) -> List[Dict]:
        """Finalize every armed dump regardless of post-roll (end of
        run) and return all dumps."""
        self._finalize_due(float("inf"))
        return self.dumps

    def write_dumps(self, directory: str) -> List[str]:
        """Write each dump's trace as ``flight_<n>_<anomaly>.json``
        under ``directory`` (validated post-mortem artifacts — run
        ``tools/check_trace.py`` over them)."""
        self.flush()
        os.makedirs(directory, exist_ok=True)
        paths = []
        for i, d in enumerate(self.dumps):
            safe = d["anomaly"].replace(".", "_").replace("/", "_")
            path = os.path.join(directory, f"flight_{i}_{safe}.json")
            with open(path, "w") as f:
                json.dump(d["trace"], f, default=str)
            paths.append(path)
        return paths

    def clear(self) -> None:
        super().clear()
        self.dumps = []
        self._pending = []
