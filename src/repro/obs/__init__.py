"""Fleet-wide observability: tracing spans + metrics, one timeline.

The paper's adaptation loop is only auditable if every layer leaves a
record on a shared timebase.  This package provides:

* :mod:`~repro.obs.recorder` — structured begin/end/instant events with
  **dual timestamps** (wall ``perf_counter`` + the fleet's simulated
  clock), a :class:`TraceRecorder` that collects them, and the no-op
  :data:`NULL_RECORDER` default that keeps disabled hot paths at one
  attribute load per tick;
* :mod:`~repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges, EWMA gauges and P² streaming-quantile histograms that backs
  the legacy public stat surfaces (``ServeStats``,
  ``step_time_ewma_s``, the fleet's wake/violation tallies) as views;
* :mod:`~repro.obs.export` — Chrome-trace/Perfetto ``trace.json``
  export (pid=device, tid=slot/subsystem, ts on one chosen clock);
* :mod:`~repro.obs.query` — span pairing and request-metric helpers
  (span-derived TTFT/TPOT, per-rid token accounting), with lenient
  pairing (:func:`pair_spans`) for truncated traces;
* :mod:`~repro.obs.analysis` — per-request critical-path latency
  attribution (components sum bit-equal to end-to-end latency) and the
  :func:`attribute_fleet` tail-latency rollup;
* :mod:`~repro.obs.slo` — :class:`SLOClass` targets scored as rolling
  burn-rate windows; the :class:`SLOTracker` pressure signal is what
  the fleet controller feeds back into the adaptation loop;
* :mod:`~repro.obs.flight` — :class:`FlightRecorder`, a bounded ring
  that dumps the seconds around anomalies as validated trace files.

Span taxonomy and metric names are documented in
``docs/OBSERVABILITY.md``; ``tools/check_trace.py`` validates exported
traces in CI, and ``tools/check_perf.py`` gates committed
``BENCH_*.json`` artifacts against tolerance baselines.
"""
from .analysis import (COMPONENT_LAYER, COMPONENTS, DeviceAttribution,
                       FleetAttribution, RequestAttribution,
                       attribute_fleet, attribute_requests)
from .export import chrome_trace, write_trace
from .flight import DEFAULT_TRIGGERS, FlightRecorder
from .metrics import (Counter, EwmaGauge, Gauge, Histogram,
                      MetricsRegistry)
from .query import (PairingReport, Span, events, instants, pair_spans,
                    request_token_counts, request_tpot_s, request_ttft_s,
                    spans)
from .recorder import (BEGIN, COUNTER, END, INSTANT, LAYERS,
                       NULL_RECORDER, Event, NullRecorder, TraceRecorder)
from .slo import SLOClass, SLOTracker

__all__ = ["chrome_trace", "write_trace",
           "Counter", "EwmaGauge", "Gauge", "Histogram", "MetricsRegistry",
           "PairingReport", "Span", "events", "instants", "pair_spans",
           "request_token_counts", "request_tpot_s", "request_ttft_s",
           "spans",
           "COMPONENT_LAYER", "COMPONENTS", "DeviceAttribution",
           "FleetAttribution", "RequestAttribution", "attribute_fleet",
           "attribute_requests",
           "SLOClass", "SLOTracker",
           "DEFAULT_TRIGGERS", "FlightRecorder",
           "BEGIN", "COUNTER", "END", "INSTANT", "LAYERS",
           "NULL_RECORDER", "Event", "NullRecorder", "TraceRecorder"]
