"""Fleet-wide observability: tracing spans + metrics, one timeline.

The paper's adaptation loop is only auditable if every layer leaves a
record on a shared timebase.  This package provides:

* :mod:`~repro.obs.recorder` — structured begin/end/instant events with
  **dual timestamps** (wall ``perf_counter`` + the fleet's simulated
  clock), a :class:`TraceRecorder` that collects them, and the no-op
  :data:`NULL_RECORDER` default that keeps disabled hot paths at one
  attribute load per tick;
* :mod:`~repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges, EWMA gauges and P² streaming-quantile histograms that backs
  the legacy public stat surfaces (``ServeStats``,
  ``step_time_ewma_s``, the fleet's wake/violation tallies) as views;
* :mod:`~repro.obs.export` — Chrome-trace/Perfetto ``trace.json``
  export (pid=device, tid=slot/subsystem, ts on one chosen clock);
* :mod:`~repro.obs.query` — span pairing and request-metric helpers
  (span-derived TTFT/TPOT, per-rid token accounting).

Span taxonomy and metric names are documented in
``docs/OBSERVABILITY.md``; ``tools/check_trace.py`` validates exported
traces in CI.
"""
from .export import chrome_trace, write_trace
from .metrics import (Counter, EwmaGauge, Gauge, Histogram,
                      MetricsRegistry)
from .query import (Span, events, instants, request_token_counts,
                    request_tpot_s, request_ttft_s, spans)
from .recorder import (BEGIN, COUNTER, END, INSTANT, LAYERS,
                       NULL_RECORDER, Event, NullRecorder, TraceRecorder)

__all__ = ["chrome_trace", "write_trace",
           "Counter", "EwmaGauge", "Gauge", "Histogram", "MetricsRegistry",
           "Span", "events", "instants", "request_token_counts",
           "request_tpot_s", "request_ttft_s", "spans",
           "BEGIN", "COUNTER", "END", "INSTANT", "LAYERS",
           "NULL_RECORDER", "Event", "NullRecorder", "TraceRecorder"]
