"""Query helpers over recorded events: span pairing + request metrics.

Benchmarks and tests should derive latency figures from spans through
these helpers instead of re-implementing hand-stamped arithmetic —
``request_ttft_s`` is the span-derived replacement for the legacy
``first_token_s - arrived_s`` subtraction (and is asserted equal to it
in ``tests/test_obs.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

from .recorder import BEGIN, END, INSTANT, Event, TraceRecorder


@dataclass(frozen=True)
class Span:
    """A paired begin/end: ``args`` merges the begin args with the end
    args (end wins on key collisions — it carries the outcome)."""
    name: str
    cat: str
    pid: str
    tid: str
    wall_begin_s: float
    wall_end_s: float
    sim_begin_s: Optional[float]
    sim_end_s: Optional[float]
    args: Dict[str, object]

    @property
    def wall_dur_s(self) -> float:
        return self.wall_end_s - self.wall_begin_s

    @property
    def sim_dur_s(self) -> Optional[float]:
        if self.sim_begin_s is None or self.sim_end_s is None:
            return None
        return self.sim_end_s - self.sim_begin_s


def events(rec: TraceRecorder, name: Optional[str] = None,
           cat: Optional[str] = None, ph: Optional[str] = None,
           pid: Optional[str] = None, tid: Optional[str] = None,
           **arg_filters) -> Iterator[Event]:
    """Filtered view over the raw event list; ``arg_filters`` match
    against ``Event.args`` entries (missing key = no match)."""
    for e in rec.events:
        if name is not None and e.name != name:
            continue
        if cat is not None and e.cat != cat:
            continue
        if ph is not None and e.ph != ph:
            continue
        if pid is not None and e.pid != pid:
            continue
        if tid is not None and e.tid != tid:
            continue
        if arg_filters:
            a = e.args or {}
            if any(k not in a or a[k] != v
                   for k, v in arg_filters.items()):
                continue
        yield e


def instants(rec: TraceRecorder, name: Optional[str] = None,
             **kw) -> List[Event]:
    return list(events(rec, name=name, ph=INSTANT, **kw))


@dataclass
class PairingReport:
    """What :func:`pair_spans` recovered from a (possibly truncated)
    trace: the well-paired spans plus counts of edges that could not
    pair — ``orphaned_ends`` (an END whose BEGIN was dropped at the
    recorder's capacity ceiling or aged out of a flight ring) and
    ``unclosed_begins`` (a BEGIN whose END was dropped / hadn't landed
    yet).  ``truncated`` records whether the source recorder reported
    dropped events — only then is lenient accounting legitimate."""
    spans: List[Span]
    orphaned_ends: int = 0
    unclosed_begins: int = 0
    truncated: bool = False


def pair_spans(evts, dropped: int = 0,
               strict: Optional[bool] = None) -> PairingReport:
    """Pair begin/end events into :class:`Span` rows, walking each
    ``(pid, tid)`` track with a stack (spans must nest per track — the
    recording discipline the property tests pin).

    On a complete trace (``dropped == 0``, the default ``strict``) a
    mismatched or dangling edge raises, because a malformed trace
    should fail the query, not silently drop rows.  When the recorder
    *reported truncation* (``dropped > 0``) the same defects are an
    expected artifact of the lost events, so pairing degrades to a
    counted report: orphaned ENDs are skipped (never popping an
    unrelated frame), dangling BEGINs are tallied, and every span that
    did survive is still returned."""
    if strict is None:
        strict = dropped == 0
    stacks: Dict[tuple, List[Event]] = {}
    out: List[Span] = []
    orphaned = 0
    for e in evts:
        if e.ph not in (BEGIN, END):
            continue
        key = (e.pid, e.tid)
        stack = stacks.setdefault(key, [])
        if e.ph == BEGIN:
            stack.append(e)
            continue
        if not stack:
            if strict:
                raise ValueError(f"end without begin: {e.name!r} on {key}")
            orphaned += 1
            continue
        if stack[-1].name != e.name:
            if strict:
                raise ValueError(f"mis-nested spans on {key}: begin "
                                 f"{stack[-1].name!r} closed by end "
                                 f"{e.name!r}")
            # the matching BEGIN was dropped; popping the (unrelated)
            # top frame would corrupt an outer span's pairing
            orphaned += 1
            continue
        b = stack.pop()
        merged = dict(b.args or {})
        merged.update(e.args or {})
        out.append(Span(name=b.name, cat=b.cat, pid=b.pid, tid=b.tid,
                        wall_begin_s=b.wall_s, wall_end_s=e.wall_s,
                        sim_begin_s=b.sim_s, sim_end_s=e.sim_s,
                        args=merged))
    unclosed = 0
    for key, stack in stacks.items():
        if stack:
            if strict:
                raise ValueError(f"unclosed span(s) on {key}: "
                                 f"{[b.name for b in stack]}")
            unclosed += len(stack)
    return PairingReport(spans=out, orphaned_ends=orphaned,
                         unclosed_begins=unclosed,
                         truncated=dropped > 0)


def spans(rec: TraceRecorder, name: Optional[str] = None,
          cat: Optional[str] = None, pid: Optional[str] = None,
          tid: Optional[str] = None,
          strict: Optional[bool] = None) -> List[Span]:
    """Paired :class:`Span` rows (see :func:`pair_spans` for the
    pairing/strictness contract — a saturated recorder degrades to
    lenient pairing instead of raising on its truncation artifacts).
    Filters apply to the *paired* spans, so an enclosing span of
    another name never hides its children."""
    report = pair_spans(rec.events, dropped=getattr(rec, "dropped", 0),
                        strict=strict)

    def keep(s: Span) -> bool:
        return ((name is None or s.name == name)
                and (cat is None or s.cat == cat)
                and (pid is None or s.pid == pid)
                and (tid is None or s.tid == tid))

    return [s for s in report.spans if keep(s)]


# ------------------------------------------------------ request metrics ----
def request_ttft_s(rec: TraceRecorder,
                   pid: Optional[str] = None) -> Dict[int, float]:
    """Span-derived time-to-first-token per rid (wall clock): first
    ``req.queued`` instant → first ``req.first_token`` instant.  Both
    instants are stamped with the exact floats the engine writes into
    ``Request.arrived_s`` / ``first_token_s``, so this equals the
    legacy subtraction bit-for-bit."""
    queued: Dict[int, float] = {}
    first: Dict[int, float] = {}
    for e in events(rec, name="req.queued", ph=INSTANT, pid=pid):
        rid = e.args["rid"]
        queued.setdefault(rid, e.wall_s)
    for e in events(rec, name="req.first_token", ph=INSTANT, pid=pid):
        rid = e.args["rid"]
        first.setdefault(rid, e.wall_s)
    return {rid: first[rid] - queued[rid]
            for rid in first if rid in queued}


def request_token_counts(rec: TraceRecorder,
                         pid: Optional[str] = None
                         ) -> Dict[int, Dict[str, int]]:
    """Per rid: how many admissions (``first_token`` instants — each
    admission's prefill emits exactly one) and how many decode-tick
    tokens (``req.decode`` instants).  Total tokens generated for a rid
    is ``admissions + decodes``."""
    out: Dict[int, Dict[str, int]] = {}
    for e in events(rec, name="req.first_token", ph=INSTANT, pid=pid):
        d = out.setdefault(e.args["rid"], {"admissions": 0, "decodes": 0})
        d["admissions"] += 1
    for e in events(rec, name="req.decode", ph=INSTANT, pid=pid):
        d = out.setdefault(e.args["rid"], {"admissions": 0, "decodes": 0})
        d["decodes"] += 1
    return out


def request_tpot_s(rec: TraceRecorder,
                   pid: Optional[str] = None) -> Dict[int, float]:
    """Span-derived mean time-per-output-token per rid: the wall span
    from the first token to the last decode instant, divided by the
    decode-token count (undefined — omitted — for rids that never
    decoded past their prefill token)."""
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    count: Dict[int, int] = {}
    for e in events(rec, name="req.first_token", ph=INSTANT, pid=pid):
        first.setdefault(e.args["rid"], e.wall_s)
    for e in events(rec, name="req.decode", ph=INSTANT, pid=pid):
        rid = e.args["rid"]
        last[rid] = e.wall_s
        count[rid] = count.get(rid, 0) + 1
    return {rid: (last[rid] - first[rid]) / count[rid]
            for rid in count if rid in first and count[rid] > 0}
